"""Distributed plan pipeline: placement quality, strong scaling, batched
segments, dynamic runtime, and real multi-device parity (DESIGN.md §11/§13).

Four sections, all feeding one artifact:

* **placement + strong scaling** (in-process, deterministic): ``analyze``
  each matrix once, build the ``pack_panels``-bin placement over the
  strong-scaling device counts {1, 2, 4, 8}, and report the *modeled
  level-parallel speedup* — total panel weight over the sum of per-level
  maximum per-device loads (the critical path of a device-parallel level
  sweep).  These are exact scheduling quantities, machine-portable, and
  gated against the committed baseline (``run.py --check-baseline``,
  ratio keys ``*_speedup``).  Every device must receive panel work
  (enforced here, not just in the baseline).
* **batched segments** (bbd-8k): wall-clock of the same-shape stacked
  segment GEMMs (``LUOptions.segment_batch``) against per-panel dispatch
  — the kernel backend must win by >= 1.3x (hard gate; the stack
  amortizes per-panel launch overhead B-fold).
* **dynamic runtime** (in-process): ``runtime="dynamic"`` analyze through
  the work-stealing scheduler + a flat-mesh sharded analyze, both bitwise
  against the static reference — this is also where the ``runtime`` and
  ``overlap`` trace phases the ``--trace`` acceptance run validates come
  from (the double-buffered fixpoint hides host reduction behind the next
  device step).
* **multidevice-8** (subprocess under ``XLA_FLAGS=--xla_force_host_
  platform_device_count=8``): the sharded analyze against the mesh-less
  reference — counts, supernodes, pattern, and factors must be
  *bitwise-identical* (enforced; this is the same contract the
  ``tests/test_distributed_plan.py`` tier holds at {1, 2, 8}), plus the
  per-device edge-check balance of the interleaved source sharding and
  wall times (reported, never gated — forced host devices share one CPU).

Exits nonzero (via run.py) if parity, coverage, or any enforced gate
fails.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import print_table, save_artifact
from repro.api import LUOptions, analyze
from repro.numeric.schedule import build_placement
from repro.sparse import (
    bordered_block_diagonal, circuit_like, grid2d_laplacian, permute_csr,
    rcm_order,
)
from repro.sparse.numeric import generic_values_csr
from repro.supernodes.balance import supernode_weights

DEVICE_COUNTS = (2, 8)
# strong-scaling sweep of the modeled level-parallel speedup: D=1 anchors
# the curve at 1.0, the rest show how far the structure's level widths
# carry before the per-level critical path flattens the curve
SCALING_COUNTS = (1, 2, 4, 8)

# grid2d is the honest control: an RCM-ordered stencil condenses to a
# serial supernode chain (max level width 1), so its placement speedup is
# exactly 1.0 at any device count — level-parallelism is a property of the
# structure, and the BBD circuit analogues are where it exists (wide
# independent-block levels; the paper's target workload)
MATRICES = {
    "grid2d-24": lambda: grid2d_laplacian(24),
    "bbd-4k": lambda: bordered_block_diagonal(4096, block=32, border=32,
                                              seed=3),
    "bbd-8k": lambda: bordered_block_diagonal(8192, block=16, border=64,
                                              seed=3),
}

_SUBPROCESS = r"""
import json
import time
import numpy as np
import jax

assert len(jax.devices()) == 8, len(jax.devices())

from repro.core.symbolic import symbolic_factorize
from repro.launch.mesh import make_flat_mesh
from repro.sparse import circuit_like, permute_csr, rcm_order

a = circuit_like(512, seed=7)
a = permute_csr(a, rcm_order(a))
kw = dict(concurrency=64, detect_supernodes=True, supernode_relax=2,
          collect_pattern=True)

t0 = time.perf_counter()
ref = symbolic_factorize(a, **kw)
t_single = time.perf_counter() - t0

mesh = make_flat_mesh()
t0 = time.perf_counter()
dist = symbolic_factorize(a, mesh=mesh, **kw)
t_dist = time.perf_counter() - t0

parity = bool(
    np.array_equal(ref.l_counts, dist.l_counts)
    and np.array_equal(ref.u_counts, dist.u_counts)
    and np.array_equal(ref.supernodes, dist.supernodes)
    and np.array_equal(ref.pattern.indptr, dist.pattern.indptr)
    and np.array_equal(ref.pattern.rowind, dist.pattern.rowind))
print("RESULT " + json.dumps({
    "parity": int(parity),
    "n": a.n,
    "n_shards": dist.dist["n_shards"],
    "balance_ratio": dist.dist["balance_ratio"],
    "t_analyze_single_s": t_single,
    "t_analyze_dist_s": t_dist,
}))
"""


def modeled_level_speedup(plan, n_devices: int) -> dict:
    """Modeled device-parallel speedup of the level sweep under the plan's
    bin placement: serial cost = total panel weight; parallel cost = sum
    over levels of the heaviest per-device load (the level's critical
    path).  Exact and deterministic — this is a property of the schedule,
    not of the machine."""
    placement = build_placement(plan.schedule, n_devices)
    loads = placement.level_loads(plan.schedule)        # (levels, devices)
    weights = supernode_weights(plan.schedule.supernodes,
                                plan.schedule.col_counts)
    serial = float(weights.sum())
    parallel = float(loads.max(axis=1).sum())
    return {
        "speedup": serial / max(1.0, parallel),
        "devices_used": int(np.unique(placement.device_of_panel).size),
    }


def _measured_imbalance(plan, a, n_devices: int = 8) -> dict:
    """*Measured* per-level segment imbalance of the device-segmented
    numeric sweep — the wall-clock counterpart of the modeled
    ``placement*_speedup`` columns (modeled numbers say what the LPT bins
    *should* cost; this runs the sweep with the placement installed, obs
    enabled, and reads the ``factor.level_imbalance_measured`` histogram
    the per-segment spans recorded).  Also the traced analyze+factorize+
    solve pass the ``--trace`` acceptance trace comes from."""
    from repro import obs

    prev = plan.placement
    plan.placement = build_placement(plan.schedule, n_devices)
    values = generic_values_csr(a)
    reg = obs.registry()
    try:
        with obs.ensure(True):
            h0 = reg.get("factor.level_imbalance_measured")
            c0 = h0.count if h0 is not None else 0
            factor = plan.factorize(values)
            factor.solve(np.ones(a.n))
    finally:
        plan.placement = prev
    h = reg.get("factor.level_imbalance_measured")
    vals = h.values[c0:] if h is not None else []
    if not vals:
        raise RuntimeError(
            "segmented sweep recorded no per-level imbalance measurements "
            "— the factor_segment instrumentation is disconnected")
    arr = np.asarray(vals)
    return {
        "n_devices": n_devices,
        "levels_measured": len(vals),
        "imbalance_mean": float(arr.mean()),
        "imbalance_p90": float(np.percentile(arr, 90)),
        "imbalance_max": float(arr.max()),
    }


def _batched_segment_case(plan, a, *, repeats: int = 3,
                          min_speedup: float = 1.3) -> dict:
    """Wall-clock of the same-shape batched segment GEMMs
    (``LUOptions.segment_batch``, DESIGN.md §13) against per-panel
    dispatch: best-of-N factorize each way on the same plan.  The batched
    path folds every same-shape panel of a segment into ONE kernel launch,
    amortizing per-panel dispatch overhead B-fold on the Pallas backend —
    so the kernel-backend ratio must clear ``min_speedup`` (hard gate, and
    the ``*_speedup`` keys are floor-gated against the committed
    baseline).  The numpy-backend ratio (stacked ``np.matmul`` vs
    per-panel BLAS calls) is reported alongside as an ungated ratio —
    BLAS calls carry far less launch overhead than interpret-mode Pallas,
    so the win there is small and noisy on a shared CPU."""
    values = generic_values_csr(a)
    prev = plan.options
    times = {}
    try:
        for backend in ("kernel", "numpy"):
            for sb in (True, False):
                plan.options = prev.replace(numeric_backend=backend,
                                            segment_batch=sb)
                best = float("inf")
                for _ in range(repeats):
                    t0 = time.perf_counter()
                    plan.factorize(values)
                    best = min(best, time.perf_counter() - t0)
                times[(backend, sb)] = best
    finally:
        plan.options = prev
    speedup = times[("kernel", False)] / times[("kernel", True)]
    if speedup < min_speedup:
        raise RuntimeError(
            f"batched segment GEMMs won only {speedup:.2f}x over per-panel "
            f"dispatch on the kernel backend — below the {min_speedup:.1f}x "
            f"floor; the stacked dispatch is not amortizing launches")
    return {
        "batched_segment_speedup": speedup,
        "batched_numpy_ratio":
            times[("numpy", False)] / times[("numpy", True)],
        "t_factor_batched_s": times[("kernel", True)],
        "t_factor_perpanel_s": times[("kernel", False)],
    }


def _runtime_case() -> dict:
    """Dynamic-runtime analyze (work-stealing scheduler) + flat-mesh
    sharded analyze, both in-process and both bitwise against the static
    reference.  Under ``--trace`` this is what puts the ``runtime`` span
    (scheduler drain loop) and the ``overlap`` span (double-buffered host
    reduction hidden behind the next device step) into the suite's trace —
    ``run.py --validate-traces`` requires both phases."""
    from repro.core.symbolic import symbolic_factorize
    from repro.launch.mesh import make_flat_mesh

    a = circuit_like(512, seed=7)
    a = permute_csr(a, rcm_order(a))
    kw = dict(concurrency=64, detect_supernodes=True, supernode_relax=2,
              collect_pattern=True)
    ref = symbolic_factorize(a, **kw)

    t0 = time.perf_counter()
    dyn = symbolic_factorize(a, runtime="dynamic", **kw)
    t_dyn = time.perf_counter() - t0
    if not (np.array_equal(ref.l_counts, dyn.l_counts)
            and np.array_equal(ref.u_counts, dyn.u_counts)
            and np.array_equal(ref.supernodes, dyn.supernodes)):
        raise RuntimeError(
            "dynamic-runtime analyze diverged from the static reference — "
            "the bitwise conformance contract is broken")

    dist = symbolic_factorize(a, mesh=make_flat_mesh(), **kw)
    if not (np.array_equal(ref.l_counts, dist.l_counts)
            and np.array_equal(ref.u_counts, dist.u_counts)):
        raise RuntimeError(
            "sharded analyze diverged from the static reference — the "
            "bitwise conformance contract is broken")
    return {
        "n": a.n,
        "chunks": dyn.runtime["chunks"],
        "completed": dyn.runtime["completed"],
        "steals": dyn.runtime["steals"],
        "reissues": dyn.runtime["reissues"],
        "t_analyze_dynamic_s": t_dyn,
        "overlap_hidden_s": float(dist.dist.get("overlap_hidden_s", 0.0)),
    }


def _multidevice_case() -> dict:
    with tempfile.TemporaryDirectory() as d:
        script = os.path.join(d, "bench_dist_sub.py")
        with open(script, "w") as f:
            f.write(_SUBPROCESS)
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(__file__), "..", "src")
        proc = subprocess.run([sys.executable, script], env=env,
                              capture_output=True, text=True, timeout=1200)
        if proc.returncode != 0:
            raise RuntimeError(f"multidevice subprocess failed:\n"
                               f"{proc.stderr[-3000:]}")
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT ")][-1]
        return json.loads(line[len("RESULT "):])


def run() -> dict:
    results = {}
    rows = []
    for name, gen in MATRICES.items():
        m = gen()
        a = permute_csr(m, rcm_order(m))
        plan = analyze(a, LUOptions(concurrency=256, supernode_relax=2))
        max_width = max(len(lv) for lv in plan.schedule.levels)
        rec = {"n": a.n, "nnz": a.nnz, "n_panels": plan.n_supernodes,
               "n_levels": plan.n_levels, "max_level_width": max_width}
        for d in sorted(set(DEVICE_COUNTS) | set(SCALING_COUNTS)):
            m = modeled_level_speedup(plan, d)
            # per-level LPT fills min(devices, level width) bins, so the
            # widest level bounds reachable coverage — anything less means
            # the placement left reachable devices idle
            if m["devices_used"] != min(d, max_width):
                raise RuntimeError(
                    f"{name}: placement left devices idle at D={d} "
                    f"({m['devices_used']} of {min(d, max_width)} "
                    f"reachable)")
            rec[f"scaling{d}_speedup"] = m["speedup"]
            if d in DEVICE_COUNTS:
                rec[f"placement{d}_speedup"] = m["speedup"]
                rec[f"devices_used_d{d}"] = m["devices_used"]
        results[name] = rec
        rows.append([name, a.n, plan.n_supernodes, plan.n_levels,
                     " ".join(f"{rec[f'scaling{d}_speedup']:.2f}x"
                              for d in SCALING_COUNTS)])
        if name == "bbd-8k":                   # measured, not only modeled
            mi = _measured_imbalance(plan, a)
            rec["measured_imbalance"] = mi
            rows.append(["bbd-8k measured (D=8)", a.n, "-",
                         mi["levels_measured"],
                         f"imb mean {mi['imbalance_mean']:.2f} "
                         f"max {mi['imbalance_max']:.2f}"])
            bs = _batched_segment_case(plan, a)
            rec["batched_segments"] = bs
            rows.append(["bbd-8k batched segments", a.n, "-", "-",
                         f"kernel {bs['batched_segment_speedup']:.2f}x "
                         f"numpy {bs['batched_numpy_ratio']:.2f}x"])

    rt = _runtime_case()
    results["runtime-dynamic"] = rt
    rows.append(["runtime-dynamic (circuit-512)", rt["n"], "-", "-",
                 f"chunks {rt['completed']}/{rt['chunks']} "
                 f"steals {rt['steals']} reissues {rt['reissues']}"])

    md = _multidevice_case()
    if not md["parity"]:
        raise RuntimeError(
            "distributed analyze diverged from the single-device reference "
            "on 8 forced host devices — the bitwise conformance contract "
            "is broken")
    results["multidevice-8"] = md
    rows.append(["multidevice-8 (real)", md["n"], "-", "-",
                 f"balance {md['balance_ratio']:.2f} "
                 f"parity {'OK' if md['parity'] else 'BROKEN'}"])

    print_table("Distributed plan: scaling + runtime + 8-device parity",
                ["matrix", "|V|", "panels", "levels",
                 "scaling D=" + "/".join(map(str, SCALING_COUNTS))], rows)
    save_artifact("bench_distributed", results)
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
