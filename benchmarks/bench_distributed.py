"""Distributed plan pipeline: panel placement quality + real multi-device
parity (DESIGN.md §11).

Two halves, both feeding one artifact:

* **placement** (in-process, deterministic): ``analyze`` each matrix once,
  build the ``pack_panels``-bin placement at 2 and 8 devices, and report
  the *modeled level-parallel speedup* — total panel weight over the sum
  of per-level maximum per-device loads (the critical path of a
  device-parallel level sweep).  These are exact scheduling quantities,
  machine-portable, and gated against the committed baseline
  (``run.py --check-baseline``, ratio keys ``*_speedup``).  Every device
  must receive panel work (enforced here, not just in the baseline).
* **multidevice-8** (subprocess under ``XLA_FLAGS=--xla_force_host_
  platform_device_count=8``): the sharded analyze against the mesh-less
  reference — counts, supernodes, pattern, and factors must be
  *bitwise-identical* (enforced; this is the same contract the
  ``tests/test_distributed_plan.py`` tier holds at {1, 2, 8}), plus the
  per-device edge-check balance of the interleaved source sharding and
  wall times (reported, never gated — forced host devices share one CPU).

Exits nonzero (via run.py) if parity, coverage, or any enforced gate
fails.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

import numpy as np

from benchmarks.common import print_table, save_artifact
from repro.api import LUOptions, analyze
from repro.numeric.schedule import build_placement
from repro.sparse import (
    bordered_block_diagonal, grid2d_laplacian, permute_csr, rcm_order,
)
from repro.sparse.numeric import generic_values_csr
from repro.supernodes.balance import supernode_weights

DEVICE_COUNTS = (2, 8)

# grid2d is the honest control: an RCM-ordered stencil condenses to a
# serial supernode chain (max level width 1), so its placement speedup is
# exactly 1.0 at any device count — level-parallelism is a property of the
# structure, and the BBD circuit analogues are where it exists (wide
# independent-block levels; the paper's target workload)
MATRICES = {
    "grid2d-24": lambda: grid2d_laplacian(24),
    "bbd-4k": lambda: bordered_block_diagonal(4096, block=32, border=32,
                                              seed=3),
    "bbd-8k": lambda: bordered_block_diagonal(8192, block=16, border=64,
                                              seed=3),
}

_SUBPROCESS = r"""
import json
import time
import numpy as np
import jax

assert len(jax.devices()) == 8, len(jax.devices())

from repro.core.symbolic import symbolic_factorize
from repro.launch.mesh import make_flat_mesh
from repro.sparse import circuit_like, permute_csr, rcm_order

a = circuit_like(512, seed=7)
a = permute_csr(a, rcm_order(a))
kw = dict(concurrency=64, detect_supernodes=True, supernode_relax=2,
          collect_pattern=True)

t0 = time.perf_counter()
ref = symbolic_factorize(a, **kw)
t_single = time.perf_counter() - t0

mesh = make_flat_mesh()
t0 = time.perf_counter()
dist = symbolic_factorize(a, mesh=mesh, **kw)
t_dist = time.perf_counter() - t0

parity = bool(
    np.array_equal(ref.l_counts, dist.l_counts)
    and np.array_equal(ref.u_counts, dist.u_counts)
    and np.array_equal(ref.supernodes, dist.supernodes)
    and np.array_equal(ref.pattern.indptr, dist.pattern.indptr)
    and np.array_equal(ref.pattern.rowind, dist.pattern.rowind))
print("RESULT " + json.dumps({
    "parity": int(parity),
    "n": a.n,
    "n_shards": dist.dist["n_shards"],
    "balance_ratio": dist.dist["balance_ratio"],
    "t_analyze_single_s": t_single,
    "t_analyze_dist_s": t_dist,
}))
"""


def modeled_level_speedup(plan, n_devices: int) -> dict:
    """Modeled device-parallel speedup of the level sweep under the plan's
    bin placement: serial cost = total panel weight; parallel cost = sum
    over levels of the heaviest per-device load (the level's critical
    path).  Exact and deterministic — this is a property of the schedule,
    not of the machine."""
    placement = build_placement(plan.schedule, n_devices)
    loads = placement.level_loads(plan.schedule)        # (levels, devices)
    weights = supernode_weights(plan.schedule.supernodes,
                                plan.schedule.col_counts)
    serial = float(weights.sum())
    parallel = float(loads.max(axis=1).sum())
    return {
        "speedup": serial / max(1.0, parallel),
        "devices_used": int(np.unique(placement.device_of_panel).size),
    }


def _measured_imbalance(plan, a, n_devices: int = 8) -> dict:
    """*Measured* per-level segment imbalance of the device-segmented
    numeric sweep — the wall-clock counterpart of the modeled
    ``placement*_speedup`` columns (modeled numbers say what the LPT bins
    *should* cost; this runs the sweep with the placement installed, obs
    enabled, and reads the ``factor.level_imbalance_measured`` histogram
    the per-segment spans recorded).  Also the traced analyze+factorize+
    solve pass the ``--trace`` acceptance trace comes from."""
    from repro import obs

    prev = plan.placement
    plan.placement = build_placement(plan.schedule, n_devices)
    values = generic_values_csr(a)
    reg = obs.registry()
    try:
        with obs.ensure(True):
            h0 = reg.get("factor.level_imbalance_measured")
            c0 = h0.count if h0 is not None else 0
            factor = plan.factorize(values)
            factor.solve(np.ones(a.n))
    finally:
        plan.placement = prev
    h = reg.get("factor.level_imbalance_measured")
    vals = h.values[c0:] if h is not None else []
    if not vals:
        raise RuntimeError(
            "segmented sweep recorded no per-level imbalance measurements "
            "— the factor_segment instrumentation is disconnected")
    arr = np.asarray(vals)
    return {
        "n_devices": n_devices,
        "levels_measured": len(vals),
        "imbalance_mean": float(arr.mean()),
        "imbalance_p90": float(np.percentile(arr, 90)),
        "imbalance_max": float(arr.max()),
    }


def _multidevice_case() -> dict:
    with tempfile.TemporaryDirectory() as d:
        script = os.path.join(d, "bench_dist_sub.py")
        with open(script, "w") as f:
            f.write(_SUBPROCESS)
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(__file__), "..", "src")
        proc = subprocess.run([sys.executable, script], env=env,
                              capture_output=True, text=True, timeout=1200)
        if proc.returncode != 0:
            raise RuntimeError(f"multidevice subprocess failed:\n"
                               f"{proc.stderr[-3000:]}")
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT ")][-1]
        return json.loads(line[len("RESULT "):])


def run() -> dict:
    results = {}
    rows = []
    for name, gen in MATRICES.items():
        m = gen()
        a = permute_csr(m, rcm_order(m))
        plan = analyze(a, LUOptions(concurrency=256, supernode_relax=2))
        max_width = max(len(lv) for lv in plan.schedule.levels)
        rec = {"n": a.n, "nnz": a.nnz, "n_panels": plan.n_supernodes,
               "n_levels": plan.n_levels, "max_level_width": max_width}
        for d in DEVICE_COUNTS:
            m = modeled_level_speedup(plan, d)
            # per-level LPT fills min(devices, level width) bins, so the
            # widest level bounds reachable coverage — anything less means
            # the placement left reachable devices idle
            if m["devices_used"] != min(d, max_width):
                raise RuntimeError(
                    f"{name}: placement left devices idle at D={d} "
                    f"({m['devices_used']} of {min(d, max_width)} "
                    f"reachable)")
            rec[f"placement{d}_speedup"] = m["speedup"]
            rec[f"devices_used_d{d}"] = m["devices_used"]
        results[name] = rec
        rows.append([name, a.n, plan.n_supernodes, plan.n_levels,
                     f"{rec['placement2_speedup']:.2f}x",
                     f"{rec['placement8_speedup']:.2f}x"])
        if name == "bbd-8k":                   # measured, not only modeled
            mi = _measured_imbalance(plan, a)
            rec["measured_imbalance"] = mi
            rows.append(["bbd-8k measured (D=8)", a.n, "-",
                         mi["levels_measured"],
                         f"imb mean {mi['imbalance_mean']:.2f}",
                         f"max {mi['imbalance_max']:.2f}"])

    md = _multidevice_case()
    if not md["parity"]:
        raise RuntimeError(
            "distributed analyze diverged from the single-device reference "
            "on 8 forced host devices — the bitwise conformance contract "
            "is broken")
    results["multidevice-8"] = md
    rows.append(["multidevice-8 (real)", md["n"], "-", "-",
                 f"balance {md['balance_ratio']:.2f}",
                 f"parity {'OK' if md['parity'] else 'BROKEN'}"])

    print_table("Distributed plan: placement + 8-device parity",
                ["matrix", "|V|", "panels", "levels", "D=2", "D=8"], rows)
    save_artifact("bench_distributed", results)
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
