"""Structure-aware irregular blocking + roofline autotune (DESIGN.md §16).

T2/T3 relaxed detection leaves bbd-20k with 9372 supernodes at n = 20_000,
so the one-GEMM-per-panel sweep spends its time in per-panel dispatch
instead of math and runs far below the roofline the PR 6 probes measure.
The blocking merge pass coalesces near-miss adjacent structures into padded
dense blocks when the modeled flop/byte gain pays for the explicit zeros;
the autotune sweep picks the relax/max_size/merge knobs per matrix.

Gates (both hard, both baseline-ratio-gated via ``_speedup`` keys):

* ``blocking_fop_speedup`` — panel-GEMM fraction-of-peak (achieved
  bandwidth over the probed machine peak, from the sweep's analytic
  ``gemm.*`` counters) with blocking on must be **>= 1.2x** the unblocked
  plan's on bbd-20k;
* ``autotune_factorize_speedup`` — end-to-end ``plan.factorize`` with the
  autotuned plan must be **>= 1.0x** the default-knob plan (autotuning
  never loses).

One full ``analyze`` builds the default plan; the blocked and autotuned
variants come from ``repro.replan`` (fingerprint re-detection, no fixpoint
re-run), which is itself the feature's amortization story — and its
wall-clock is reported alongside.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import print_table, progress_cb, save_artifact, timeit
from benchmarks.roofline import machine_peaks
from repro.api import LUOptions, analyze, replan
from repro.obs.metrics import fraction_of_peak
from repro.sparse import bordered_block_diagonal
from repro.sparse.numeric import generic_values_csr

GATE_FOP_SPEEDUP = 1.2
GATE_AUTOTUNE_SPEEDUP = 1.0

LARGE_N = 20_000
LARGE_BLOCK = 16
LARGE_BORDER = 64


def _gemm_fraction(plan, values, peaks, repeats) -> dict:
    """Best-of-N panel-GEMM fraction-of-peak of one plan's sweep.

    The sweep's ``gemm.bytes`` are analytic (gather + GEMM operands +
    scatter per panel) and ``gemm.seconds`` is the measured sweep wall
    time, so achieved-bandwidth-over-peak is comparable across partitions
    of the same matrix; best-of-N for the same reason the speedup gates
    use ``reduce=min`` — load spikes only ever lower it.
    """
    from repro import obs

    best = None
    for _ in range(repeats):
        obs.registry().reset()
        with obs.tracing():
            plan.factorize(values)
        c = obs.registry().snapshot()["counters"]
        rep = fraction_of_peak(c["gemm.bytes"], c["gemm.seconds"], peaks,
                               flops=c["gemm.flops"])
        rep["gemm_bytes"] = c["gemm.bytes"]
        rep["gemm_seconds"] = c["gemm.seconds"]
        if best is None or rep["bw_fraction"] > best["bw_fraction"]:
            best = rep
    return best


def run(repeats: int = 3) -> dict:
    peaks = machine_peaks()
    a = bordered_block_diagonal(LARGE_N, block=LARGE_BLOCK,
                                border=LARGE_BORDER, seed=3)
    values = generic_values_csr(a)
    name = f"bbd-{LARGE_N // 1000}k"

    # one fixpoint, three partitions: default knobs, blocked, autotuned
    opts = LUOptions(concurrency=512)
    plan = analyze(a, opts, peaks=peaks,
                   on_progress=progress_cb(f"analyze {name}"))
    t0 = time.perf_counter()
    blocked = replan(plan, opts.replace(blocking=True), peaks=peaks)
    t_replan_block = time.perf_counter() - t0
    t0 = time.perf_counter()
    tuned = replan(plan, opts.replace(autotune=True), peaks=peaks)
    t_replan_tune = time.perf_counter() - t0

    # parity before any speedup is reported: blocked factors must solve to
    # the same answer as the unblocked ones (merging regroups float ops, so
    # the bound is accuracy, not bitwise).  Solve-based — the dense-oracle
    # factor comparison lives in tests/test_blocking.py at small n; at
    # n=20k densifying L/U would cost ~13 GB, more than a CI runner has.
    f_def = plan.factorize(values)
    rhs = np.random.default_rng(11).standard_normal(a.n)
    x_def = f_def.solve(rhs).x
    for variant, p in (("blocked", blocked), ("autotuned", tuned)):
        res = p.factorize(values).solve(rhs)
        err = (np.abs(res.x - x_def).max()
               / max(1e-300, np.abs(x_def).max()))
        if err > 1e-8 or res.residual > 1e-8:
            raise RuntimeError(
                f"{name}: {variant} solution diverged from the unblocked "
                f"plan (rel err {err:.2e}, residual {res.residual:.2e})")

    # panel-GEMM fraction of peak, unblocked vs blocked
    fop_def = _gemm_fraction(plan, values, peaks, repeats)
    fop_blk = _gemm_fraction(blocked, values, peaks, repeats)
    fop_speedup = fop_blk["bw_fraction"] / max(1e-12,
                                               fop_def["bw_fraction"])

    # end-to-end factorize, default knobs vs autotuned (best-of-N)
    t_def = timeit(lambda: plan.factorize(values), repeats=repeats,
                   warmup=0, reduce=min)
    t_tuned = timeit(lambda: tuned.factorize(values), repeats=repeats,
                     warmup=0, reduce=min)
    autotune_speedup = t_def / t_tuned

    results = {
        name: {
            "n": a.n, "nnz": a.nnz, "lu_nnz": plan.lu_nnz,
            "analyze_s": plan.analyze_s,
            "replan_blocked_s": t_replan_block,
            "replan_autotuned_s": t_replan_tune,
            "panels_default": plan.n_supernodes,
            "panels_blocked": blocked.n_supernodes,
            "panels_autotuned": tuned.n_supernodes,
            "pad_entries_blocked": blocked.store_template.pad_entries,
            "tuned_chosen": tuned.tuned.chosen,
            "tuned_modeled_s": tuned.tuned.modeled_s,
            "tuned_baseline_modeled_s": tuned.tuned.baseline_s,
            "fop_default": fop_def,
            "fop_blocked": fop_blk,
            "blocking_fop_speedup": fop_speedup,
            "t_factorize_default_s": t_def,
            "t_factorize_autotuned_s": t_tuned,
            "autotune_factorize_speedup": autotune_speedup,
        }
    }
    print_table(
        "Structure-aware blocking + autotune (bbd-20k)",
        ["partition", "panels", "gemm fop", "factorize", "vs default"],
        [["default", plan.n_supernodes,
          f"{fop_def['bw_fraction']:.1%}", f"{t_def*1e3:.0f}ms", "1.0x"],
         ["blocked", blocked.n_supernodes,
          f"{fop_blk['bw_fraction']:.1%}", "-",
          f"{fop_speedup:.2f}x fop"],
         ["autotuned", tuned.n_supernodes, "-",
          f"{t_tuned*1e3:.0f}ms", f"{autotune_speedup:.2f}x"]])
    save_artifact("bench_blocking", results)
    if fop_speedup < GATE_FOP_SPEEDUP:
        raise RuntimeError(
            f"{name}: blocked panel-GEMM fraction-of-peak speedup "
            f"{fop_speedup:.2f}x below the {GATE_FOP_SPEEDUP:.1f}x gate "
            f"({fop_def['bw_fraction']:.2%} -> "
            f"{fop_blk['bw_fraction']:.2%})")
    if autotune_speedup < GATE_AUTOTUNE_SPEEDUP:
        raise RuntimeError(
            f"{name}: autotuned factorize {autotune_speedup:.2f}x vs the "
            f"default knobs — autotune must never lose "
            f"(gate {GATE_AUTOTUNE_SPEEDUP:.1f}x)")
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
