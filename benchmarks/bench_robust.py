"""Robust tier: static-pivoting rescue + perturbation overhead + quality
certificates (DESIGN.md §15).

Three gated claims of the numerical robustness subsystem:

* **Rescue** — every hostile generator (``indefinite``,
  ``shuffled_dominant``: exact-zero pivots the pivot-free seed path dies
  on) must raise ``ZeroPivotError`` without the robust tier, and must
  factor + solve to relative residual **<= 1e-8** with
  ``LUOptions(pivot="static", perturb=True)``.  Never report a rescue for
  a wrong answer: the residual is checked before any timing is recorded.
* **Perturbation overhead** — a ``perturb=True`` factorization on a
  well-conditioned system (where the guard never fires) must cost **<=
  10%** over the plain sweep: the tiny-pivot check is a per-panel scalar
  compare, not a new pass.
* **Quality certificate** — ``factor.quality()`` must return finite
  estimates with verdict "ok" on the dominant system and flag the
  perturbed factorization "suspect" (certificates that wave garbage
  through are worse than none).

Also reported (not gated): the analyze-time prepass cost relative to the
symbolic analysis it rides on, and per-generator condition estimates.

Exits nonzero (via run.py) if any gate fails.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import print_table, save_artifact, timeit
from repro.api import LUOptions, analyze
from repro.sparse import (
    banded_random, indefinite, indefinite_values_csr, permute_csr, rcm_order,
    shuffled_dominant, shuffled_dominant_values_csr,
)
from repro.sparse.numeric import ZeroPivotError, csr_matvec, generic_values_csr

RESIDUAL_GATE = 1e-8         # rescue: relative residual after refinement
PERTURB_OVERHEAD_GATE = 0.10  # perturb=True factorize cost over plain

PLAIN = LUOptions(concurrency=64, supernode_relax=2)
ROBUST = LUOptions(concurrency=64, supernode_relax=2,
                   pivot="static", perturb=True)

#: hostile systems the seed path cannot factor (exact zero pivots)
HOSTILE = {
    "indefinite": lambda n: (
        lambda a: (a, indefinite_values_csr(a, seed=1)))(
            indefinite(n, band=6, seed=1)),
    "shuffled": lambda n: (
        lambda a: (a, shuffled_dominant_values_csr(a, band=6, seed=2)))(
            shuffled_dominant(n, band=6, seed=2)),
}
RESCUE_N = 400


def _rescue_case() -> dict:
    """Hostile generators: seed path raises, robust tier solves."""
    out = {}
    rng = np.random.default_rng(0)
    for name, make in HOSTILE.items():
        a, vals = make(RESCUE_N)
        try:
            analyze(a, PLAIN).factorize(vals)
            raise RuntimeError(
                f"{name}: seed path factored a hostile matrix — the "
                f"generator no longer exercises the rescue")
        except ZeroPivotError:
            pass
        t0 = time.perf_counter()
        plan = analyze(a, ROBUST, values=vals)
        t_analyze = time.perf_counter() - t0
        t0 = time.perf_counter()
        factor = plan.factorize(vals)
        t_factor = time.perf_counter() - t0
        b = rng.standard_normal(a.n)
        res = factor.solve(b)
        rel = (np.linalg.norm(csr_matvec(a, vals, res.x) - b)
               / np.linalg.norm(b))
        if rel > RESIDUAL_GATE:
            raise RuntimeError(
                f"{name}: robust residual {rel:.2e} above "
                f"{RESIDUAL_GATE:.0e} — rescue failed")
        q = factor.quality()
        if q.verdict == "reject":
            raise RuntimeError(
                f"{name}: quality verdict 'reject' on a rescued system "
                f"(cond {q.cond_1_est:.2e}, growth {q.growth:.2e})")
        out[name] = {
            "n": a.n, "nnz": a.nnz,
            "t_analyze_s": t_analyze, "t_factorize_s": t_factor,
            "residual": rel,
            "perturbed_pivots": int(factor.perturbed_pivots),
            "cond_1_est": q.cond_1_est, "growth": q.growth,
            "verdict": q.verdict,
        }
    return out


def _overhead_case(repeats: int) -> dict:
    """perturb=True on a dominant system: the guard never fires, so the
    factorize cost over the plain sweep is pure check overhead."""
    a = banded_random(600, band=8, seed=4)
    a = permute_csr(a, rcm_order(a))
    vals = generic_values_csr(a)
    plan_plain = analyze(a, PLAIN)
    plan_perturb = analyze(a, LUOptions(concurrency=64, supernode_relax=2,
                                        perturb=True))
    f_perturb = plan_perturb.factorize(vals)       # warmup + sanity
    if f_perturb.perturbed_pivots != 0:
        raise RuntimeError("dominant system perturbed a pivot — the "
                           "overhead case is no longer measuring a cold "
                           "guard")
    ls, us = plan_plain.factorize(vals).num.store.dense_lu()
    lp, up = f_perturb.num.store.dense_lu()
    if not (np.array_equal(ls, lp) and np.array_equal(us, up)):
        raise RuntimeError("perturb=True changed factors on a system it "
                           "never touched — bitwise parity broken")
    t_plain = timeit(lambda: plan_plain.factorize(vals), repeats=repeats,
                     warmup=1, reduce=min)
    t_perturb = timeit(lambda: plan_perturb.factorize(vals),
                       repeats=repeats, warmup=1, reduce=min)
    overhead = t_perturb / t_plain - 1.0
    if overhead > PERTURB_OVERHEAD_GATE:
        raise RuntimeError(
            f"perturbation guard costs {overhead:.1%} over the plain "
            f"sweep (gate {PERTURB_OVERHEAD_GATE:.0%})")
    return {
        "n": a.n, "nnz": a.nnz,
        "t_factorize_plain_s": t_plain,
        "t_factorize_perturb_s": t_perturb,
        "overhead_frac": overhead,
        # ratio-gated by the committed baseline (floor at tolerance):
        # plain/perturb — 1.0 means the guard is free
        "perturb_parity_speedup": t_plain / t_perturb,
    }


def _quality_case() -> dict:
    """Certificates: "ok" on the dominant system, "suspect" once a pivot
    was bumped, estimates finite both ways."""
    a = banded_random(300, band=6, seed=9)
    vals = generic_values_csr(a, seed=9)
    factor = analyze(a, PLAIN).factorize(vals)

    def _cold_quality():
        factor._quality = None        # defeat the cache: time the estimate
        return factor.quality(itmax=5)

    t_quality = timeit(_cold_quality, repeats=3, warmup=1, reduce=min)
    q_ok = factor.quality()
    if not (q_ok.verdict == "ok" and np.isfinite(q_ok.cond_1_est)
            and np.isfinite(q_ok.growth)):
        raise RuntimeError(f"dominant system certified {q_ok.verdict} "
                           f"(cond {q_ok.cond_1_est:.2e})")
    # exact zero in the first pivot: perturbation fires, verdict degrades
    rows = np.repeat(np.arange(a.n), np.diff(a.indptr))
    slot = int(np.flatnonzero((rows == 0) & (a.indices == 0))[0])
    bad = vals.copy()
    bad[slot] = 0.0
    f_bad = analyze(a, LUOptions(concurrency=64, supernode_relax=2,
                                 perturb=True)).factorize(bad)
    q_bad = f_bad.quality()
    if f_bad.perturbed_pivots < 1 or q_bad.verdict == "ok":
        raise RuntimeError(
            f"perturbed factorization certified '{q_bad.verdict}' with "
            f"{f_bad.perturbed_pivots} bumps — suspect gating broken")
    return {
        "n": a.n, "nnz": a.nnz, "t_quality_s": t_quality,
        "ok_cond_1_est": q_ok.cond_1_est, "ok_growth": q_ok.growth,
        "ok_verdict": q_ok.verdict,
        "perturbed_pivots": int(f_bad.perturbed_pivots),
        "perturbed_verdict": q_bad.verdict,
    }


def run(repeats: int = 3) -> dict:
    results = {
        "rescue": _rescue_case(),
        "overhead": _overhead_case(repeats),
        "quality": _quality_case(),
    }
    o, q = results["overhead"], results["quality"]
    rows = []
    for name, r in results["rescue"].items():
        rows.append([f"rescue {name}", r["n"], f"{r['residual']:.1e}",
                     f"cond {r['cond_1_est']:.1e}", r["verdict"]])
    rows.append(["perturb overhead", o["n"],
                 f"{o['t_factorize_perturb_s']*1e3:.0f}ms vs "
                 f"{o['t_factorize_plain_s']*1e3:.0f}ms",
                 f"{o['overhead_frac']:+.1%}",
                 f"gate {PERTURB_OVERHEAD_GATE:.0%}"])
    rows.append(["quality certificate", q["n"],
                 f"{q['t_quality_s']*1e3:.1f}ms",
                 f"cond {q['ok_cond_1_est']:.1e}",
                 f"{q['ok_verdict']} / {q['perturbed_verdict']}"])
    print_table("Robust tier: static pivoting + perturbation + quality",
                ["case", "n", "measure", "detail", "result"], rows)
    save_artifact("bench_robust", results)
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
