"""Machine roofline peaks: probed once, cached, consumed by ``repro.obs``.

This is the peak-probe half of the fraction-of-peak computation
(DESIGN.md §12): ``repro.obs.metrics`` owns the pure math
(``fraction_of_peak(bytes, seconds, peaks)``); this module owns the
hardware numbers — probed on the machine the benchmarks actually run on,
never read from a spec sheet, because the achieved-fraction claim (the
repo's analogue of GSoFa's 47%-of-V100-peak memory throughput) is only
meaningful against what *this* host can sustain:

* **memory bandwidth** — a STREAM-style triad ``a = b + s * c`` over
  arrays far larger than LLC, best-of-N (3 arrays * 8 bytes moved per
  element per iteration);
* **compute** — float64 DGEMM throughput via ``numpy.dot`` on a square
  operand, best-of-N (2 * m^3 flops).

Peaks are cached to ``artifacts/machine_peaks.json`` so a full bench run
probes once; delete the file (or pass ``force=True``) after a hardware
change.  Bench scripts call ``machine_peaks()`` and hand the dict to
``repro.obs.roofline_report`` together with the byte/second counters the
traced pipeline recorded (``fingerprint.bytes``/``gemm.bytes``/...).

The earlier LM dry-run roofline reader that lived here (TPU v5e spec
constants against ``launch/dryrun.py`` artifacts) was retired when the
repo's focus narrowed to the LU pipeline — see ROADMAP "Recent".
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional

import numpy as np

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")
CACHE_PATH = os.path.join(ARTIFACTS, "machine_peaks.json")

# triad arrays sized to defeat any plausible LLC (3 * 32 MiB of float64)
_TRIAD_ELEMS = 4 * 1024 * 1024
_GEMM_M = 768


def _probe_triad(repeats: int = 5) -> float:
    """Sustained memory bandwidth in GB/s (STREAM triad, best-of-N)."""
    b = np.ones(_TRIAD_ELEMS, dtype=np.float64)
    c = np.full(_TRIAD_ELEMS, 0.5, dtype=np.float64)
    a = np.empty_like(b)
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        np.multiply(c, 3.0, out=a)
        a += b
        dt = time.perf_counter() - t0
        # a written + read (the += round-trip), b and c read once each
        nbytes = 4 * _TRIAD_ELEMS * 8
        best = max(best, nbytes / dt / 1e9)
    return best


def _probe_gemm(repeats: int = 5) -> float:
    """Sustained float64 GEMM throughput in GFLOP/s (best-of-N)."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((_GEMM_M, _GEMM_M))
    y = rng.standard_normal((_GEMM_M, _GEMM_M))
    x @ y                                   # warm the BLAS thread pool
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        x @ y
        dt = time.perf_counter() - t0
        best = max(best, 2.0 * _GEMM_M ** 3 / dt / 1e9)
    return best


def machine_peaks(cache_path: Optional[str] = CACHE_PATH, *,
                  force: bool = False) -> Dict:
    """{"mem_bw_gbs", "flops_gflops", ...} for this host — cached.

    ``cache_path=None`` probes without touching disk (tests).
    """
    if cache_path and not force and os.path.exists(cache_path):
        try:
            with open(cache_path) as f:
                peaks = json.load(f)
            if "mem_bw_gbs" in peaks and "flops_gflops" in peaks:
                return peaks
        except (json.JSONDecodeError, OSError):
            pass                            # stale/corrupt cache: re-probe
    peaks = {
        "mem_bw_gbs": _probe_triad(),
        "flops_gflops": _probe_gemm(),
        "probe": {
            "triad_mib": _TRIAD_ELEMS * 8 * 3 / 2 ** 20,
            "gemm_m": _GEMM_M,
            "dtype": "float64",
        },
        "probed_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }
    if cache_path:
        os.makedirs(os.path.dirname(cache_path), exist_ok=True)
        with open(cache_path, "w") as f:
            json.dump(peaks, f, indent=1)
    return peaks


def main() -> None:
    peaks = machine_peaks(force=True)
    print(f"machine peaks (probed, cached to {os.path.relpath(CACHE_PATH)}):")
    print(f"  memory bandwidth : {peaks['mem_bw_gbs']:8.2f} GB/s  "
          f"(STREAM triad, {peaks['probe']['triad_mib']:.0f} MiB working set)")
    print(f"  float64 compute  : {peaks['flops_gflops']:8.2f} GFLOP/s "
          f"(DGEMM m={peaks['probe']['gemm_m']})")


if __name__ == "__main__":
    main()
