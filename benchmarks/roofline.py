"""Roofline analysis (§g): three terms per (arch x shape x mesh) cell.

Reads the dry-run artifacts (launch/dryrun.py) and derives, per device:

  compute term     = HLO_FLOPs_per_device / peak_FLOP/s
  memory term      = HBM_traffic_per_device / HBM_bw
  collective term  = collective_bytes_per_device / link_bw

HLO_FLOPs come from the compositional cost extraction (exact; scan bodies
multiplied — see launch/costs.py).  Collective bytes are parsed from the
partitioned HLO (per-device result shapes).  HBM traffic uses an *analytic
minimum-traffic model* (below) because XLA:CPU's "bytes accessed" counts
every instruction operand without fusion dedup (~5x inflated, measured) and
the jnp attention path round-trips score matrices that the Pallas kernels
keep in VMEM on the real target; both raw numbers are reported alongside.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI.  Per-device collective bytes / link_bw equals the assignment's
collective_bytes_global / (chips x link_bw).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def _clamped_micro(cfg, shape) -> int:
    micro = max(1, cfg.micro_steps) if shape.kind == "train" else 1
    while shape.global_batch % micro:
        micro //= 2
    return max(1, micro)


def analytic_hbm_traffic(cfg, shape, rec: Dict) -> float:
    """Per-device HBM bytes for one step — minimum-traffic model.

    Assumes the Pallas kernels for attention (scores stay in VMEM, K/V
    stream once per query block) and the SSM scans (state resident in
    VMEM); weights are read once per forward/backward pass; remat re-reads
    them once more; optimizer states stream once.
    """
    sb = rec.get("state_bytes_per_device", {})
    p = sb.get("params", 0.0)
    o = sb.get("opt", 0.0)
    caches = sb.get("caches", 0.0)
    n_batch_shards = 16 if rec["mesh"] == "pod" else 32
    if shape.global_batch % n_batch_shards:
        n_batch_shards = 1
    d = cfg.d_model
    micro = _clamped_micro(cfg, shape)
    tokens_loc = shape.global_batch * shape.seq_len / n_batch_shards
    tok_m = tokens_loc / micro
    q_chunk = 1024

    n_attn = sum(1 for m, _ in cfg.full_pattern if m in ("attn", "local")) * cfg.n_groups
    n_local = sum(1 for m, _ in cfg.full_pattern if m == "local") * cfg.n_groups
    n_mla = sum(1 for m, _ in cfg.full_pattern if m == "mla") * cfg.n_groups
    n_moe = sum(1 for _, f in cfg.full_pattern if f == "moe") * cfg.n_groups
    kv_w = 2 * cfg.n_kv_heads * cfg.hd * 2                      # k+v bytes/token
    lat_w = (cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim) * 2 if cfg.mla else 0

    if shape.kind == "train":
        s = shape.seq_len
        t = 0.0
        t += micro * 3 * p                     # param reads: fwd + remat + bwd
        t += micro * 4 * p                     # f32 grad-accum buffer r/w
        t += 2 * o + p                         # optimizer stream + param write
        stash = cfg.n_groups * tok_m * d * 2
        t += micro * 2 * stash                 # remat stash w+r
        # attention K/V streaming (batch rows per device = tok_m / s)
        rows = max(1.0, tok_m / s)
        t += micro * n_attn * rows * (s / q_chunk) * s * kv_w * 0.5   # causal half
        if n_local:
            t -= micro * n_local * rows * (s / q_chunk) * max(0, s - cfg.sliding_window - q_chunk) * kv_w * 0.5
        t += micro * n_mla * rows * (s / q_chunk) * s * lat_w * 0.5
        if cfg.moe:
            disp = tok_m * cfg.moe.top_k * cfg.moe.capacity_factor * d * 2 / 16
            t += micro * 4 * n_moe * disp
        # chunked CE logits r/w (f32, vocab model-sharded 16-way when divisible)
        v_loc = cfg.vocab / (16 if cfg.vocab % 16 == 0 else 1)
        t += micro * 2 * tok_m * v_loc * 4
        t += micro * 3 * tok_m * d * 2         # embed fwd + bwd scatter
        t *= 2.0                               # bwd activation traffic ~ fwd
        return t

    if shape.kind == "prefill":
        s = shape.seq_len
        rows = max(1.0, tokens_loc / s)
        t = p
        n_layers = len(cfg.full_pattern) * cfg.n_groups
        t += n_layers * 4 * tokens_loc * d * 2          # layer activations r/w
        t += n_attn * rows * (s / q_chunk) * s * kv_w * 0.5
        if n_local:
            t -= n_local * rows * (s / q_chunk) * max(0, s - cfg.sliding_window - q_chunk) * kv_w * 0.5
        t += n_mla * rows * (s / q_chunk) * s * lat_w * 0.5
        t += caches                                     # cache write
        if cfg.moe:
            t += 4 * n_moe * tokens_loc * cfg.moe.top_k * cfg.moe.capacity_factor * d * 2 / 16
        return t

    # decode: params read (all resident experts in the dense-EP impl),
    # full cache read + slot write, small activations
    return p + caches + 64 * d * 2 * len(cfg.full_pattern) * cfg.n_groups


def model_flops(cfg, shape) -> float:
    """Global MODEL_FLOPS: 6*N_active*D (train) / 2*N_active*D (inference),
    plus the attention score/value matmuls (2*2*T_ctx*d_attn per token per
    attention layer, causal-halved), which 6ND ignores and which dominate at
    32k+ context."""
    n = cfg.active_param_count()
    d_attn = cfg.n_heads * cfg.hd
    s = shape.seq_len
    per_layer_ctx = {"attn": s, "local": min(s, cfg.sliding_window),
                     "mla": s}
    if shape.kind == "decode":
        toks = shape.global_batch
        attn = sum(4.0 * per_layer_ctx[m] * d_attn
                   for m, _ in cfg.full_pattern if m in per_layer_ctx
                   ) * cfg.n_groups * toks
        return 2.0 * n * toks + attn
    toks = shape.global_batch * s
    attn = sum(4.0 * per_layer_ctx[m] * 0.5 * d_attn
               for m, _ in cfg.full_pattern if m in per_layer_ctx
               ) * cfg.n_groups * toks
    mult = 3.0 if shape.kind == "train" else 1.0
    base = (6.0 if shape.kind == "train" else 2.0) * n * toks
    return base + mult * attn


def suggest(dom: str, cfg, shape, frac: float) -> str:
    if dom == "collective":
        return ("shrink/overlap the TP all-gathers (fuse collectives with the "
                "following matmul, or move FSDP gathers off the critical path)")
    if dom == "memory":
        if shape.kind == "decode":
            return ("decode is cache/weight-bandwidth bound: shard the cache "
                    "over more axes or batch more requests per chip")
        return ("cut optimizer/stash traffic: fewer micro-steps, bf16 opt "
                "states, or offload the master copy")
    if frac < 0.2:
        return ("compute-bound but far off peak: the model axis does "
                "redundant work for this arch — reshard batch over "
                "(data x model) or shrink TP")
    return "compute-bound near peak: increase per-chip batch or fuse pointwise ops"


def analyze_record(rec: Dict) -> Optional[Dict]:
    from repro.configs.base import SHAPES, get_config
    if "error" in rec or "skipped" in rec or rec.get("arch") == "gsofa":
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    costs = rec.get("costs")
    if costs:
        fl = costs["totals_per_device"]["flops"]
        coll = costs["totals_per_device"]["collective_bytes"]
        xla_bytes = costs["totals_per_device"]["hbm_bytes"]
    else:
        fl = rec["full_step"]["flops"]
        coll = rec["full_step"]["collectives"]["total_bytes"]
        xla_bytes = rec["full_step"]["hbm_bytes"]
    mem_bytes = analytic_hbm_traffic(cfg, shape, rec)
    t_c = fl / PEAK_FLOPS
    t_m = mem_bytes / HBM_BW
    t_l = coll / LINK_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_l)),
              key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    n_dev = rec["n_devices"]
    useful = mf / max(1.0, fl * n_dev)
    step_time = max(t_c, t_m, t_l)           # perfect-overlap bound
    mfu = mf / max(1e-9, step_time) / (n_dev * PEAK_FLOPS)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
        "dominant": dom, "model_flops": mf, "hlo_flops_per_dev": fl,
        "useful_flop_ratio": useful, "roofline_mfu": mfu,
        "mem_bytes_analytic": mem_bytes, "mem_bytes_xla": xla_bytes,
        "coll_bytes_per_dev": coll,
        "fits_hbm_16g": rec["memory"]["peak_bytes_est"] < 16e9,
        "peak_bytes": rec["memory"]["peak_bytes_est"],
        "suggestion": suggest(dom, cfg, shape, mfu),
    }


def load_all(mesh: str = "pod") -> Dict[str, Dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}.json"))):
        rec = json.load(open(path))
        r = analyze_record(rec)
        if r:
            out[f"{r['arch']}__{r['shape']}"] = r
    return out


def main() -> None:
    rows = load_all("pod")
    if not rows:
        print("no dry-run artifacts found — run: python -m repro.launch.dryrun --sweep")
        return
    hdr = ["cell", "compute_s", "memory_s", "collective_s", "dominant",
           "MFU-bound", "useful/HLO", "fits16G"]
    print("| " + " | ".join(hdr) + " |")
    print("|" + "|".join(["---"] * len(hdr)) + "|")
    for key, r in sorted(rows.items()):
        print(f"| {key} | {r['compute_s']:.3f} | {r['memory_s']:.3f} | "
              f"{r['collective_s']:.3f} | {r['dominant']} | "
              f"{r['roofline_mfu']*100:.1f}% | {r['useful_flop_ratio']*100:.1f}% | "
              f"{'Y' if r['fits_hbm_16g'] else 'N'} |")
    with open(os.path.join(os.path.dirname(ART_DIR), "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
