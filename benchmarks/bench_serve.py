"""Serving tier: plan-cache hit rate + batched-vs-loop throughput
(DESIGN.md §14).

Three gated claims of the many-matrix batched solver service:

* **Plan cache** — a ``SolverEngine.plan_for`` hit (content-hash probe into
  the fingerprint-keyed LRU) must be **>= 100x** faster than the cold
  analyze a miss pays.  The reported ratio is clamped at 500x so the
  committed-baseline gate stays stable (the raw ratio is thousands and
  swings with analyze wall time across machines; the raw value is reported
  unclamped as ``cache_hit_ratio_raw``).
* **Batched solve** — ``solve_batch`` at B = 64 must be **>= 3x** faster
  than the sequential ``factor.solve`` loop over the same factors, with
  factors and solutions **bitwise-identical** per system (asserted before
  any speedup is reported — never report a speedup for wrong answers).
* **Engine end-to-end** — a mixed request stream through
  ``submit``/``flush`` must return residuals at machine precision and
  match the sequential session API bitwise on a spot-checked request.

Also reported (not gated): solves/s at B in {1, 64, 1024} (the occupancy
sweep — B = 1024 runs on a smaller matrix to keep CI memory/time bounded)
and the batched factorize gain at B = 64.

Exits nonzero (via run.py) if any gate fails.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import print_table, save_artifact, timeit
from repro.api import LUOptions, analyze
from repro.serve import SolverEngine
from repro.sparse import circuit_like, permute_csr, rcm_order
from repro.sparse.numeric import generic_values_csr

CACHE_HIT_GATE = 100.0       # plan_for hit vs cold analyze
CACHE_HIT_CLAMP = 500.0      # reported ratio cap (baseline stability)
BATCH_SOLVE_GATE = 3.0       # solve_batch @ B=64 vs sequential loop
RESIDUAL_GATE = 1e-10

OPTS = LUOptions(concurrency=64, supernode_relax=2)
GATE_N = 240                 # matrix for the B=64 conformance + speedup gate
SWEEP = ((1, 240), (64, 240), (1024, 120))   # (B, n) occupancy sweep


def _matrix(n: int, seed: int = 7):
    a = circuit_like(n, seed=seed)
    return permute_csr(a, rcm_order(a))


def _values(a, count: int) -> np.ndarray:
    return np.stack([generic_values_csr(a, seed=s % 17)
                     for s in range(count)])


def _cache_case() -> dict:
    """plan_for miss (cold analyze) vs hit (fingerprint probe)."""
    a = _matrix(GATE_N)
    eng = SolverEngine(OPTS, capacity=4)
    t0 = time.perf_counter()
    eng.plan_for(a)                               # cold: analyze + insert
    t_miss = time.perf_counter() - t0
    t_hit = timeit(lambda: eng.plan_for(a), repeats=20, warmup=2,
                   reduce=min)
    raw = t_miss / t_hit
    if raw < CACHE_HIT_GATE:
        raise RuntimeError(
            f"plan-cache hit only {raw:.1f}x faster than cold analyze "
            f"(gate {CACHE_HIT_GATE:.0f}x)")
    return {
        "n": a.n, "nnz": a.nnz,
        "t_analyze_miss_s": t_miss, "t_cache_hit_s": t_hit,
        "cache_hit_speedup": min(raw, CACHE_HIT_CLAMP),
        "cache_hit_ratio_raw": raw,
        "cache_hits": int(eng.stats["cache_hits"]),
        "cache_misses": int(eng.stats["cache_misses"]),
    }


def _batch_case(repeats: int) -> dict:
    """B=64 bitwise conformance + batched-vs-loop speedups."""
    bsz = 64
    a = _matrix(GATE_N)
    plan = analyze(a, OPTS)
    vb = _values(a, bsz)
    rhs = np.random.default_rng(0).standard_normal((bsz, a.n))

    bf = plan.factorize_batch(vb)                  # warmup + parity ref
    seq = [plan.factorize(vb[i]) for i in range(bsz)]
    # never report a speedup for wrong answers: every system's factors and
    # solution must be bitwise-identical to the sequential session API
    for i in range(bsz):
        for j, blk in enumerate(seq[i].store.blocks):
            if not np.array_equal(blk, bf.store.blocks[j][i]):
                raise RuntimeError(
                    f"factorize_batch diverged from plan.factorize at "
                    f"system {i}, panel {j}")
    solved = bf.solve_batch(rhs)
    for i in range(bsz):
        s = seq[i].solve(rhs[i])
        if not np.array_equal(s.x, solved.x[i]):
            raise RuntimeError(
                f"solve_batch diverged from factor.solve at system {i}")
        if s.residuals != solved.residuals[i]:
            raise RuntimeError(
                f"solve_batch refinement history diverged at system {i}")
    if float(solved.residual.max()) > RESIDUAL_GATE:
        raise RuntimeError(
            f"batched residual {float(solved.residual.max()):.2e} above "
            f"{RESIDUAL_GATE:.0e}")

    t_batch_f = timeit(lambda: plan.factorize_batch(vb), repeats=repeats,
                       warmup=0, reduce=min)
    t_loop_f = timeit(lambda: [plan.factorize(vb[i]) for i in range(bsz)],
                      repeats=repeats, warmup=0, reduce=min)
    t_batch_s = timeit(lambda: bf.solve_batch(rhs), repeats=repeats,
                       warmup=0, reduce=min)
    t_loop_s = timeit(lambda: [seq[i].solve(rhs[i]) for i in range(bsz)],
                      repeats=repeats, warmup=0, reduce=min)
    solve_speedup = t_loop_s / t_batch_s
    if solve_speedup < BATCH_SOLVE_GATE:
        raise RuntimeError(
            f"batched solve at B={bsz} only {solve_speedup:.2f}x the "
            f"sequential loop (gate {BATCH_SOLVE_GATE:.0f}x)")
    return {
        "n": a.n, "nnz": a.nnz, "batch": bsz,
        "t_factorize_batch_s": t_batch_f, "t_factorize_loop_s": t_loop_f,
        "t_solve_batch_s": t_batch_s, "t_solve_loop_s": t_loop_s,
        "batch_solve_speedup": solve_speedup,
        # reported, not baseline-gated (no _speedup suffix on purpose: the
        # factorize win is Python-overhead amortization and machine-bound)
        "batch_factorize_gain": t_loop_f / t_batch_f,
    }


def _sweep_case() -> dict:
    """solves/s at B in {1, 64, 1024} (B=1024 on a smaller matrix)."""
    out = {}
    for bsz, n in SWEEP:
        a = _matrix(n)
        plan = analyze(a, OPTS)
        vb = _values(a, bsz)
        rhs = np.random.default_rng(0).standard_normal((bsz, a.n))
        t0 = time.perf_counter()
        bf = plan.factorize_batch(vb)
        t_f = time.perf_counter() - t0
        t0 = time.perf_counter()
        solved = bf.solve_batch(rhs)
        t_s = time.perf_counter() - t0
        if float(solved.residual.max()) > RESIDUAL_GATE:
            raise RuntimeError(
                f"B={bsz} residual {float(solved.residual.max()):.2e} "
                f"above {RESIDUAL_GATE:.0e}")
        out[f"b{bsz}"] = {
            "n": n, "batch": bsz,
            "t_factorize_s": t_f, "t_solve_s": t_s,
            "factorizes_per_s": bsz / t_f,
            "solves_per_s": bsz / t_s,
            "store_mb": bf.store.nbytes / 1e6,
        }
    return out


def _engine_case() -> dict:
    """Mixed request stream through submit/flush: two patterns, fixed
    slots, per-request answers matching the session API."""
    mats = [_matrix(GATE_N, seed=100 + p) for p in range(2)]
    eng = SolverEngine(OPTS, capacity=4, batch_slots=8)
    rng = np.random.default_rng(1)
    reqs = []
    for r in range(24):
        a = mats[r % 2]
        vals = generic_values_csr(a, seed=r)
        rhs = rng.standard_normal(a.n)
        reqs.append((eng.submit(a, vals, rhs), a, vals, rhs))
    t0 = time.perf_counter()
    results = eng.flush()
    elapsed = time.perf_counter() - t0
    worst = max(r.residual for r in results)
    if worst > RESIDUAL_GATE:
        raise RuntimeError(f"engine residual {worst:.2e} above "
                           f"{RESIDUAL_GATE:.0e}")
    rid, a, vals, rhs = reqs[0]
    seq = analyze(a, OPTS).factorize(vals).solve(rhs)
    r0 = next(r for r in results if r.rid == rid)
    if not np.array_equal(seq.x, r0.x):
        raise RuntimeError("engine answer diverged from the session API")
    s = eng.stats
    return {
        "requests": len(results), "t_flush_s": elapsed,
        "requests_per_s": len(results) / elapsed,
        "batches": int(s["batches"]),
        "padded_slots": int(s["padded_slots"]),
        "cache_misses": int(s["cache_misses"]),
        "worst_residual": worst,
    }


def run(repeats: int = 3) -> dict:
    results = {
        "cache": _cache_case(),
        "batch64": _batch_case(repeats),
        "sweep": _sweep_case(),
        "engine": _engine_case(),
    }
    c, b, e = results["cache"], results["batch64"], results["engine"]
    rows = [
        ["cache hit vs analyze", c["n"], "-",
         f"{c['t_cache_hit_s']*1e6:.0f}us vs {c['t_analyze_miss_s']:.2f}s",
         f"{c['cache_hit_ratio_raw']:.0f}x"],
        [f"solve B={b['batch']}", b["n"], b["batch"],
         f"{b['t_solve_batch_s']*1e3:.1f}ms vs "
         f"{b['t_solve_loop_s']*1e3:.1f}ms",
         f"{b['batch_solve_speedup']:.2f}x"],
        [f"factorize B={b['batch']}", b["n"], b["batch"],
         f"{b['t_factorize_batch_s']*1e3:.0f}ms vs "
         f"{b['t_factorize_loop_s']*1e3:.0f}ms",
         f"{b['batch_factorize_gain']:.2f}x"],
    ]
    for key, r in results["sweep"].items():
        rows.append([f"sweep {key}", r["n"], r["batch"],
                     f"{r['solves_per_s']:.0f} solves/s",
                     f"{r['store_mb']:.0f}MB"])
    rows.append(["engine stream", GATE_N, e["requests"],
                 f"{e['requests_per_s']:.0f} req/s",
                 f"{e['batches']} dispatches"])
    print_table("Serving tier: plan cache + batched dispatch",
                ["case", "n", "B", "measure", "result"], rows)
    save_artifact("bench_serve", results)
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
