"""Paper §VI / Figs 13-14-16 / Tables II-III: space management.

* Table II / Fig 16 analogue — auxiliary-structure bytes vs matrix bytes per
  #C (the paper reports ratios up to 4222:1, which is what motivates the
  whole section).
* Fig 13 analogue — dynamic arena (window-trick label reuse) on/off.
* Fig 14 analogue — performance under a shrinking memory envelope: the
  budget auto-reduces #C (the paper's final fallback) and runtime degrades
  gracefully rather than failing.
* Table III analogue is structural in our adaptation (dense frontiers have
  no queue-usage dynamics); the corresponding measurement is the bubble-
  removal width saving (chunked label truncation).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import load_datasets, print_table, save_artifact, timeit
from repro.core.gsofa import prepare_graph
from repro.core.multisource import plan_chunks, run_multisource
from repro.core.spaceopt import aux_memory_report, bytes_per_source
from repro.core.symbolic import symbolic_factorize


def run(codes=("G3", "HM", "PR", "TT"), concurrency: int = 256) -> dict:
    results = {}
    aux_rows, env_rows = [], []
    for code, a in load_datasets(codes).items():
        graph = prepare_graph(a)
        rep = aux_memory_report(graph, concurrency)

        # Fig 13: arena (window trick) on/off
        t_arena = timeit(lambda: run_multisource(graph, concurrency=concurrency,
                                                 use_arena=True), repeats=1)
        t_noarena = timeit(lambda: run_multisource(graph, concurrency=concurrency,
                                                   use_arena=False), repeats=1)
        ms = run_multisource(graph, concurrency=concurrency, use_arena=True)

        # bubble removal width saving
        chunks = plan_chunks(a.n, concurrency, bubble=True)
        width_frac = float(np.mean([c.width for c in chunks]) / a.n)

        # Fig 14: shrinking memory envelope -> auto-#C -> runtime
        full_bytes = bytes_per_source(graph) * concurrency
        envelope = {}
        for frac in (1.0, 0.5, 0.3, 0.1):
            budget = int(full_bytes * frac) + graph.in_ell.size * 8 + 1
            res = symbolic_factorize(a, concurrency=concurrency,
                                     budget_bytes=budget, graph=graph)
            envelope[frac] = {"eff_c": res.concurrency,
                              "elapsed_s": res.elapsed_s}
        results[code] = {
            "aux_ratio": rep["ratio"], "aux_bytes": rep["aux_bytes"],
            "matrix_bytes": rep["matrix_bytes"],
            "arena_speedup": t_noarena / max(1e-9, t_arena),
            "reinits_with_arena": ms.reinits, "windows": ms.windows,
            "bubble_width_fraction": width_frac,
            "envelope": envelope,
        }
        aux_rows.append([code, f"{rep['aux_bytes']/1e6:.1f}MB",
                         f"{rep['matrix_bytes']/1e6:.2f}MB",
                         f"{rep['ratio']:.0f}:1",
                         f"{t_noarena/max(1e-9,t_arena):.2f}x",
                         f"{ms.reinits}/{ms.windows}",
                         f"{width_frac:.2f}"])
        env_rows.append([code] + [
            f"#C={envelope[f]['eff_c']} {envelope[f]['elapsed_s']*1e3:.0f}ms"
            for f in (1.0, 0.5, 0.3, 0.1)])
    print_table("Table II / Fig 16 analogue — aux vs matrix memory + arena",
                ["dataset", "aux bytes", "matrix bytes", "ratio",
                 "arena speedup", "reinits/windows", "bubble width frac"],
                aux_rows)
    print_table("Fig 14 analogue — memory envelope (auto-#C)",
                ["dataset", "100%", "50%", "30%", "10%"], env_rows)
    save_artifact("bench_space", results)
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
