"""Plan reuse amortizing symbolic analysis (the plan/factor API, DESIGN.md §10).

The dominant sparse-LU workload — circuit simulation (GLU3.0, HYLU) —
factorizes one sparsity pattern hundreds of times with new values.  The old
one-shot surface re-derived the pattern, the panel schedule, the packed
store structure, and every row-index gather map on each ``numeric_factorize``
call; ``repro.analyze`` hoists all of that into a reusable ``LUPlan``.

Two regimes:

* fill-heavy stencils (the bench_numeric matrices) — ``plan.factorize`` for
  the 2nd..Nth value set must be **>= 5x** faster than one-shot
  ``numeric_factorize`` on the same pattern (enforced), with
  bitwise-identical factors (asserted before any speedup is reported);
* a large bordered block-diagonal circuit analogue (n = 20_000) driven
  through the full ``analyze -> factorize -> solve`` pipeline — ``analyze``
  must never materialize a dense (n, n) pattern: tracemalloc peak is gated
  at 256 MB where a dense bool pattern alone would be 400 MB (the same
  O(nnz) contract as the packed-store gate in bench_solve).

Exits nonzero (via run.py) if any speedup, residual, or memory gate fails.
"""
from __future__ import annotations

import tracemalloc

import numpy as np

from benchmarks.common import print_table, progress_cb, save_artifact, timeit
from repro.api import LUOptions, analyze
from repro.core.symbolic import symbolic_factorize
from repro.numeric import numeric_factorize
from repro.sparse import (
    bordered_block_diagonal, grid2d_laplacian, grid3d_laplacian, permute_csr,
    rcm_order,
)
from repro.sparse.numeric import generic_values_csr

SPEEDUP_GATE = 5.0
RESIDUAL_GATE = 1e-10
MEM_GATE_BYTES = 256 * 1024 * 1024

MATRICES = {
    "grid2d-24": lambda: grid2d_laplacian(24),
    "grid3d-8": lambda: grid3d_laplacian(8),
}

LARGE_N = 20_000
LARGE_BLOCK = 16
LARGE_BORDER = 64


def _refactorize_case(name, gen, repeats):
    a = permute_csr(gen(), rcm_order(gen()))
    plan = analyze(a, LUOptions(concurrency=256, supernode_relax=2))
    values = generic_values_csr(a)

    # the old API's refactorization loop: symbolic once (it was always
    # separable), then one-shot numeric_factorize per value set — which
    # re-derives the pattern, schedule, store structure, and gather maps
    sym = symbolic_factorize(a, concurrency=256, detect_supernodes=True,
                             supernode_relax=2)
    # best-of-N on both sides: the speedup is a *gate*, and median-of-3
    # flaps under CI load spikes
    t_oneshot = timeit(lambda: numeric_factorize(a, sym, values=values),
                       repeats=repeats, reduce=min)
    factor = plan.factorize(values)                    # warmup + parity ref
    t_refactor = timeit(lambda: plan.factorize(values), repeats=repeats,
                        warmup=0, reduce=min)

    # never report a speedup for wrong factors: plan-based refactorization
    # must be bitwise-identical to the one-shot path
    num = numeric_factorize(a, sym, values=values)
    ls, us = factor.num.store.dense_lu()
    ld, ud = num.store.dense_lu()
    if not (np.array_equal(ls, ld) and np.array_equal(us, ud)):
        raise RuntimeError(f"{name}: plan.factorize diverged from one-shot "
                           f"numeric_factorize")

    speedup = t_oneshot / t_refactor
    return {
        "n": a.n, "nnz": a.nnz, "lu_nnz": plan.lu_nnz,
        "n_supernodes": plan.n_supernodes, "n_levels": plan.n_levels,
        "analyze_s": plan.analyze_s,
        "t_oneshot_s": t_oneshot, "t_refactorize_s": t_refactor,
        "refactorize_speedup": speedup,
        "amortize_after": (plan.analyze_s / max(1e-12, t_oneshot - t_refactor)),
    }


def _large_case(repeats):
    """analyze -> factorize -> solve at n = 20_000 on the BBD circuit
    analogue, with the no-dense-pattern memory gate on analyze."""
    a = bordered_block_diagonal(LARGE_N, block=LARGE_BLOCK,
                                border=LARGE_BORDER, seed=3)
    tracemalloc.start()
    plan = analyze(a, LUOptions(concurrency=512),
                   on_progress=progress_cb(f"analyze bbd-{LARGE_N}"))
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    dense_pattern_bytes = LARGE_N * LARGE_N           # (n, n) bool
    if peak > MEM_GATE_BYTES:
        raise RuntimeError(
            f"analyze peak {peak/1e6:.0f} MB breached the "
            f"{MEM_GATE_BYTES/1e6:.0f} MB O(nnz) gate — a dense (n, n) "
            f"pattern ({dense_pattern_bytes/1e6:.0f} MB bool) leaked in")

    values = generic_values_csr(a)
    factor = plan.factorize(values)                    # warmup
    t_refactor = timeit(lambda: plan.factorize(values), repeats=repeats,
                        warmup=0)
    b = np.random.default_rng(42).standard_normal(LARGE_N)
    res = factor.solve(b)
    if res.residual > RESIDUAL_GATE:
        raise RuntimeError(f"bbd-{LARGE_N}: residual {res.residual:.2e} "
                           f"above {RESIDUAL_GATE:.0e}")
    return {
        "n": LARGE_N, "nnz": a.nnz, "lu_nnz": plan.lu_nnz,
        "n_supernodes": plan.n_supernodes,
        "analyze_s": plan.analyze_s,
        "t_refactorize_s": t_refactor,
        "solve_s": res.solve_s,
        "residual": res.residual,
        "analyze_peak_mb": peak / 1e6,
        "dense_pattern_mb": dense_pattern_bytes / 1e6,
        # not named mem_ratio on purpose: the peak is dominated by jax
        # tracing overhead, which shifts across jax versions — the absolute
        # MEM_GATE_BYTES ceiling above is the enforced contract
        "dense_pattern_over_peak": dense_pattern_bytes / max(1, peak),
        "store_entries": factor.num.store_entries,
    }


def run(repeats: int = 5) -> dict:
    results = {}
    rows = []
    for name, gen in MATRICES.items():
        r = _refactorize_case(name, gen, repeats)
        results[name] = r
        rows.append([name, r["n"],
                     f"{r['analyze_s']*1e3:.0f}ms",
                     f"{r['t_oneshot_s']*1e3:.0f}ms",
                     f"{r['t_refactorize_s']*1e3:.1f}ms",
                     f"{r['refactorize_speedup']:.1f}x",
                     f"{r['amortize_after']:.1f}"])
    r = _large_case(repeats)
    results[f"bbd-{LARGE_N//1000}k"] = r
    rows.append([f"bbd-{LARGE_N//1000}k", r["n"],
                 f"{r['analyze_s']:.0f}s", "-",
                 f"{r['t_refactorize_s']*1e3:.0f}ms", "-",
                 f"peak {r['analyze_peak_mb']:.0f}MB"])
    print_table("Plan reuse: analyze once, refactorize many",
                ["matrix", "|V|", "analyze", "one-shot", "refactorize",
                 "speedup", "amortize@"], rows)
    save_artifact("bench_refactorize", results)
    worst = min(r["refactorize_speedup"] for r in results.values()
                if "refactorize_speedup" in r)
    if worst < SPEEDUP_GATE:
        raise RuntimeError(
            f"plan refactorization speedup dropped below "
            f"{SPEEDUP_GATE:.0f}x ({worst:.2f}x)")
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
