"""Supernode detection throughput + partition quality (DESIGN.md §3).

Compares the serial dense post-pass (gather the n x n pattern, walk columns
comparing them) against the streamed fingerprint pipeline (repro.supernodes)
on the paper's dataset analogues, and reports the partition statistics the
downstream numeric consumers care about: supernode count, mean size, and the
balance ratio of the LPT panel packing.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import load_datasets, print_table, save_artifact
from repro.core.gsofa import dense_pattern, prepare_graph
from repro.core.symbolic import detect_supernodes
from repro.supernodes import (
    detect_from_fingerprints, fingerprints_from_graph, pack_panels,
    supernode_stats,
)


def _fingerprint_roofline(graph, concurrency: int, relax: int,
                          max_size: int) -> dict:
    """Achieved memory bandwidth of the fingerprint-update kernel as a
    fraction of this host's probed STREAM peak (DESIGN.md §12) — the
    repo's analogue of GSoFa's 47%-of-V100-peak figure.  Counters come
    from the obs-instrumented ``Fingerprints.update`` (bytes from the
    traffic model, seconds measured), deltas taken so an outer ``--trace``
    run's accumulation does not pollute the report."""
    from benchmarks.roofline import machine_peaks
    from repro import obs

    reg = obs.registry()
    with obs.ensure(True):
        b0 = float(reg.get("fingerprint.bytes") or 0.0)
        s0 = float(reg.get("fingerprint.seconds") or 0.0)
        fp = fingerprints_from_graph(graph, concurrency=concurrency)
        detect_from_fingerprints(fp, relax=relax, max_size=max_size)
        nbytes = float(reg.get("fingerprint.bytes") or 0.0) - b0
        seconds = float(reg.get("fingerprint.seconds") or 0.0) - s0
    return obs.roofline_report("fingerprint_update", nbytes=nbytes,
                               seconds=seconds, peaks=machine_peaks())


def run(codes=("BC", "EP", "G7", "LH", "TT", "PR"), concurrency: int = 256,
        relax: int = 0, max_size: int = 64, n_panels: int = 8) -> dict:
    results = {}
    rows = []
    roof_code, roof_graph, roof_n = None, None, -1
    for code, a in load_datasets(codes).items():
        graph = prepare_graph(a)
        if a.n > roof_n:                       # roofline on the largest case
            roof_code, roof_graph, roof_n = code, graph, a.n

        def batched():
            fp = fingerprints_from_graph(graph, concurrency=concurrency)
            return fp, detect_from_fingerprints(fp, relax=relax,
                                                max_size=max_size)

        t0 = time.perf_counter()
        serial_ranges = detect_supernodes(dense_pattern(graph),
                                          max_size=max_size)
        t_serial = time.perf_counter() - t0
        batched()                                  # jit warmup
        t0 = time.perf_counter()
        fp, ranges = batched()
        t_batched = time.perf_counter() - t0
        # T2 must be bit-identical to the serial oracle; relaxed modes
        # legitimately merge more
        parity_ok = relax != 0 or np.array_equal(ranges, serial_ranges)
        stats = supernode_stats(ranges)
        part = pack_panels(ranges, fp.counts, n_panels)
        r = {
            "n": a.n, "nnz": a.nnz,
            "t_serial_s": t_serial, "t_batched_s": t_batched,
            "cols_per_s": a.n / max(1e-9, t_batched),
            "balance_ratio": part.balance_ratio,
            "parity_ok": parity_ok,
            **stats,
        }
        if not parity_ok:
            save_artifact("bench_supernode", results | {code: r})
            raise RuntimeError(f"{code}: batched/serial parity broken")
        results[code] = r
        rows.append([code, a.n, f"{t_serial*1e3:.0f}ms", f"{t_batched*1e3:.0f}ms",
                     stats["n_supernodes"], f"{stats['mean_size']:.2f}",
                     f"{part.balance_ratio:.2f}"])
    print_table("Supernode detection — serial dense post-pass vs streamed "
                "fingerprints",
                ["dataset", "|V|", "serial", "batched", "#sn", "mean size",
                 f"LPT balance (p={n_panels})"], rows)
    roof = _fingerprint_roofline(roof_graph, concurrency, relax, max_size)
    roof["dataset"] = roof_code
    results["roofline_fingerprint"] = roof
    print(f"\nfingerprint roofline ({roof_code}): "
          f"{roof['achieved_gbs']:.2f} GB/s achieved = "
          f"{roof['bw_fraction']:.1%} of probed peak "
          f"{roof['peak_gbs']:.2f} GB/s")
    save_artifact("bench_supernode", results)
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
