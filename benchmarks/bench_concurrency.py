"""Paper Fig 12: throughput vs number of concurrent sources (#C).

The paper sweeps #C 1..4096 and sees climbing speedup as multi-source
batches saturate the GPU (max 61.6x on LH).  The TPU/CPU analogue: one
batched fixpoint over #C sources vs #C single-source runs — the win is
vectorization across the batch dimension (the 'combined traversal' lanes).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import load_datasets, print_table, save_artifact, timeit
from repro.core.gsofa import prepare_graph
from repro.core.multisource import run_multisource


def run(codes=("BC", "EP", "TT", "PR"), cs=(1, 4, 16, 64, 256)) -> dict:
    results = {}
    rows = []
    for code, a in load_datasets(codes).items():
        graph = prepare_graph(a)
        times = {}
        for c in cs:
            # time a fixed slice of the source space per #C for comparability
            n_src = max(cs)
            srcs = np.arange(a.n - n_src, a.n, dtype=np.int32)  # heavy tail
            times[c] = timeit(
                lambda c=c: run_multisource(graph, concurrency=c, sources=srcs,
                                            use_arena=False),
                repeats=1) / n_src
        speedups = {c: times[cs[0]] / times[c] for c in cs}
        results[code] = {"per_source_s": times, "speedup_vs_c1": speedups}
        rows.append([code] + [f"{speedups[c]:.1f}x" for c in cs])
    print_table("Fig 12 analogue — speedup vs #C (vs #C=1)",
                ["dataset"] + [f"#C={c}" for c in cs], rows)
    save_artifact("bench_concurrency", results)
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
