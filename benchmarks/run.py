"""Benchmark driver: one module per paper table/figure + the roofline reader.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only speedup,space

Paper-figure map:
  workload     -> Fig 3   (per-source workload growth)
  balance      -> Figs 7/8/11 (combined traversal + interleaved assignment)
  concurrency  -> Fig 12  (throughput vs #C)
  speedup      -> Fig 10  (GSoFa vs sequential fill2 baseline)
  space        -> Figs 13/14/16 + Tables II/III (memory management)
  supernode    -> §"supernode detection" (streamed fingerprints vs post-pass)
  numeric      -> DESIGN.md §4 (supernodal numeric LU vs column-at-a-time)
  roofline     -> EXPERIMENTS.md §Roofline (reads dry-run artifacts)

Exits nonzero if any selected suite fails, so CI smoke steps catch wiring rot.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))

    from benchmarks import (bench_balance, bench_concurrency, bench_numeric,
                            bench_space, bench_speedup, bench_supernode,
                            bench_workload, roofline)
    suites = [
        ("workload", bench_workload.main),
        ("balance", bench_balance.main),
        ("concurrency", bench_concurrency.main),
        ("speedup", bench_speedup.main),
        ("space", bench_space.main),
        ("supernode", bench_supernode.main),
        ("numeric", bench_numeric.main),
        ("roofline", roofline.main),
    ]
    failures = []
    for name, fn in suites:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"\n===== {name} =====")
        try:
            fn()
        except Exception as e:  # keep the suite running; report at the end
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
            failures.append(name)
        print(f"[{name}] {time.time()-t0:.1f}s")
    if failures:
        print(f"\nFAILED suites: {', '.join(failures)}")
        sys.exit(1)


if __name__ == "__main__":
    main()
