"""Benchmark driver: one module per paper table/figure + the roofline probe.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only speedup,space
    PYTHONPATH=src python -m benchmarks.run --check-baseline
    PYTHONPATH=src python -m benchmarks.run --trace     # + Chrome traces
    PYTHONPATH=src python -m benchmarks.run --validate-traces

Paper-figure map:
  workload     -> Fig 3   (per-source workload growth)
  balance      -> Figs 7/8/11 (combined traversal + interleaved assignment)
  concurrency  -> Fig 12  (throughput vs #C)
  speedup      -> Fig 10  (GSoFa vs sequential fill2 baseline)
  space        -> Figs 13/14/16 + Tables II/III (memory management)
  supernode    -> §"supernode detection" (streamed fingerprints vs post-pass)
  numeric      -> DESIGN.md §4 (supernodal numeric LU vs column-at-a-time)
  solve        -> DESIGN.md §9 (packed CSC-panel storage + solve/refinement)
  refactorize  -> DESIGN.md §10 (plan reuse: analyze once, refactorize many)
  distributed  -> DESIGN.md §11 (panel placement + 8-device analyze parity)
  roofline     -> DESIGN.md §12 (machine peak probe: STREAM triad + DGEMM)
  serve        -> DESIGN.md §14 (plan cache + batched factorize/solve tier)
  robust       -> DESIGN.md §15 (static pivoting + perturbation + quality)
  blocking     -> DESIGN.md §16 (irregular blocking merge + roofline autotune)

Exits nonzero if any selected suite fails, so CI smoke steps catch wiring rot.

``--check-baseline`` is the CI regression gate: fresh ``artifacts/*.json``
are compared against the committed ``baselines/*.json``.  Machine-portable
ratio metrics (speedups) are gated at ``--tolerance`` (default 25%); absolute
times participate only with ``--check-times`` (opt-in for like-for-like
hardware).  Exits nonzero on any regression.

``--trace`` (DESIGN.md §12) wraps every selected suite in
``repro.obs.tracing``, writing a Perfetto-loadable Chrome trace to
``artifacts/trace_<suite>.json`` per suite (the registry is reset per suite
so each artifact's ``metrics`` block is that suite's own), and turns on
rate-limited stderr progress/ETA lines for the long analyzes.
``--validate-traces`` is the matching CI smoke step: every expected trace
must parse as Chrome trace-event JSON and contain at least one span for
each of the suite's required phases (wiring rot in the instrumentation
fails loudly, not by silently emitting empty traces).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# span names every suite's trace must contain at least once under --trace
# (the span taxonomy is DESIGN.md §12; suites listed with an empty set are
# parse-validated only — e.g. roofline probes record no pipeline spans)
REQUIRED_PHASES = {
    "workload": ["fixpoint_chunk"],
    "balance": ["fixpoint_chunk"],
    "concurrency": ["fixpoint_chunk"],
    "speedup": ["fixpoint_chunk"],
    "space": ["fixpoint", "fixpoint_chunk"],
    "supernode": ["fingerprint_update", "supernode_detect"],
    "numeric": ["analyze", "fixpoint", "supernode_detect", "factorize",
                "factor_level", "scatter_values"],
    "solve": ["analyze", "factorize", "solve_forward", "solve_backward"],
    "refactorize": ["analyze", "factorize", "factor_level",
                    "solve_forward"],
    "distributed": ["analyze", "placement", "factorize", "factor_level",
                    "factor_segment", "solve_forward", "solve_backward",
                    "runtime", "overlap"],
    "roofline": [],
    "serve": ["serve", "factorize_batch", "solve_batch"],
    "robust": ["analyze", "robust_prepass", "factorize", "solve_forward",
               "robust_quality"],
    "blocking": ["analyze", "factorize", "replan", "blocking_merge",
                 "autotune"],
}


def check_baseline(tolerance: float, include_times: bool,
                   baseline_dir: str | None) -> None:
    from benchmarks.common import check_baselines

    violations = check_baselines(baseline_dir=baseline_dir,
                                 tolerance=tolerance,
                                 include_times=include_times)
    if not violations:
        print(f"baseline gate: OK (tolerance {tolerance:.0%}, "
              f"times {'included' if include_times else 'excluded'})")
        return
    print(f"baseline gate: {len(violations)} violation(s)")
    for v in violations:
        print(f"  [{v['kind']}] {v['path']}: {v['detail']}")
    sys.exit(1)


def validate_traces(only: set) -> None:
    from benchmarks.common import ARTIFACTS

    names = [n for n in REQUIRED_PHASES if not only or n in only]
    failures = []
    for name in names:
        path = os.path.join(ARTIFACTS, f"trace_{name}.json")
        if not os.path.exists(path):
            failures.append(f"{name}: trace file missing ({path}) — was the "
                            f"suite run with --trace?")
            continue
        try:
            with open(path) as f:
                events = json.load(f)
        except json.JSONDecodeError as e:
            failures.append(f"{name}: trace is not valid JSON ({e})")
            continue
        if isinstance(events, dict):           # JSON-object trace format
            events = events.get("traceEvents")
        if not isinstance(events, list):
            failures.append(f"{name}: Chrome trace must be a JSON array or "
                            f"an object with a 'traceEvents' array")
            continue
        spans = [e for e in events if isinstance(e, dict)
                 and e.get("ph") == "X"]
        bad = [e for e in spans
               if not {"name", "ts", "dur", "pid", "tid"} <= e.keys()]
        if bad:
            failures.append(f"{name}: {len(bad)} complete event(s) missing "
                            f"required keys (name/ts/dur/pid/tid)")
        seen = {e["name"] for e in spans if "name" in e}
        for phase in REQUIRED_PHASES[name]:
            if phase not in seen:
                failures.append(f"{name}: no '{phase}' span in trace "
                                f"(has: {sorted(seen)[:12]})")
    if failures:
        print(f"trace validation: {len(failures)} failure(s)")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    print(f"trace validation: OK ({len(names)} trace(s), every required "
          f"phase present)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--check-baseline", action="store_true",
                    help="compare fresh artifacts against committed "
                         "baselines and exit nonzero on regression")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative drift for gated metrics")
    ap.add_argument("--check-times", action="store_true",
                    help="also gate absolute wall-clock metrics (only "
                         "meaningful on the hardware that recorded the "
                         "baselines)")
    ap.add_argument("--baseline-dir", default=None)
    ap.add_argument("--trace", action="store_true",
                    help="wrap each suite in repro.obs.tracing, writing "
                         "artifacts/trace_<suite>.json, and print stderr "
                         "progress for long analyzes")
    ap.add_argument("--validate-traces", action="store_true",
                    help="validate previously written traces: Chrome "
                         "trace-event JSON with >=1 span per required phase")
    args = ap.parse_args()

    only = set(filter(None, args.only.split(",")))

    if args.check_baseline:
        check_baseline(args.tolerance, args.check_times, args.baseline_dir)
        return
    if args.validate_traces:
        validate_traces(only)
        return

    from benchmarks import (bench_balance, bench_blocking,
                            bench_concurrency, bench_distributed,
                            bench_numeric, bench_refactorize, bench_robust,
                            bench_serve, bench_solve, bench_space,
                            bench_speedup, bench_supernode, bench_workload,
                            roofline)
    suites = [
        ("workload", bench_workload.main),
        ("balance", bench_balance.main),
        ("concurrency", bench_concurrency.main),
        ("speedup", bench_speedup.main),
        ("space", bench_space.main),
        ("supernode", bench_supernode.main),
        ("numeric", bench_numeric.main),
        ("solve", bench_solve.main),
        ("refactorize", bench_refactorize.main),
        ("distributed", bench_distributed.main),
        ("roofline", roofline.main),
        ("serve", bench_serve.main),
        ("robust", bench_robust.main),
        ("blocking", bench_blocking.main),
    ]
    if args.trace:
        import benchmarks.common as common
        from repro import obs

        common.PROGRESS = True
        os.makedirs(common.ARTIFACTS, exist_ok=True)

    failures = []
    for name, fn in suites:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"\n===== {name} =====")
        try:
            if args.trace:
                # fresh counters per suite so each artifact's metrics block
                # is self-contained; the trace writes even if the suite
                # raises (wiring rot stays diagnosable from the artifact)
                obs.registry().reset()
                trace_path = os.path.join(common.ARTIFACTS,
                                          f"trace_{name}.json")
                with obs.tracing(trace_path):
                    fn()
            else:
                fn()
        except Exception as e:  # keep the suite running; report at the end
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
            failures.append(name)
        print(f"[{name}] {time.time()-t0:.1f}s")
    if failures:
        print(f"\nFAILED suites: {', '.join(failures)}")
        sys.exit(1)


if __name__ == "__main__":
    main()
