"""Benchmark driver: one module per paper table/figure + the roofline reader.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only speedup,space
    PYTHONPATH=src python -m benchmarks.run --check-baseline

Paper-figure map:
  workload     -> Fig 3   (per-source workload growth)
  balance      -> Figs 7/8/11 (combined traversal + interleaved assignment)
  concurrency  -> Fig 12  (throughput vs #C)
  speedup      -> Fig 10  (GSoFa vs sequential fill2 baseline)
  space        -> Figs 13/14/16 + Tables II/III (memory management)
  supernode    -> §"supernode detection" (streamed fingerprints vs post-pass)
  numeric      -> DESIGN.md §4 (supernodal numeric LU vs column-at-a-time)
  solve        -> DESIGN.md §9 (packed CSC-panel storage + solve/refinement)
  refactorize  -> DESIGN.md §10 (plan reuse: analyze once, refactorize many)
  distributed  -> DESIGN.md §11 (panel placement + 8-device analyze parity)
  roofline     -> EXPERIMENTS.md §Roofline (reads dry-run artifacts)

Exits nonzero if any selected suite fails, so CI smoke steps catch wiring rot.

``--check-baseline`` is the CI regression gate: fresh ``artifacts/*.json``
are compared against the committed ``baselines/*.json``.  Machine-portable
ratio metrics (speedups) are gated at ``--tolerance`` (default 25%); absolute
times participate only with ``--check-times`` (opt-in for like-for-like
hardware).  Exits nonzero on any regression.
"""
from __future__ import annotations

import argparse
import sys
import time


def check_baseline(tolerance: float, include_times: bool,
                   baseline_dir: str | None) -> None:
    from benchmarks.common import check_baselines

    violations = check_baselines(baseline_dir=baseline_dir,
                                 tolerance=tolerance,
                                 include_times=include_times)
    if not violations:
        print(f"baseline gate: OK (tolerance {tolerance:.0%}, "
              f"times {'included' if include_times else 'excluded'})")
        return
    print(f"baseline gate: {len(violations)} violation(s)")
    for v in violations:
        print(f"  [{v['kind']}] {v['path']}: {v['detail']}")
    sys.exit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--check-baseline", action="store_true",
                    help="compare fresh artifacts against committed "
                         "baselines and exit nonzero on regression")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative drift for gated metrics")
    ap.add_argument("--check-times", action="store_true",
                    help="also gate absolute wall-clock metrics (only "
                         "meaningful on the hardware that recorded the "
                         "baselines)")
    ap.add_argument("--baseline-dir", default=None)
    args = ap.parse_args()

    if args.check_baseline:
        check_baseline(args.tolerance, args.check_times, args.baseline_dir)
        return

    only = set(filter(None, args.only.split(",")))

    from benchmarks import (bench_balance, bench_concurrency,
                            bench_distributed, bench_numeric,
                            bench_refactorize, bench_solve, bench_space,
                            bench_speedup, bench_supernode, bench_workload,
                            roofline)
    suites = [
        ("workload", bench_workload.main),
        ("balance", bench_balance.main),
        ("concurrency", bench_concurrency.main),
        ("speedup", bench_speedup.main),
        ("space", bench_space.main),
        ("supernode", bench_supernode.main),
        ("numeric", bench_numeric.main),
        ("solve", bench_solve.main),
        ("refactorize", bench_refactorize.main),
        ("distributed", bench_distributed.main),
        ("roofline", roofline.main),
    ]
    failures = []
    for name, fn in suites:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"\n===== {name} =====")
        try:
            fn()
        except Exception as e:  # keep the suite running; report at the end
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
            failures.append(name)
        print(f"[{name}] {time.time()-t0:.1f}s")
    if failures:
        print(f"\nFAILED suites: {', '.join(failures)}")
        sys.exit(1)


if __name__ == "__main__":
    main()
