"""Supernodal numeric LU: batched panel updates vs column-at-a-time.

The end-to-end payoff of the PR-2 numeric subsystem (DESIGN.md §4): consume
the symbolic panel partition in a supernodal left-looking factorization whose
updates are accumulated dense GEMMs, and compare against the honest
column-at-a-time left-looking baseline (one axpy per structural U entry) on
the fill-heavy generators.  The supernodal side runs through the plan/factor
session API (``analyze`` once, ``plan.factorize`` per timing repeat —
DESIGN.md §10).  Parity against the dense no-pivot oracle is asserted, so
the speedup is never reported for wrong factors.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, save_artifact, timeit
from repro.api import LUOptions, analyze
from repro.numeric import factorize_columns
from repro.sparse import grid2d_laplacian, grid3d_laplacian, permute_csr, rcm_order
from repro.sparse.numeric import generic_values, lu_nopivot

# fill-heavy stencil generators (BC / RM analogues), RCM-reordered
MATRICES = {
    "grid2d-24": lambda: grid2d_laplacian(24),
    "grid3d-8": lambda: grid3d_laplacian(8),
}


def _gemm_roofline(name, plan, values) -> dict:
    """Panel-GEMM sweep throughput against both roofs (DESIGN.md §12):
    bandwidth via the sweep's analytic gather/scatter traffic model
    (``gemm.bytes``), compute via the counted flops — the arithmetic
    intensity in the report says which roof binds.  Counter deltas, so an
    outer ``--trace`` run's accumulation does not pollute the report."""
    from benchmarks.roofline import machine_peaks
    from repro import obs

    reg = obs.registry()
    with obs.ensure(True):
        f0 = float(reg.get("gemm.flops") or 0.0)
        b0 = float(reg.get("gemm.bytes") or 0.0)
        s0 = float(reg.get("gemm.seconds") or 0.0)
        plan.factorize(values)
        flops = float(reg.get("gemm.flops") or 0.0) - f0
        nbytes = float(reg.get("gemm.bytes") or 0.0) - b0
        seconds = float(reg.get("gemm.seconds") or 0.0) - s0
    rep = obs.roofline_report("panel_gemm_sweep", nbytes=nbytes,
                              seconds=seconds, peaks=machine_peaks(),
                              flops=flops)
    rep["matrix"] = name
    return rep


def run(relax: int = 2, n_bins: int = 8, repeats: int = 3) -> dict:
    results = {}
    rows = []
    roof_case = None
    for name, gen in MATRICES.items():
        a = gen()
        a = permute_csr(a, rcm_order(a))
        plan = analyze(a, LUOptions(concurrency=256, supernode_relax=relax,
                                    n_bins=n_bins))
        pattern = plan.pattern.to_dense()           # column baseline input
        values = generic_values(a)

        t_col = timeit(lambda: factorize_columns(values, pattern),
                       repeats=repeats)
        num = plan.factorize(values).num            # doubles as the warmup
        t_sup = timeit(lambda: plan.factorize(values), repeats=repeats,
                       warmup=0)
        l0, u0 = lu_nopivot(values)
        rel = max(np.abs(num.l - l0).max() / np.abs(l0).max(),
                  np.abs(num.u - u0).max() / np.abs(u0).max())
        if rel > 1e-10:
            raise RuntimeError(f"{name}: supernodal parity broken ({rel:.2e})")
        speedup = t_col / t_sup
        r = {
            "n": a.n, "nnz": a.nnz,
            "n_supernodes": num.n_supernodes, "n_levels": num.n_levels,
            "n_updates": num.n_updates,
            "gemm_gflops": num.gemm_flops / 1e9,
            "t_column_s": t_col, "t_supernodal_s": t_sup,
            "speedup": speedup, "rel_err": rel,
            "balance_ratio": num.schedule.partition.balance_ratio,
        }
        results[name] = r
        rows.append([name, a.n, num.n_supernodes, num.n_levels,
                     f"{t_col*1e3:.0f}ms", f"{t_sup*1e3:.0f}ms",
                     f"{speedup:.2f}x", f"{rel:.1e}"])
        roof_case = (name, plan, values)       # last = most GEMM-heavy
    print_table("Supernodal numeric LU — batched panel GEMMs vs "
                "column-at-a-time",
                ["matrix", "|V|", "#sn", "levels", "column", "supernodal",
                 "speedup", "rel err"], rows)
    rep = _gemm_roofline(*roof_case)
    results["roofline_gemm"] = rep
    print(f"\npanel-GEMM roofline ({rep['matrix']}): "
          f"{rep['achieved_gflops']:.2f} GFLOP/s = "
          f"{rep['flop_fraction']:.1%} of peak; "
          f"{rep['achieved_gbs']:.2f} GB/s = "
          f"{rep['bw_fraction']:.1%} of peak "
          f"(intensity {rep['intensity_flops_per_byte']:.1f} flop/byte)")
    save_artifact("bench_numeric", results)
    worst = min(r["speedup"] for r in results.values() if "speedup" in r)
    if worst < 1.5:
        raise RuntimeError(
            f"supernodal-batched speedup dropped below 1.5x ({worst:.2f}x)")
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
