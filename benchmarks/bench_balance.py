"""Paper Figs 7/8/11: workload-balancing optimizations.

* Fig 7 analogue — *combined traversal*: per-lane workload spread.  In the
  dense-batch adaptation the shared frontier pool is the batch dimension
  itself; the measurable analogue of "#edge checks per thread" is the
  spread of per-source work inside one combined batch (lanes process whole
  (source, vertex) tiles, so the per-lane work is the batch mean rather
  than a single source's) versus one-source-at-a-time execution.
* Fig 8 analogue — *interleaved source assignment*: per-device edge-check
  max/min ratio under contiguous vs round-robin source->device assignment
  (the paper reports 10.31 -> 1.01 on 36 GPUs; we use the same per-source
  edge counts aggregated over simulated device shards, which is exactly how
  the imbalance arises — per-source work is schedule-independent).
* Fig 11 analogue — wall-clock impact of combined traversal (the "combine"
  bar; thread- vs warp-centric collapses into kernel block shape on TPU and
  is swept in tests/test_kernels.py instead).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import load_datasets, print_table, save_artifact, timeit
from repro.core.distributed import assign_sources
from repro.core.gsofa import prepare_graph
from repro.core.multisource import run_multisource


def device_balance(edge_checks: np.ndarray, n_dev: int, policy: str) -> float:
    srcs = assign_sources(len(edge_checks), n_dev, policy=policy)
    per_dev = np.array([
        edge_checks[np.unique(srcs[d])].sum() for d in range(n_dev)],
        dtype=np.float64)
    return float(per_dev.max() / max(1.0, per_dev.min()))


def run(codes=("BC", "RM", "TT", "PR"), n_dev: int = 36,
        concurrency: int = 128) -> dict:
    results = {}
    rows = []
    for code, a in load_datasets(codes).items():
        graph = prepare_graph(a)
        ms = run_multisource(graph, concurrency=concurrency)
        ec = ms.edge_checks.astype(np.float64)

        contiguous = device_balance(ec, n_dev, "contiguous")
        interleave = device_balance(ec, n_dev, "interleave")

        t_combined = timeit(lambda: run_multisource(graph, concurrency=concurrency,
                                                    combined=True), repeats=1)
        t_separate = timeit(lambda: run_multisource(graph, concurrency=concurrency,
                                                    combined=False), repeats=1)

        # Fig 7 spread: per-source edge checks inside a combined batch
        chunk = ec[: concurrency]
        spread_before = float(chunk.max() / max(1.0, chunk[chunk > 0].min()))
        r = {
            "balance_contiguous": contiguous,
            "balance_interleave": interleave,
            "combined_speedup": t_separate / max(1e-9, t_combined),
            "t_combined_s": t_combined,
            "t_separate_s": t_separate,
            "per_source_spread_in_batch": spread_before,
        }
        results[code] = r
        rows.append([code, f"{contiguous:.2f}x", f"{interleave:.2f}x",
                     f"{r['combined_speedup']:.1f}x",
                     f"{spread_before:.0f}x -> 1.0x (lane view)"])
    print_table("Fig 8/11 analogue — balancing",
                ["dataset", "contiguous max/min", "interleaved max/min",
                 "combined speedup", "per-lane spread"], rows)
    save_artifact("bench_balance", results)
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
