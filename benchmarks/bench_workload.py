"""Paper Fig 3: per-source workload grows with the source ID.

Measures edge checks (the paper's workload metric) and convergence
supersteps per source on the Table-I analogues; reports the max/min ratio
between the largest and smallest deciles (the paper quotes 1,265x-49,726x
between single smallest/largest sources on the real matrices).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import load_datasets, print_table, save_artifact
from repro.core.gsofa import prepare_graph
from repro.core.multisource import run_multisource


def run(codes=("BC", "RM", "TT", "PR"), concurrency: int = 128) -> dict:
    results = {}
    rows = []
    for code, a in load_datasets(codes).items():
        graph = prepare_graph(a)
        ms = run_multisource(graph, concurrency=concurrency)
        ec = ms.edge_checks.astype(np.float64)
        deciles = np.array_split(ec, 10)
        first, last = max(1.0, deciles[0].mean()), max(1.0, deciles[-1].mean())
        r = {
            "n": a.n,
            "edge_checks_total": int(ec.sum()),
            "decile_means": [float(d.mean()) for d in deciles],
            "first_decile": first,
            "last_decile": last,
            "growth_ratio": last / first,
            "max_over_min_source": float(max(1.0, ec.max())
                                         / max(1.0, ec[ec > 0].min()
                                               if (ec > 0).any() else 1.0)),
        }
        results[code] = r
        rows.append([code, a.n, f"{r['first_decile']:.1f}", f"{r['last_decile']:.1f}",
                     f"{r['growth_ratio']:.1f}x", f"{r['max_over_min_source']:.0f}x"])
    print_table("Fig 3 analogue — workload vs source ID",
                ["dataset", "|V|", "first-decile edges", "last-decile edges",
                 "growth", "max/min source"], rows)
    save_artifact("bench_workload", results)
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
