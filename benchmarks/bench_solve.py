"""End-to-end solve() on packed CSC-panel factors (DESIGN.md §9).

Two regimes:

* fill-heavy stencil generators (the bench_numeric matrices) — full
  pipeline through the plan/factor session API with dense-oracle parity:
  the solve must match ``numpy.linalg.solve`` and reach a relative
  residual <= 1e-10, with factorization and substitution timed separately
  (``LUFactorization.factor_s`` / ``SolveResult.solve_s``);
* a large full-band matrix (n = 20_000) driven entirely through the sparse
  engine path (CSR-aligned values + a hand-built ``CSCPattern`` + uniform
  panels — the band's diameter makes the symbolic fixpoint the wrong tool,
  so the analyze-driven large case lives in bench_refactorize) — the
  regime the dense working matrix could never reach; the packed store is
  asserted to stay O(nnz(L+U)) (no (n, n) allocation anywhere).

Exits nonzero (via run.py) if any residual or memory gate fails.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, save_artifact, timeit
from repro.api import LUOptions, analyze
from repro.numeric import (
    CSCPattern, numeric_factorize, solve, solve_factored, uniform_supernodes,
)
from repro.numeric.solve import build_solve_schedule
from repro.sparse import (
    banded_full, grid2d_laplacian, grid3d_laplacian, permute_csr, rcm_order,
)
from repro.sparse.numeric import generic_values, generic_values_csr

RESIDUAL_GATE = 1e-10

MATRICES = {
    "grid2d-24": lambda: grid2d_laplacian(24),
    "grid3d-8": lambda: grid3d_laplacian(8),
}

LARGE_N = 20_000
LARGE_BAND = 4
LARGE_PANEL = 8


def _small_case(name, gen, repeats):
    a = permute_csr(gen(), rcm_order(gen()))
    plan = analyze(a, LUOptions(concurrency=256, supernode_relax=2))
    values = generic_values(a)
    rng = np.random.default_rng(42)
    b = rng.standard_normal(a.n)

    t_factor = timeit(lambda: plan.factorize(values), repeats=repeats)
    factor = plan.factorize(values)
    res = factor.solve(b)
    t_solve = timeit(lambda: solve_factored(res.num, b), repeats=repeats)

    x0 = np.linalg.solve(values, b)
    rel = float(np.abs(res.x - x0).max() / np.abs(x0).max())
    if rel > 1e-10:
        raise RuntimeError(f"{name}: solve() disagrees with "
                           f"numpy.linalg.solve ({rel:.2e})")
    if res.residual > RESIDUAL_GATE:
        raise RuntimeError(f"{name}: residual {res.residual:.2e} above "
                           f"{RESIDUAL_GATE:.0e}")
    sched = plan.solve_schedule
    return a, res, {
        "n": a.n, "nnz": a.nnz,
        "store_entries": res.num.store_entries,
        "store_mb": res.num.store.nbytes / 1e6,
        "dense_mb": a.n * a.n * 8 / 1e6,
        "mem_ratio": (a.n * a.n * 8) / max(1, res.num.store.nbytes),
        # the factor/solve timing split: factor_s is the plan-based numeric
        # sweep, solve_s the substitution + refinement of the solve call
        "t_factor_s": t_factor, "t_solve_s": t_solve,
        "factor_s": factor.factor_s, "solve_s": res.solve_s,
        "residual_first": res.residuals[0], "residual_final": res.residual,
        "refine_accepted": res.refine_accepted,
        "n_fwd_levels": sched.n_fwd_levels,
        "n_bwd_levels": sched.n_bwd_levels,
        "rel_err_vs_dense": rel,
    }


def _large_case(repeats):
    """The sparse-path regime: everything O(nnz(L+U)), no dense anywhere."""
    n, band, width = LARGE_N, LARGE_BAND, LARGE_PANEL
    a = banded_full(n, band=band)
    pattern = CSCPattern.banded(n, band)        # exact: full bands don't fill
    sup = uniform_supernodes(n, width)
    values = generic_values_csr(a)
    rng = np.random.default_rng(42)
    b = rng.standard_normal(n)

    t_factor = timeit(lambda: numeric_factorize(a, values=values,
                                                pattern=pattern,
                                                supernodes=sup),
                      repeats=repeats, warmup=1)
    res = solve(a, b, values=values, pattern=pattern, supernodes=sup)
    t_solve = timeit(lambda: solve_factored(res.num, b), repeats=repeats,
                     warmup=0)

    store = res.num.store
    if store.total_entries > 4 * pattern.nnz:
        raise RuntimeError(
            f"packed store grew past O(nnz(L+U)): {store.total_entries} "
            f"slots for {pattern.nnz} pattern nonzeros")
    biggest = max(blk.size for blk in store.blocks)
    if biggest >= n:
        raise RuntimeError(
            f"a panel block holds {biggest} entries — the packed path must "
            f"never approach an (n, n) allocation")
    if res.residual > RESIDUAL_GATE:
        raise RuntimeError(f"banded-{n}: residual {res.residual:.2e} above "
                           f"{RESIDUAL_GATE:.0e}")
    sched = build_solve_schedule(store)
    return {
        "n": n, "nnz": a.nnz,
        "store_entries": store.total_entries,
        "store_mb": store.nbytes / 1e6,
        "dense_mb": n * n * 8 / 1e6,
        "mem_ratio": (n * n * 8) / max(1, store.nbytes),
        "t_factor_s": t_factor, "t_solve_s": t_solve,
        "factor_s": res.factor_s, "solve_s": res.solve_s,
        "residual_first": res.residuals[0], "residual_final": res.residual,
        "refine_accepted": res.refine_accepted,
        "n_fwd_levels": sched.n_fwd_levels,
        "n_bwd_levels": sched.n_bwd_levels,
    }


def run(repeats: int = 3) -> dict:
    results = {}
    rows = []
    for name, gen in MATRICES.items():
        _, res, r = _small_case(name, gen, repeats)
        results[name] = r
        rows.append([name, r["n"], f"{r['t_factor_s']*1e3:.0f}ms",
                     f"{r['t_solve_s']*1e3:.1f}ms",
                     f"{r['residual_final']:.1e}",
                     f"{r['mem_ratio']:.0f}x"])
    r = _large_case(repeats)
    results[f"banded-{LARGE_N//1000}k"] = r
    rows.append([f"banded-{LARGE_N//1000}k", r["n"],
                 f"{r['t_factor_s']*1e3:.0f}ms", f"{r['t_solve_s']*1e3:.1f}ms",
                 f"{r['residual_final']:.1e}", f"{r['mem_ratio']:.0f}x"])
    print_table("End-to-end solve on packed CSC-panel factors",
                ["matrix", "|V|", "factor", "solve", "residual",
                 "mem vs dense"], rows)
    save_artifact("bench_solve", results)
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
