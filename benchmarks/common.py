"""Shared benchmark helpers: dataset loading, timing, artifact output, and
the baseline-regression gate CI runs (``run.py --check-baseline``)."""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Callable, Dict, Iterable, List, Optional

import numpy as np

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")
BASELINES = os.path.join(os.path.dirname(__file__), "baselines")

# artifact schema: v1 = raw result dicts + "_meta"; v2 adds "_schema" and an
# obs "metrics" block (span phase totals + registry snapshot).  The baseline
# gate walks only the keys a committed baseline names and skips "_"-prefixed
# sections and "metrics", so v2 artifacts check cleanly against v1 baselines.
SCHEMA_VERSION = 2

# flipped by ``run.py --trace``: long-running suites pass
# ``progress_cb(label)`` to analyze()/run_multisource() and get rate-limited
# stderr progress lines (with rolling-rate ETA) only when the driver asked
PROGRESS = False


def progress_cb(label: str):
    """The suite-side half of the ``--trace`` progress plumbing: a
    ``stderr_progress`` callback when the driver enabled it, else None
    (``on_progress=None`` is the no-op default everywhere)."""
    if not PROGRESS:
        return None
    from repro.obs import stderr_progress

    return stderr_progress(label)


def artifact_meta() -> Dict:
    """Provenance stamped into every artifact so baseline diffs in CI are
    attributable: git sha, jax version, backend, UTC timestamp."""
    meta = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())}
    try:
        meta["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(__file__), timeout=10).stdout.strip() or "unknown"
    except Exception:
        meta["git_sha"] = "unknown"
    try:
        import jax

        meta["jax_version"] = jax.__version__
        meta["backend"] = jax.default_backend()
    except Exception:
        meta["jax_version"] = meta["backend"] = "unknown"
    return meta


def metrics_block(tracer=None, mark: int = 0) -> Dict:
    """The shared obs "metrics" section every bench artifact carries:
    span phase totals (from ``tracer`` — defaults to the active one) plus
    the registry's counters/gauges/histograms.  Empty subsections when
    nothing was recorded (tracing off), so artifacts stay schema-stable."""
    from repro import obs

    tr = tracer if tracer is not None else obs.tracer()
    return {
        "phases": tr.phase_totals(mark) if tr is not None else {},
        **obs.registry().snapshot(),
    }


def save_artifact(name: str, payload: Dict, *,
                  directory: Optional[str] = None,
                  metrics: Optional[Dict] = None) -> str:
    directory = directory or ARTIFACTS
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, name + ".json")
    out = dict(payload)            # callers keep iterating their own dict
    out["_schema"] = SCHEMA_VERSION
    out["_meta"] = artifact_meta()
    out["metrics"] = metrics if metrics is not None else metrics_block()
    with open(path, "w") as f:
        json.dump(out, f, indent=1, default=float)
    return path


# ---------------------------------------------------------------------------
# baseline-regression gate
#
# Fresh artifacts/*.json are compared against the committed baselines/*.json.
# Ratio metrics ("speedup*") are machine-portable and always gated: fresh
# must stay >= baseline * (1 - tolerance).  Absolute wall-clock metrics
# ("t_*", "*_s") are only gated when include_times=True (CI machines are not
# the machine that recorded the baseline, so absolute-time gating is an
# opt-in for like-for-like hardware): fresh must stay <= base * (1 + tol).
# "_meta" provenance never participates.
# ---------------------------------------------------------------------------

def _is_time_key(key: str) -> bool:
    # throughput rates ("cols_per_s") are higher-is-better and machine
    # bound — they are not wall-clock times and are not gated
    if key.endswith("_per_s"):
        return False
    return key.startswith("t_") or key.endswith("_s")


def _is_ratio_key(key: str) -> bool:
    # machine-portable higher-is-better metrics: batching speedups and the
    # packed-storage memory compression factor (dense bytes / store bytes)
    return (key == "speedup" or key.endswith("_speedup")
            or key == "mem_ratio")


def _walk(base, fresh, path: str, tolerance: float, include_times: bool,
          out: List[Dict]) -> None:
    if isinstance(base, dict):
        if not isinstance(fresh, dict):
            out.append({"path": path, "kind": "missing",
                        "detail": "baseline section absent from artifact"})
            return
        for key, bval in base.items():
            # "_"-prefixed sections (_meta, _schema) are provenance, and
            # "metrics" is the machine-specific obs block — neither is a
            # gated result, even when an old baseline happens to carry one
            if key.startswith("_") or key == "metrics":
                continue
            if key not in fresh:
                out.append({"path": f"{path}.{key}", "kind": "missing",
                            "detail": "metric absent from fresh artifact"})
                continue
            _walk(bval, fresh[key], f"{path}.{key}", tolerance,
                  include_times, out)
        return
    if not isinstance(base, (int, float)) or isinstance(base, bool):
        return
    key = path.rsplit(".", 1)[-1]
    if _is_ratio_key(key):
        floor = base * (1.0 - tolerance)
        if fresh < floor:
            out.append({"path": path, "kind": "ratio-regression",
                        "baseline": base, "fresh": fresh,
                        "detail": f"{fresh:.3f} < floor {floor:.3f} "
                                  f"(baseline {base:.3f}, tol {tolerance:.0%})"})
    elif include_times and _is_time_key(key):
        ceil = base * (1.0 + tolerance)
        if fresh > ceil:
            out.append({"path": path, "kind": "time-regression",
                        "baseline": base, "fresh": fresh,
                        "detail": f"{fresh:.4f}s > ceiling {ceil:.4f}s "
                                  f"(baseline {base:.4f}s, tol {tolerance:.0%})"})


def check_baselines(*, artifacts_dir: Optional[str] = None,
                    baseline_dir: Optional[str] = None,
                    tolerance: float = 0.25,
                    include_times: bool = False) -> List[Dict]:
    """Compare every committed baseline against its fresh artifact.

    Returns a list of violation records (empty == gate passes); a baseline
    whose artifact was never produced is itself a violation, so wiring rot
    fails loudly.
    """
    artifacts_dir = artifacts_dir or ARTIFACTS
    baseline_dir = baseline_dir or BASELINES
    violations: List[Dict] = []
    names = sorted(f for f in os.listdir(baseline_dir)
                   if f.endswith(".json")) if os.path.isdir(baseline_dir) else []
    if not names:
        return [{"path": baseline_dir, "kind": "missing",
                 "detail": "no committed baselines found"}]
    for fname in names:
        fresh_path = os.path.join(artifacts_dir, fname)
        with open(os.path.join(baseline_dir, fname)) as f:
            base = json.load(f)
        if not os.path.exists(fresh_path):
            violations.append({"path": fname, "kind": "missing",
                               "detail": "fresh artifact was not produced"})
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        _walk(base, fresh, fname.removesuffix(".json"), tolerance,
              include_times, violations)
    return violations


def load_datasets(codes: Iterable[str] | None = None):
    """Paper Table I analogues, reordered with RCM like the paper's ParMETIS
    preprocessing step (ordering quality differs; see DESIGN.md §8)."""
    from repro.sparse import paper_dataset_analogue, permute_csr, rcm_order
    from repro.sparse.matrices import PAPER_DATASETS

    out = {}
    for code in (codes or PAPER_DATASETS):
        a = paper_dataset_analogue(code)
        out[code] = permute_csr(a, rcm_order(a))
    return out


def timeit(fn: Callable, *, repeats: int = 3, warmup: int = 1,
           reduce: Callable = np.median) -> float:
    """Wall time of ``fn`` reduced over ``repeats`` runs.  ``reduce`` is
    ``np.median`` for reporting; pass ``min`` for *gated* comparisons
    (best-of-N is robust to CI load spikes where median-of-3 flaps)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(reduce(ts))


def print_table(title: str, header, rows) -> None:
    print(f"\n## {title}")
    print("| " + " | ".join(header) + " |")
    print("|" + "|".join(["---"] * len(header)) + "|")
    for r in rows:
        print("| " + " | ".join(str(x) for x in r) + " |")
