"""Shared benchmark helpers: dataset loading, timing, artifact output."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Iterable

import numpy as np

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")


def save_artifact(name: str, payload: Dict) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def load_datasets(codes: Iterable[str] | None = None):
    """Paper Table I analogues, reordered with RCM like the paper's ParMETIS
    preprocessing step (ordering quality differs; see DESIGN.md §8)."""
    from repro.sparse import paper_dataset_analogue, permute_csr, rcm_order
    from repro.sparse.matrices import PAPER_DATASETS

    out = {}
    for code in (codes or PAPER_DATASETS):
        a = paper_dataset_analogue(code)
        out[code] = permute_csr(a, rcm_order(a))
    return out


def timeit(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def print_table(title: str, header, rows) -> None:
    print(f"\n## {title}")
    print("| " + " | ".join(header) + " |")
    print("|" + "|".join(["---"] * len(header)) + "|")
    for r in rows:
        print("| " + " | ".join(str(x) for x in r) + " |")
