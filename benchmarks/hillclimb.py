"""Hillclimb harness (§Perf): lower one cell variant, print the three
roofline terms and the largest collectives with shapes — the 'profile' that
grounds each hypothesis (no real TPU, so the lowered IR is the evidence).

    PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen3-14b \
        --shape train_4k [--set key=value ...]

``--set`` patches ModelConfig fields (e.g. --set micro_steps=2
--set seq_shard_attention=True) so variants are reproducible one-liners.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import re
import sys


def top_collectives(hlo: str, k: int = 8):
    from repro.launch.costs import _COLL_RE, _shape_bytes
    items = []
    for m in _COLL_RE.finditer(hlo):
        items.append((_shape_bytes(m.group(1)), m.group(2)))
    items.sort(reverse=True)
    return items[:k]


def run_cell(arch: str, shape_name: str, patches: dict, dump_hlo: str = ""):
    import jax.numpy as jnp

    from repro.configs.base import SHAPES, get_config
    from repro.launch import costs as C
    from repro.launch.mesh import make_production_mesh
    from benchmarks.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops

    cfg = get_config(arch)
    if patches:
        cfg = dataclasses.replace(cfg, **patches)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    res = C.cell_costs(cfg, mesh, shape, dtype=jnp.bfloat16)
    tot = res["totals_per_device"]
    t_c = tot["flops"] / PEAK_FLOPS
    t_l = tot["collective_bytes"] / LINK_BW
    mf = model_flops(cfg, shape)
    n_dev = mesh.devices.size
    print(f"\n=== {arch} x {shape_name} patches={patches} ===")
    print(f"compute {t_c:.3f}s | collective {t_l:.3f}s | "
          f"flops/dev {tot['flops']:.3e} | coll GB/dev "
          f"{tot['collective_bytes']/1e9:.2f}")
    print(f"useful/HLO = {mf / max(1, tot['flops'] * n_dev) * 100:.1f}%  "
          f"bound-MFU = {mf / max(t_c, t_l) / (n_dev * PEAK_FLOPS) * 100:.2f}%")
    for name, comp in res["components"].items():
        if name == "ssm_scan_correction" or "collectives" not in comp:
            continue
        print(f"  [{name}] x{comp['multiplier']}  flops {comp['flops']:.3e}  "
              f"coll {comp['collectives']['total_bytes']/1e9:.3f} GB  "
              f"{comp['collectives']['counts_by_op']}")
    return res


def profile_component(arch: str, shape_name: str, patches: dict,
                      component: str = "group"):
    """Print the largest collectives (with shapes) of one component."""
    import jax.numpy as jnp

    from repro.configs.base import SHAPES, get_config
    from repro.launch import costs as C
    from repro.launch.mesh import make_production_mesh
    import jax

    cfg = get_config(arch)
    if patches:
        cfg = dataclasses.replace(cfg, **patches)
    shape = SHAPES[shape_name]
    micro = 1
    if shape.kind == "train":
        micro = max(1, cfg.micro_steps)
        while shape.global_batch % micro:
            micro //= 2
    eff = dataclasses.replace(shape, global_batch=shape.global_batch // micro)
    mesh = make_production_mesh()
    if component == "group":
        fn, structs, shards = C.group_component(cfg, mesh, eff, jnp.bfloat16, 1024)
    elif component == "stem_head":
        fn, structs, shards = C.stem_head_component(cfg, mesh, eff, jnp.bfloat16)
    else:
        fn, structs, shards = C.optimizer_component(cfg, mesh, jnp.bfloat16)
    hlo = jax.jit(fn, in_shardings=shards).lower(*structs).compile().as_text()
    print(f"--- top collectives in [{component}] ({arch} x {shape_name}) ---")
    for size, op in top_collectives(hlo, 12):
        print(f"  {size/1e6:9.1f} MB  {op}")
    return hlo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--profile", default="")
    args = ap.parse_args()
    patches = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        patches[k] = eval(v)  # noqa: S307 — operator tool, trusted input
    if args.profile:
        profile_component(args.arch, args.shape, patches, args.profile)
    else:
        run_cell(args.arch, args.shape, patches)


if __name__ == "__main__":
    main()
