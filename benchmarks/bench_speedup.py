"""Paper Fig 10: GSoFa vs the CPU symbolic factorization baseline.

The paper's baseline is SuperLU_DIST's parallel symbolic factorization (a
distributed fill2-family algorithm); ours is the faithful sequential fill2
(core/fill2.py) — the same algorithmic family on the same matrices, so the
ratio isolates what the paper's parallelization buys.  We report:

* wall-clock speedup of the batched fixpoint (all optimizations on) over
  sequential fill2 on this host, and
* the work ratio (edge checks), which is hardware-independent: the paper's
  fine-grained relaxation does MORE total work (re-visitation) but exposes
  the parallelism that wins on wide hardware.

Both implementations are verified to produce identical structures
(tests/test_gsofa_correctness.py); this benchmark is timing-only.
"""
from __future__ import annotations

from benchmarks.common import load_datasets, print_table, save_artifact, timeit
from repro.core.fill2 import fill2_all
from repro.core.gsofa import prepare_graph
from repro.core.multisource import run_multisource


def run(codes=("BC", "EP", "G7", "LH", "TT", "PR"), concurrency: int = 256) -> dict:
    results = {}
    rows = []
    for code, a in load_datasets(codes).items():
        graph = prepare_graph(a)
        t_gsofa = timeit(lambda: run_multisource(graph, concurrency=concurrency),
                         repeats=1)
        t_fill2 = timeit(lambda: fill2_all(a), repeats=1, warmup=0)
        ms = run_multisource(graph, concurrency=concurrency)
        _, f2_edges = fill2_all(a)
        r = {
            "n": a.n, "nnz": a.nnz,
            "t_gsofa_s": t_gsofa, "t_fill2_s": t_fill2,
            "speedup": t_fill2 / max(1e-9, t_gsofa),
            "gsofa_edge_checks": int(ms.edge_checks.sum()),
            "fill2_edge_checks": int(f2_edges.sum()),
            "work_ratio": float(ms.edge_checks.sum() / max(1, f2_edges.sum())),
            "lu_nnz": ms.total_nnz,
        }
        results[code] = r
        rows.append([code, a.n, f"{t_fill2*1e3:.0f}ms", f"{t_gsofa*1e3:.0f}ms",
                     f"{r['speedup']:.1f}x", f"{r['work_ratio']:.2f}x"])
    print_table("Fig 10 analogue — GSoFa vs sequential fill2 (this host)",
                ["dataset", "|V|", "fill2", "GSoFa", "speedup",
                 "work ratio (edge checks)"], rows)
    save_artifact("bench_speedup", results)
    return results


def main() -> None:
    run()


if __name__ == "__main__":
    main()
