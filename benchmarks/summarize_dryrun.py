"""Tabulate the dry-run artifacts into EXPERIMENTS.md §Dry-run form.

    PYTHONPATH=src python -m benchmarks.summarize_dryrun
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
OUT = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun_summary.md")


def main() -> None:
    rows, skips, errors = [], [], []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        rec = json.load(open(path))
        name = f"{rec.get('arch')} x {rec.get('shape')} x {rec.get('mesh')}"
        if "error" in rec:
            errors.append((name, rec["error"].splitlines()[-1][:120]))
            continue
        if "skipped" in rec:
            skips.append((name, rec["skipped"]))
            continue
        mem = rec["memory"]
        coll = rec["full_step"]["collectives"]["counts_by_op"]
        rows.append([
            name, rec.get("compile_s", "-"),
            f"{mem['argument_bytes']/1e9:.2f}",
            f"{mem['temp_bytes']/1e9:.2f}",
            f"{(mem['peak_bytes_est'])/1e9:.2f}",
            "Y" if mem["peak_bytes_est"] < 16e9 else "N",
            " ".join(f"{k.split('-')[0]}-{k.split('-')[1] if '-' in k else k}:{v}"
                     for k, v in sorted(coll.items())) or "-",
        ])

    lines = ["# Dry-run summary", "",
             "| cell | compile s | args GB/dev | temp GB/dev | peak GB/dev "
             "| fits 16G | collectives (full-step HLO, scan bodies once) |",
             "|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append("| " + " | ".join(str(x) for x in r) + " |")
    lines += ["", f"**Compiled cells: {len(rows)}  skips: {len(skips)}  "
              f"errors: {len(errors)}**", "", "## Documented skips", ""]
    lines += [f"* {n}: {why}" for n, why in skips]
    if errors:
        lines += ["", "## Errors", ""] + [f"* {n}: {e}" for n, e in errors]
    text = "\n".join(lines)
    with open(OUT, "w") as f:
        f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
