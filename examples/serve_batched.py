"""Batched serving example (deliverable b): prefill + greedy decode with a
fixed-shape continuous batch, on any of the ten architectures.

    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-7b
    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-4b

(Reduced configs so CPU runs in seconds; the same steps lower on the
512-chip production mesh in launch/dryrun.py.)  Shows that attention-cache,
MLA-latent, sliding-window-ring, and SSM-state serving all share one engine.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    args = ap.parse_args()
    sys.argv = ["serve", "--arch", args.arch, "--reduced",
                "--requests", "4", "--prompt-len", "24", "--gen-len", "12"]
    serve.main()


if __name__ == "__main__":
    main()
