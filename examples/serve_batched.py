"""Batched solver serving quickstart (DESIGN.md §14).

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --requests 64 --slots 16

A long-lived ``SolverEngine`` fields a stream of circuit-style solve
requests: a handful of sparsity patterns (netlists), many value sets each
(Newton iterations / Monte Carlo corners).  The engine content-hashes each
request's structure into its LRU plan cache — each pattern is analyzed
exactly once — and packs same-pattern requests into fixed-shape batched
``factorize_batch``/``solve_batch`` dispatches.  Every answer is
bitwise-identical to the sequential ``analyze``/``factorize``/``solve``
calls; the demo checks one request against the sequential path to prove it.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro
from repro.serve import SolverEngine
from repro.sparse import circuit_like, permute_csr, rcm_order
from repro.sparse.numeric import generic_values_csr


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--patterns", type=int, default=3,
                    help="distinct sparsity patterns (netlists)")
    ap.add_argument("--requests", type=int, default=24,
                    help="total solve requests across the patterns")
    ap.add_argument("--slots", type=int, default=8,
                    help="fixed batch width of each dispatch")
    ap.add_argument("--n", type=int, default=300, help="matrix dimension")
    args = ap.parse_args()

    mats = []
    for p in range(args.patterns):
        a = circuit_like(args.n, seed=100 + p)
        mats.append(permute_csr(a, rcm_order(a)))

    eng = SolverEngine(repro.LUOptions(concurrency=64, supernode_relax=2),
                       capacity=args.patterns, batch_slots=args.slots)
    rng = np.random.default_rng(0)

    t0 = time.perf_counter()
    submitted = []
    for r in range(args.requests):
        a = mats[r % len(mats)]
        values = generic_values_csr(a, seed=r)
        b = rng.standard_normal(a.n)
        submitted.append((eng.submit(a, values, b), a, values, b))
    results = eng.flush()
    elapsed = time.perf_counter() - t0

    worst = max(res.residual for res in results)
    s = eng.stats
    print(f"served {len(results)} requests over {args.patterns} patterns "
          f"in {elapsed:.3f}s ({len(results) / elapsed:.1f} solves/s)")
    print(f"plan cache: {int(s['cache_hits'])} hits / "
          f"{int(s['cache_misses'])} misses "
          f"(analyze {s['analyze_s']:.3f}s, paid once per pattern)")
    print(f"dispatches: {int(s['batches'])} batched sweeps of "
          f"{args.slots} slots ({int(s['padded_slots'])} padded)")
    print(f"worst relative residual: {worst:.3e}")

    # conformance spot-check: request 0 vs the sequential session API
    rid, a, values, b = submitted[0]
    seq = repro.analyze(
        a, repro.LUOptions(concurrency=64,
                           supernode_relax=2)).factorize(values).solve(b)
    res0 = next(r for r in results if r.rid == rid)
    assert np.array_equal(seq.x, res0.x), "engine diverged from session API"
    print("request 0 bitwise-identical to sequential analyze/factorize/solve")


if __name__ == "__main__":
    main()
