"""End-to-end training driver (deliverable b): the ~100M-class smollm-135m
architecture trained for a few hundred steps on the synthetic pipeline.

    PYTHONPATH=src python examples/train_smollm.py --steps 300

By default this runs the *reduced* config so CPU finishes in minutes while
exercising the full production path (sharded step, ZeRO-1 AdamW, remat,
checkpointing, restart).  Pass ``--full`` on real hardware for the actual
135M model.  Loss on the structured synthetic stream drops well below the
uniform floor ln(V), demonstrating real learning end to end.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ShapeConfig, get_config
from repro.data import make_batch_for
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.train.optimizer import AdamWConfig, init_adamw
from repro.train.steps import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_smollm_ckpt")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if not args.full:
        cfg = cfg.reduced()
    shape = ShapeConfig("ex", args.seq, args.batch, "train")
    mesh = make_host_mesh()
    acfg = AdamWConfig(lr=1e-3, warmup_steps=20, decay_steps=args.steps)
    step = make_train_step(cfg, mesh, shape, dtype=jnp.float32, acfg=acfg,
                           donate=False)
    params = tf.init_params(jax.random.key(0), cfg, jnp.float32)
    opt = init_adamw(params)
    print(f"training {cfg.name}{' (reduced)' if not args.full else ''}: "
          f"{tf.n_params(params):,} params, ln(V)={np.log(cfg.vocab):.2f}")

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    t0 = time.time()
    first = last = None
    for i in range(args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch_for(cfg, shape, step=i).items()}
        params, opt, m = step.fn(params, opt, batch)
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if (i + 1) % 25 == 0 or i == 0:
            print(f"step {i+1:4d}  loss {loss:.4f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
        if (i + 1) % 100 == 0:
            mgr.save(i + 1, (params, opt))
    mgr.wait()
    print(f"loss {first:.3f} -> {last:.3f} "
          f"(uniform floor ln V = {np.log(cfg.vocab):.3f})")
    # clear learning signal, scaled to the run length (full 300-step default
    # drops >0.5 nats; short smoke runs proportionally less)
    want = min(0.5, 0.004 * args.steps)
    assert last < first - want, f"expected loss drop > {want:.2f}"


if __name__ == "__main__":
    main()
