"""Quickstart: analyze once, refactorize many, solve multi-RHS.

    PYTHONPATH=src python examples/quickstart.py

Generates a circuit-simulation-like sparse matrix (the paper's dominant
application domain), reorders it (RCM), and runs the plan/factor session
API: ``repro.analyze`` performs GSoFa symbolic factorization ONCE — the
fixpoint streams out the L/U counts, the supernode panel partition, and the
sparse CSC pattern, and the plan precomputes every value-independent
structure (schedules, gather maps, packed-store template).  Each
``plan.factorize(values)`` is then only the numeric panel sweep (the
circuit-simulation refactorization regime), and ``factor.solve`` handles
single and multi-RHS systems with iterative refinement.  The symbolic
prediction is validated two independent ways along the way (sequential
fill2 and a numeric LU restricted to the pattern).
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro
from repro.core.fill2 import fill2_all
from repro.sparse import circuit_like, permute_csr, rcm_order
from repro.sparse.numeric import generic_values, validate_symbolic


def main() -> None:
    # 1. a circuit-like sparse matrix, fill-reducing reordered
    a = circuit_like(1500, seed=1)
    a = permute_csr(a, rcm_order(a))
    print(f"matrix: n={a.n} nnz={a.nnz}")

    # 2. analyze ONCE: symbolic factorization (the paper's contribution)
    #    with streamed supernode detection and CSC pattern extraction riding
    #    along on the same fixpoint chunks, plus every value-independent
    #    precomputation of the numeric pipeline
    #    (trace=True turns on the obs span tracing — DESIGN.md §12 — so
    #    step 6 can print where the time went; off, it costs one boolean)
    plan = repro.analyze(a, repro.LUOptions(concurrency=256, trace=True))
    sym = plan.sym
    print(f"L+U nonzeros: {sym.lu_nnz}  fill ratio: {sym.fill_ratio:.2f}")
    print(f"effective #C: {sym.concurrency}  supersteps: {sym.supersteps} "
          f"label re-inits: {sym.reinits}")
    print(f"supernodes: {plan.n_supernodes} "
          f"(mean size {sym.mean_supernode_size:.2f}) in "
          f"{plan.n_levels} dependency levels")
    print(f"analyze: {plan.analyze_s*1e3:.0f} ms (plan is picklable — cache "
          f"it and refactorize forever)")

    # 3a. validate against sequential fill2 (Rose & Tarjan)
    rows, _ = fill2_all(a)
    l_cnt = np.array([(r < i).sum() for i, r in enumerate(rows)])
    u_cnt = np.array([(r > i).sum() for i, r in enumerate(rows)])
    assert (l_cnt == sym.l_counts).all() and (u_cnt == sym.u_counts).all()
    print("fill2 agreement: OK")

    # 3b. validate by numeric factorization inside the predicted pattern
    #     (plan.pattern is the CSC structure streamed from the fixpoint)
    report = validate_symbolic(a, plan.pattern.to_dense())
    print(f"numeric LU within pattern: {'OK' if report['ok'] else 'FAIL'} "
          f"(missed {report['n_missed']}, spurious {report['n_spurious']})")

    # 4. refactorize: each new value set on the same pattern costs only the
    #    numeric panel sweep — packed O(nnz(L+U)) storage, no dense (n, n)
    #    working matrix, no schedule/map reconstruction
    values = generic_values(a)
    factor = plan.factorize(values)
    num = factor.num
    resid = np.abs(num.reconstruct() - values).max() / np.abs(values).max()
    print(f"factorize: {num.n_supernodes} panels, {num.n_updates} panel "
          f"updates ({num.gemm_flops/1e6:.1f} MFLOP of GEMMs) in "
          f"{factor.factor_s*1e3:.0f} ms")
    print(f"packed store: {num.store_entries} slots "
          f"({num.store.nbytes/1e6:.2f} MB vs {a.n*a.n*8/1e6:.0f} MB dense)")
    print(f"|LU - A| / |A| = {resid:.2e}")
    factor2 = plan.factorize(values * 1.7)     # new values, same structure
    print(f"refactorize (new values): {factor2.factor_s*1e3:.0f} ms")

    # 5. solve on the factors: supernodal triangular substitution +
    #    iterative refinement; b may be one RHS (n,) or a multi-RHS block
    #    (n, k) — k systems for one factorization
    rng = np.random.default_rng(0)
    b = rng.standard_normal((a.n, 4))
    sol = factor.solve(b, refine_tol=0.0)
    print(f"multi-RHS solve: x is {sol.x.shape}, worst ||Ax-b||/||b|| = "
          f"{sol.residual:.2e} after {sol.refine_accepted} refinement "
          f"step(s) in {sol.solve_s*1e3:.1f} ms "
          f"(history {['%.1e' % r for r in sol.residuals]})")

    # 6. where did the time go?  trace=True populated span-summary trees on
    #    the plan and on every factorization from it — the same spans a
    #    repro.obs.tracing("trace.json") block exports for Perfetto
    print("\nanalyze span tree (plan.stats):")
    print(plan.stats)
    print("\nfactorize span tree (factor.stats):")
    print(factor.stats)


if __name__ == "__main__":
    main()
