"""Quickstart: the paper's technique as a three-line API call.

    PYTHONPATH=src python examples/quickstart.py

Generates a circuit-simulation-like sparse matrix (the paper's dominant
application domain), reorders it (RCM), runs GSoFa symbolic factorization,
validates the predicted L/U structure two independent ways (sequential fill2
and a numeric LU restricted to the pattern), consumes the supernode panel
partition in the supernodal numeric factorization (packed O(nnz(L+U))
CSC-panel storage — no dense working matrix), and finishes with
``solve(a, b)``: supernodal triangular substitution plus iterative
refinement — the full symbolic -> numeric -> solve sparse pipeline.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import numeric_factorize, solve
from repro.core.fill2 import fill2_all
from repro.core.gsofa import dense_pattern, prepare_graph
from repro.core.symbolic import symbolic_factorize
from repro.sparse import circuit_like, permute_csr, rcm_order
from repro.sparse.numeric import generic_values, validate_symbolic


def main() -> None:
    # 1. a circuit-like sparse matrix, fill-reducing reordered
    a = circuit_like(1500, seed=1)
    a = permute_csr(a, rcm_order(a))
    print(f"matrix: n={a.n} nnz={a.nnz}")

    # 2. symbolic factorization (the paper's contribution), with streamed
    #    supernode detection riding along on the same fixpoint chunks
    res = symbolic_factorize(a, concurrency=256, detect_supernodes=True)
    print(f"L+U nonzeros: {res.lu_nnz}  fill ratio: {res.fill_ratio:.2f}")
    print(f"effective #C: {res.concurrency}  supersteps: {res.supersteps} "
          f"label re-inits: {res.reinits}")
    print(f"aux memory: {res.memory_report['aux_bytes']/1e6:.1f} MB "
          f"({res.memory_report['ratio']:.0f}x the matrix)")
    print(f"supernodes: {res.n_supernodes} "
          f"(mean size {res.mean_supernode_size:.2f}, "
          f"largest {int((res.supernodes[:,1]-res.supernodes[:,0]).max())})")
    print(f"elapsed: {res.elapsed_s*1e3:.0f} ms")

    # 3a. validate against sequential fill2 (Rose & Tarjan)
    rows, _ = fill2_all(a)
    l_cnt = np.array([(r < i).sum() for i, r in enumerate(rows)])
    u_cnt = np.array([(r > i).sum() for i, r in enumerate(rows)])
    assert (l_cnt == res.l_counts).all() and (u_cnt == res.u_counts).all()
    print("fill2 agreement: OK")

    # 3b. validate by numeric factorization inside the predicted pattern
    pattern = dense_pattern(prepare_graph(a), batch=256)
    report = validate_symbolic(a, pattern)
    print(f"numeric LU within pattern: {'OK' if report['ok'] else 'FAIL'} "
          f"(missed {report['n_missed']}, spurious {report['n_spurious']})")

    # 4. supernodal numeric factorization consuming the panel partition —
    #    factors live in packed CSC-panel storage sized by the prediction,
    #    not in a dense (n, n) working matrix
    values = generic_values(a)
    num = numeric_factorize(a, res, values=values, pattern=pattern)
    resid = np.abs(num.reconstruct() - values).max() / np.abs(values).max()
    print(f"supernodal numeric LU: {num.n_supernodes} panels in "
          f"{num.n_levels} dependency levels, {num.n_updates} panel updates "
          f"({num.gemm_flops/1e6:.1f} MFLOP of GEMMs)")
    print(f"packed store: {num.store_entries} slots "
          f"({num.store.nbytes/1e6:.2f} MB vs {a.n*a.n*8/1e6:.0f} MB dense)")
    print(f"|LU - A| / |A| = {resid:.2e}  "
          f"(elapsed {num.elapsed_s*1e3:.0f} ms)")

    # 5. end-to-end solve: supernodal triangular substitution on the packed
    #    factors + iterative refinement (refine_tol=0.0 shows the refinement
    #    history; the default stops as soon as the residual is <= 1e-14)
    b = np.random.default_rng(0).standard_normal(a.n)
    sol = solve(a, b, values=values, num=num, refine_tol=0.0)
    print(f"solve: ||Ax-b||/||b|| = {sol.residual:.2e} after "
          f"{sol.refine_accepted} refinement step(s) "
          f"(history {['%.1e' % r for r in sol.residuals]})")


if __name__ == "__main__":
    main()
