"""Distributed + fault-tolerant GSoFa (deliverable b, example 3).

    PYTHONPATH=src python examples/distributed_symbolic.py

Runs multi-source symbolic factorization through the full production
runtime: interleaved source sharding over every available device
(shard_map), the work-stealing DynamicScheduler with a simulated straggler
and an elastic device-count change, and chunk-level checkpoint/restart
(kill the run between chunks and resume without recomputation).
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.distributed import distributed_symbolic
from repro.core.gsofa import prepare_graph
from repro.core.symbolic import ChunkCheckpointer, symbolic_factorize
from repro.runtime.scheduler import DynamicScheduler
from repro.sparse import economic_like, permute_csr, rcm_order


def main() -> None:
    a = economic_like(1536, seed=7)
    a = permute_csr(a, rcm_order(a))
    graph = prepare_graph(a)
    print(f"matrix: n={a.n} nnz={a.nnz}; devices: {len(jax.devices())}")

    # 1. SPMD path: interleaved sources over the device mesh
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((len(jax.devices()),), ("src",))
    res = distributed_symbolic(graph, mesh, policy="interleave")
    print(f"distributed: balance ratio {res['balance_ratio']:.2f} "
          f"across {res['n_shards']} shard(s)")

    # 1b. the full distributed plan (DESIGN.md §11): sharded analyze ->
    # placed factorize -> placed solve, bitwise-identical to one device
    # (run under XLA_FLAGS=--xla_force_host_platform_device_count=8 to
    # see real sharding on CPU)
    import repro
    from repro.sparse.numeric import generic_values_csr

    plan = repro.analyze(a, repro.LUOptions(concurrency=256,
                                            distribute=True))
    factor = plan.factorize(generic_values_csr(a))
    b = np.random.default_rng(0).standard_normal(a.n)
    sol = factor.solve(b)
    print(f"plan: {plan.n_devices} device(s), {plan.n_supernodes} panels "
          f"in {plan.n_levels} levels, residual {sol.residual:.1e}")

    # 2. work-stealing scheduler with elastic shrink after 3 chunks
    sched = DynamicScheduler(graph, concurrency=128)
    out = sched.run(drop_devices_after=3)
    print(f"scheduler: {out['chunks']} chunks, {out['reissues']} re-issues, "
          f"elastic shrink exercised")

    # 3. checkpoint/restart: first run 'crashes' after a few chunks
    with tempfile.TemporaryDirectory() as d:
        ckpt = os.path.join(d, "chunks.jsonl")
        cp = ChunkCheckpointer(ckpt, a.n)
        full = symbolic_factorize(a, concurrency=256)
        # simulate partial progress: record only the first half of chunks
        for start in range(0, a.n // 2, 256):
            srcs = np.arange(start, min(start + 256, a.n))
            cp.record(start, srcs, full.l_counts[srcs], full.u_counts[srcs])
        resumed = symbolic_factorize(a, concurrency=256, checkpoint_path=ckpt)
        assert (resumed.l_counts == full.l_counts).all()
        assert (resumed.u_counts == full.u_counts).all()
        print("checkpoint/restart: resumed run matches uninterrupted run")

    print(f"L+U nnz = {full.lu_nnz}, fill ratio = {full.fill_ratio:.2f}")


if __name__ == "__main__":
    main()
