"""Plan/factor session API: analyze once, refactorize many, solve multi-RHS.

GSoFa's premise is that symbolic analysis is a separable, reusable phase.
The dominant sparse-LU workload in practice — circuit simulation per GLU3.0
(arXiv:1908.00204) and HYLU (arXiv:2509.07690) — factorizes the *same*
sparsity pattern hundreds of times with new values, so the public API is
built around that split (DESIGN.md §10)::

    import repro

    plan = repro.analyze(a, repro.LUOptions(supernode_relax=2))
    for values in value_stream:            # same pattern, new values
        factor = plan.factorize(values)    # numeric sweep only
        result = factor.solve(b)           # b is (n,) or multi-RHS (n, k)

``analyze`` runs the symbolic fixpoint + streamed supernode detection and
precomputes **everything value-independent**:

* the sparse ``CSCPattern`` of L+U, streamed straight from the fixpoint
  chunks (``core.symbolic.PatternCollector``) — no dense (n, n) pattern is
  ever materialized, at any n;
* the supernode panel partition and ``pack_panels`` bins;
* the factorization level schedule (panel elimination DAG);
* the per-panel sorted-row gather/scatter maps of every ancestor update
  (``schedule.build_gather_maps``) and the CSR value-scatter maps
  (``PanelStore.csr_maps``);
* the forward/backward solve-level DAGs (``build_solve_schedule``);
* a ``PanelStore`` structure template sized from the symbolic prediction.

``LUPlan.factorize(values)`` then runs only the value-dependent panel sweep
(scatter + level-scheduled GEMM updates) on a fresh set of block buffers
sharing the template's structure; ``LUFactorization.refactorize(values)``
goes one step further and reuses the same buffers in place.  Factors are
bitwise-identical to one-shot ``numeric_factorize`` by construction (shared
``factor_on_store`` engine).  Plans hold only numpy arrays and plain
dataclasses, so they pickle — analyses can be cached across processes.

Analysis and factorization distribute (DESIGN.md §11): pass a device mesh
(``launch.mesh.make_flat_mesh``) — or set ``LUOptions(distribute=True)``
to take every visible device — and the symbolic fixpoint shards its
sources over the mesh inside shard_map while the plan gains a
``PanelPlacement`` that splits every dependency level's panels into
per-device segments for factorize and solve.  Factors, solutions, panel
partitions, and patterns are **bitwise-identical at every device count**
(the `tests/test_distributed_plan.py` conformance tier runs {1, 2, 8}
forced host devices), and distributed plans still pickle.

The legacy one-shot trio (``repro.symbolic_factorize`` ->
``repro.numeric_factorize`` -> ``repro.solve``) was removed in 1.4.0
after its announced one-release ``DeprecationWarning`` period; the
engines remain importable from ``repro.core.symbolic`` and
``repro.numeric``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.core.symbolic import SymbolicResult
from repro.core.symbolic import symbolic_factorize as _symbolic_factorize
from repro.numeric.schedule import (
    PanelPlacement, PanelSchedule, build_gather_maps, build_placement,
    build_schedule,
)
from repro.numeric.solve import (
    BatchedSolveResult, SolveResult, SolveSchedule, build_solve_schedule,
)
from repro.numeric.solve import solve as _solve
from repro.numeric.solve import solve_batch as _solve_batch
from repro.numeric.storage import (
    BatchedPanelStore, CSCPattern, CsrScatterMaps, PanelStore,
)
from repro.numeric.supernodal import (
    BatchedNumericResult, NumericResult, factor_batch_on_store,
    factor_on_store,
)
from repro.obs import trace as _ot
from repro.obs.trace import SpanSummary
from repro.sparse.csr import CSRMatrix
from repro.sparse.numeric import generic_values_csr

_SYMBOLIC_BACKENDS = ("ell", "dense", "kernel")
_NUMERIC_BACKENDS = ("numpy", "kernel")
_POLICIES = ("lpt", "contiguous")
_RUNTIMES = ("static", "dynamic")
_PIVOTS = ("none", "static")


@dataclasses.dataclass(frozen=True)
class LUOptions:
    """Every knob of the symbolic -> numeric -> solve pipeline in one frozen
    object — replaces the kwarg sprawl the three-layer API used to thread.

    Symbolic fixpoint: ``concurrency`` (#C source chunk size), ``backend``
    (relaxation backend), ``combined`` (one batched fixpoint per chunk),
    ``bubble`` (label-window truncation), ``use_arena`` (label re-init
    elision), ``budget_bytes`` (memory envelope -> effective #C),
    ``checkpoint_path`` (per-chunk durable progress).

    Supernodes: ``supernode_relax`` (T3 merge tolerance, 0 = exact T2),
    ``supernode_max_size`` (panel width cap).

    Blocking / autotune (DESIGN.md §16): ``blocking=True`` runs the
    structure-aware irregular merge pass after detection — adjacent
    supernodes with nearly-overlapping row structures coalesce into one
    padded dense block when the roofline cost model says the flop/byte
    gain pays for the explicit zeros (``block_merge_threshold``, default
    1.0 = accept exactly the modeled wins; ``block_max_width`` caps the
    merged panel).  ``autotune=True`` goes further and sweeps
    ``supernode_relax``/``supernode_max_size`` candidates (re-detected
    from the retained fingerprints, no fixpoint re-run) through that
    merge pass, freezing the winning knobs — including a
    ``concurrency`` sized to the label-matrix byte budget — onto the
    plan's options (``LUPlan.tuned`` records the sweep).  Both off by
    default: the defaults are bitwise-identical to the unblocked
    pipeline; blocked partitions regroup float ops and carry
    dense-oracle parity instead.

    Numeric: ``n_bins``/``policy`` (pack_panels within-level grouping),
    ``numeric_backend`` ("numpy" float64 BLAS or "kernel" Pallas MXU),
    ``piv_tol`` (zero-pivot threshold; None = eps at matrix scale),
    ``check_pattern``/``pattern_tol`` (validate_symbolic contract).

    Solve: ``refine_iters``/``refine_tol`` (iterative refinement bounds).

    Robustness (DESIGN.md §15): ``pivot="static"`` adds the analyze-time
    maximum-product transversal + equilibration pre-pass (the factored
    system becomes ``Dr·P·A·Dc``, stored on the plan so refactorization
    stays value-only); ``perturb=True`` replaces tiny pivots
    (|piv| <= ``perturb_eps``·max|A|, default sqrt(machine eps)) with the
    signed threshold during the sweep instead of raising, counting them in
    ``NumericResult.perturbed_pivots`` — iterative refinement recovers the
    accuracy.  Both off by default: the defaults are bitwise-identical to
    the historical pipeline.

    Distribution: ``distribute=True`` makes ``analyze`` build a flat mesh
    over every visible device (``launch.mesh.make_flat_mesh``) when no
    explicit mesh is passed — the symbolic fixpoint shards its sources and
    the plan's panel placement splits level work per device (DESIGN.md
    §11); results are bitwise-identical at any device count.
    """

    # -- symbolic fixpoint
    concurrency: int = 128
    backend: str = "ell"
    combined: bool = True
    bubble: bool = False
    use_arena: bool = True
    budget_bytes: Optional[int] = None
    checkpoint_path: Optional[str] = None
    # -- supernode detection
    supernode_relax: int = 0
    supernode_max_size: int = 64
    # -- structure-aware blocking + roofline autotune (DESIGN.md §16);
    # both off by default (bitwise-identical to the unblocked pipeline)
    blocking: bool = False
    block_merge_threshold: Optional[float] = None   # None = 1.0 (model wins)
    block_max_width: int = 256
    autotune: bool = False
    # -- numeric factorization
    n_bins: int = 8
    policy: str = "lpt"
    numeric_backend: str = "numpy"
    piv_tol: Optional[float] = None
    check_pattern: bool = True
    pattern_tol: Optional[float] = None
    # batch same-shape panels of a (level, device) segment into one stacked
    # GEMM dispatch (DESIGN.md §13) — bitwise-identical to per-panel
    # dispatch; off restores the one-GEMM-per-panel sweep
    segment_batch: bool = True
    # -- solve / refinement
    refine_iters: int = 2
    refine_tol: Optional[float] = None
    # -- numerical robustness (DESIGN.md §15): static pivoting pre-pass at
    # analyze time + tiny-pivot perturbation during the sweep; both off by
    # default (bitwise-identical to the historical path)
    pivot: str = "none"
    perturb: bool = False
    perturb_eps: Optional[float] = None
    # -- distribution (DESIGN.md §11)
    distribute: bool = False
    # -- execution runtime (DESIGN.md §13): "static" = fixed chunk loop;
    # "dynamic" = work-stealing DynamicScheduler over the visible devices
    # (straggler re-issue, elastic join/leave), bitwise-identical outputs
    runtime: str = "static"
    # -- observability (DESIGN.md §12): record phase spans + counters for
    # this plan's analyze/factorize calls (repro.obs); plans/factors gain a
    # ``stats`` summary tree.  Off by default — the disabled path is a
    # module-level boolean check, so it cannot perturb timings.
    trace: bool = False

    def __post_init__(self):
        # Range-check the numeric knobs up front with actionable messages —
        # a bad value would otherwise surface deep inside the fixpoint
        # chunking or panel packing as an opaque shape/index error.
        if self.concurrency < 1:
            raise ValueError(
                f"concurrency must be >= 1 (source-chunk width of the "
                f"symbolic fixpoint), got {self.concurrency}")
        if self.supernode_max_size < 1:
            raise ValueError(
                f"supernode_max_size must be >= 1 (panel width cap; 1 "
                f"disables supernode fusion), got {self.supernode_max_size}")
        if self.supernode_relax < 0:
            raise ValueError(
                f"supernode_relax must be >= 0 (T3 merge tolerance; 0 is "
                f"exact T2), got {self.supernode_relax}")
        if self.n_bins < 1:
            raise ValueError(
                f"n_bins must be >= 1 (pack_panels bins per level), "
                f"got {self.n_bins}")
        if self.refine_iters < 0:
            raise ValueError(
                f"refine_iters must be >= 0 (0 disables iterative "
                f"refinement), got {self.refine_iters}")
        if self.budget_bytes is not None and self.budget_bytes < 1:
            raise ValueError(
                f"budget_bytes must be >= 1 when set (memory envelope for "
                f"the fixpoint working set), got {self.budget_bytes}")
        if self.block_max_width < 1:
            raise ValueError(
                f"block_max_width must be >= 1 (merged-panel column cap "
                f"for blocking/autotune), got {self.block_max_width}")
        if (self.block_merge_threshold is not None
                and not self.block_merge_threshold > 0.0):
            raise ValueError(
                f"block_merge_threshold must be > 0 when set (1.0 accepts "
                f"exactly the modeled wins; larger merges more "
                f"aggressively), got {self.block_merge_threshold!r}")
        if self.backend not in _SYMBOLIC_BACKENDS:
            raise ValueError(f"unknown symbolic backend {self.backend!r}; "
                             f"pick from {_SYMBOLIC_BACKENDS}")
        if self.numeric_backend not in _NUMERIC_BACKENDS:
            raise ValueError(f"unknown numeric backend "
                             f"{self.numeric_backend!r}; pick from "
                             f"{_NUMERIC_BACKENDS}")
        if self.policy not in _POLICIES:
            raise ValueError(f"unknown packing policy {self.policy!r}; "
                             f"pick from {_POLICIES}")
        if self.runtime not in _RUNTIMES:
            raise ValueError(f"unknown runtime {self.runtime!r}; "
                             f"pick from {_RUNTIMES}")
        if self.pivot not in _PIVOTS:
            raise ValueError(f"unknown pivot mode {self.pivot!r}; "
                             f"pick from {_PIVOTS}")
        if self.perturb_eps is not None and not self.perturb_eps > 0.0:
            raise ValueError(f"perturb_eps must be positive, got "
                             f"{self.perturb_eps!r}")
        if self.runtime == "dynamic" and self.distribute:
            raise ValueError(
                "runtime='dynamic' is the host-driven scheduler over the "
                "visible devices and cannot be combined with "
                "distribute=True (the shard_map mesh) — drop one")

    def replace(self, **changes) -> "LUOptions":
        """A copy with ``changes`` applied (frozen-dataclass convenience)."""
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass
class LUFactorization:
    """Numeric factors of one value set on a plan's structure.

    ``solve`` runs supernodal substitution + refinement on the packed
    factors (single (n,) or multi-RHS (n, k)); ``refactorize`` overwrites
    *this* factorization's buffers with a new value set (in-place reuse —
    the previous factors become invalid; use ``plan.factorize`` for
    independent factor objects).
    """

    plan: "LUPlan"
    num: NumericResult
    values: np.ndarray           # ORIGINAL values (refinement matvec)
    factor_s: float              # scatter + panel-sweep wall time
    # span summary of this factorization (tracing enabled only): the same
    # spans the Chrome trace carries, rendered as a text tree by ``str()``
    stats: Optional[SpanSummary] = None
    # the values actually swept: ``RobustPlan.transform_values(values)``
    # under static pivoting, ``values`` itself otherwise (same object)
    factored_values: Optional[np.ndarray] = None
    _quality: Optional[object] = dataclasses.field(default=None, repr=False)

    @property
    def n(self) -> int:
        return self.num.n

    @property
    def store(self) -> PanelStore:
        return self.num.store

    @property
    def l(self) -> np.ndarray:
        """Dense unit-lower L — test/oracle reconstruction helper."""
        return self.num.l

    @property
    def u(self) -> np.ndarray:
        """Dense upper U — test/oracle reconstruction helper."""
        return self.num.u

    def solve(self, b: np.ndarray, *, refine_iters: Optional[int] = None,
              refine_tol: Optional[float] = None,
              batched: Optional[bool] = None) -> SolveResult:
        """Solve A x = b on the existing factors.  ``b`` is (n,) or
        (n, k); refinement knobs default to the plan's ``LUOptions``.
        ``batched=None`` auto-picks the level-batched diagonal-solve path
        for multi-RHS ``b`` (one vmapped call per level-width group); the
        substitution sweeps keep the plan's per-device segments either
        way.  ``SolveResult.factor_s`` is 0.0 — the factorization time
        lives on this object's ``factor_s``."""
        opts = self.plan.options
        return _solve(
            self.plan.a, b, values=self.values, num=self.num,
            refine_iters=(opts.refine_iters if refine_iters is None
                          else refine_iters),
            refine_tol=opts.refine_tol if refine_tol is None else refine_tol,
            batched=batched, transform=self.plan.robust)

    @property
    def perturbed_pivots(self) -> int:
        """Tiny pivots bumped by the robust tier during this sweep."""
        return self.num.perturbed_pivots

    def quality(self, *, itmax: int = 5):
        """Trust certificate of these factors (DESIGN.md §15): element
        growth, Hager 1-norm condition estimate of the factored system, and
        an "ok"/"suspect"/"reject" verdict.  A few triangular solves on the
        packed factors — computed lazily and cached on this object."""
        if self._quality is None:
            from repro.robust.condition import estimate_quality

            fvals = (self.factored_values if self.factored_values is not None
                     else self.values)
            self._quality = estimate_quality(
                self.num, self.plan.a_factored, fvals,
                perturbed_pivots=self.num.perturbed_pivots, itmax=itmax)
        return self._quality

    def refactorize(self, values: np.ndarray) -> "LUFactorization":
        """Factor a new value set **in place** on this factorization's
        buffers (zero + rescatter + panel sweep; no allocation)."""
        return self.plan.factorize(values, _reuse_store=self.num.store)


@dataclasses.dataclass
class BatchedLUFactorization:
    """Factors of B same-pattern value sets in one batched sweep
    (DESIGN.md §14) — the many-matrix tier of the session API.

    ``solve_batch`` runs the substitution level sweeps + iterative
    refinement across all B systems at once; ``system(i)`` exposes system
    i as an ordinary ``LUFactorization`` over zero-copy views of the
    batched buffers, so everything downstream of the sequential API
    (solve, dense oracle reconstruction) works per system.  Every per-
    system result is bitwise-identical to the sequential
    ``plan.factorize(values_batch[i])`` / ``.solve(b[i])`` loop.
    """

    plan: "LUPlan"
    num: BatchedNumericResult
    values: np.ndarray           # (B, nnz) ORIGINAL values
    factor_s: float              # scatter + batched panel-sweep wall time
    stats: Optional[SpanSummary] = None
    factored_values: Optional[np.ndarray] = None   # (B, nnz) swept values

    @property
    def batch(self) -> int:
        return self.num.batch

    @property
    def n(self) -> int:
        return self.num.n

    @property
    def store(self) -> BatchedPanelStore:
        return self.num.store

    @property
    def perturbed_pivots(self) -> np.ndarray:
        """Per-system tiny-pivot bump counts, (B,) int64 (all zero unless
        the plan was built with ``LUOptions(perturb=True)``)."""
        pp = self.num.perturbed_pivots
        return (pp if pp is not None
                else np.zeros(self.batch, dtype=np.int64))

    def system(self, i: int) -> LUFactorization:
        """System i as a sequential ``LUFactorization`` (zero-copy factor
        views; its ``factor_s`` is 0.0 — the batch owns the timing)."""
        return LUFactorization(
            plan=self.plan, num=self.num.system(i),
            values=self.values[i], factor_s=0.0,
            factored_values=(self.factored_values[i]
                             if self.factored_values is not None else None))

    def solve_batch(self, b: np.ndarray, *,
                    refine_iters: Optional[int] = None,
                    refine_tol: Optional[float] = None
                    ) -> BatchedSolveResult:
        """Solve A_i x_i = b_i for every system on the existing factors.
        ``b`` is (B, n) or (B, n, k); refinement knobs default to the
        plan's ``LUOptions``.  Refinement masks per system, so each
        system's solution and residual history match the sequential
        ``factor.solve`` loop bitwise."""
        opts = self.plan.options
        return _solve_batch(
            self.plan.a, b, self.values, self.num,
            refine_iters=(opts.refine_iters if refine_iters is None
                          else refine_iters),
            refine_tol=opts.refine_tol if refine_tol is None else refine_tol,
            transform=self.plan.robust)


@dataclasses.dataclass
class LUPlan:
    """One matrix structure, analyzed once: the symbolic prediction plus
    every value-independent precomputation of the numeric pipeline.

    Plans are picklable (numpy arrays + plain dataclasses only), so an
    analysis can be computed in one process and reused in many — the
    refactorization server pattern.  ``factorize(values)`` is the only
    per-value work: O(nnz) scatter + the level-scheduled panel sweep.
    """

    a: CSRMatrix
    options: LUOptions
    sym: SymbolicResult
    pattern: CSCPattern
    schedule: PanelSchedule
    store_template: PanelStore
    gather_maps: List
    csr_maps: CsrScatterMaps
    solve_schedule: SolveSchedule
    analyze_s: float
    # device placement of panel work (DESIGN.md §11): plain numpy, so the
    # plan pickles; the mesh itself is never stored — rebuild one with
    # ``launch.mesh.make_flat_mesh`` where live devices are needed
    placement: Optional[PanelPlacement] = None
    # span summary of the analyze that built this plan (tracing enabled
    # only); picklable like everything else on the plan
    stats: Optional[SpanSummary] = None
    # static-pivoting state (DESIGN.md §15, ``LUOptions(pivot="static")``):
    # the ``RobustPlan`` transform and the permuted structural matrix the
    # symbolic analysis actually ran on.  Plain numpy — the plan pickles.
    robust: Optional[object] = None
    factored: Optional[CSRMatrix] = None
    # autotune record (DESIGN.md §16, ``LUOptions(autotune=True)``): the
    # ``tune.TuneReport`` whose chosen knob values are frozen into
    # ``options`` — picklable, so a loaded plan replays without re-tuning
    tuned: Optional[object] = None

    @property
    def a_factored(self) -> CSRMatrix:
        """The structural matrix the factors describe: ``Dr·P·A·Dc``'s
        pattern under static pivoting, ``a`` itself otherwise."""
        return self.factored if self.factored is not None else self.a

    @property
    def n(self) -> int:
        return self.a.n

    @property
    def n_devices(self) -> int:
        return self.placement.n_devices if self.placement is not None else 1

    @property
    def lu_nnz(self) -> int:
        """Predicted structural nonzeros of L+U (diagonal included)."""
        return self.pattern.nnz

    @property
    def n_supernodes(self) -> int:
        return self.schedule.n_panels

    @property
    def n_levels(self) -> int:
        return self.schedule.n_levels

    def place(self, n_devices: Optional[int] = None, *,
              policy: str = "lpt") -> "LUPlan":
        """Re-derive the panel placement for ``n_devices`` (DESIGN.md §13).

        Placement is a *derived* property of the schedule, not a frozen
        analyze-time fact: re-binning every dependency level's panels via
        ``numeric.schedule.build_placement`` adapts a pickled plan to
        whatever mesh exists where it is loaded — a plan analyzed at D=8
        runs on 1, 2, or 200 devices.  ``n_devices=None`` takes the
        visible device count (``launch.mesh.visible_device_count``).
        Within a level panels are independent, so placement changes
        scheduling only — factors and solutions stay bitwise-identical at
        every count.  Returns ``self`` (placement is replaced in place) so
        ``pickle.load(f).place().factorize(v)`` chains.
        """
        if n_devices is None:
            from repro.launch.mesh import visible_device_count

            n_devices = visible_device_count()
        from repro.launch.mesh import FLAT_AXIS

        self.placement = build_placement(self.schedule, n_devices,
                                         axis=FLAT_AXIS, policy=policy)
        return self

    def factorize(self, values: Optional[np.ndarray] = None, *,
                  _reuse_store: Optional[PanelStore] = None
                  ) -> LUFactorization:
        """Numeric factorization of ``values`` (CSR-aligned (nnz,) or dense
        (n, n); defaults to ``generic_values_csr``) on the precomputed
        structure — no schedule/store/map reconstruction.  Bitwise-identical
        factors to one-shot ``numeric_factorize`` on the same inputs."""
        t0 = time.perf_counter()
        if values is None:
            values = generic_values_csr(self.a)
        values = np.asarray(values, dtype=np.float64)
        if self.robust is not None:
            # replay the static-pivoting transform: O(nnz) gather + scale
            # (value-only — no symbolic work on refactorize)
            fvals = (self.robust.transform_dense(values) if values.ndim == 2
                     else self.robust.transform_values(values))
        else:
            fvals = values
        store = (_reuse_store if _reuse_store is not None
                 else PanelStore.from_structure(self.store_template))
        store._solve_schedule = self.solve_schedule
        store._placement = self.placement       # per-device solve segments
        with _ot.ensure(self.options.trace) as tr:
            mark = tr.mark() if tr is not None else 0
            with _ot.span("factorize"):
                num = factor_on_store(
                    self.a_factored, fvals, store, self.schedule,
                    backend=self.options.numeric_backend,
                    piv_tol=self.options.piv_tol,
                    check_pattern=self.options.check_pattern,
                    pattern_tol=self.options.pattern_tol,
                    maps=self.gather_maps, csr_maps=self.csr_maps,
                    store_is_zeroed=_reuse_store is None,
                    placement=self.placement,
                    segment_batch=self.options.segment_batch,
                    perturb=self.options.perturb,
                    perturb_eps=self.options.perturb_eps)
            stats = tr.summary(mark) if tr is not None else None
        return LUFactorization(plan=self, num=num, values=values,
                               factor_s=time.perf_counter() - t0,
                               stats=stats, factored_values=fvals)

    def factorize_batch(self, values_batch: np.ndarray
                        ) -> BatchedLUFactorization:
        """Numeric factorization of B same-pattern value sets in ONE
        batched level sweep (DESIGN.md §14): ``values_batch`` is a
        (B, nnz) CSR-aligned stack; every per-panel operation of the
        sweep broadcasts over the leading system axis, so the per-call
        Python/scheduling overhead is paid once for the whole batch —
        the circuit-simulation regime (Newton iterations, transient
        sweeps, Monte Carlo corners sharing one pattern).

        System i's factors are bitwise-identical to
        ``self.factorize(values_batch[i])`` — property-tested across
        every ``sparse/matrices.py`` generator."""
        t0 = time.perf_counter()
        values_batch = np.asarray(values_batch, dtype=np.float64)
        if values_batch.ndim != 2:
            raise ValueError(
                f"values_batch must be a (B, {self.a.nnz}) CSR-aligned "
                f"stack, got shape {values_batch.shape}")
        fvals_batch = (self.robust.transform_values(values_batch)
                       if self.robust is not None else values_batch)
        bstore = BatchedPanelStore(self.store_template,
                                   values_batch.shape[0])
        # solve_batch levels come from the plan, cached where the batched
        # substitution looks for them (the shared structure template)
        self.store_template._solve_schedule = self.solve_schedule
        with _ot.ensure(self.options.trace) as tr:
            mark = tr.mark() if tr is not None else 0
            with _ot.span("factorize_batch"):
                num = factor_batch_on_store(
                    self.a_factored, fvals_batch, bstore, self.schedule,
                    backend=self.options.numeric_backend,
                    piv_tol=self.options.piv_tol,
                    check_pattern=self.options.check_pattern,
                    pattern_tol=self.options.pattern_tol,
                    maps=self.gather_maps, csr_maps=self.csr_maps,
                    store_is_zeroed=True,
                    perturb=self.options.perturb,
                    perturb_eps=self.options.perturb_eps)
            stats = tr.summary(mark) if tr is not None else None
        return BatchedLUFactorization(plan=self, num=num,
                                      values=values_batch,
                                      factor_s=time.perf_counter() - t0,
                                      stats=stats,
                                      factored_values=fvals_batch)

    def solve(self, b: np.ndarray,
              values: Optional[np.ndarray] = None) -> SolveResult:
        """Convenience: factorize ``values`` and solve in one call (the
        result's ``factor_s``/``solve_s`` split stays honest)."""
        factor = self.factorize(values)
        res = factor.solve(b)
        res.factor_s = factor.factor_s
        return res


def _partition_with_blocking(pattern, supernodes, fingerprints, opts,
                             peaks):
    """Apply autotune / structure-aware blocking to a detected partition.

    Returns ``(supernodes, tuned, opts)``: the (possibly merged) partition,
    the ``TuneReport`` when autotuning ran, and the options with any chosen
    knob values frozen in.  A no-op (same objects back) when both knobs are
    off — the default path never touches the new code.
    """
    tuned = None
    if opts.autotune:
        from repro.tune import autotune_partition

        supernodes, tuned = autotune_partition(pattern, fingerprints, opts,
                                               peaks=peaks)
        opts = opts.replace(**tuned.chosen)
    elif opts.blocking:
        from repro.supernodes.blocking import merge_supernodes
        from repro.tune import cost_model_for

        threshold = (1.0 if opts.block_merge_threshold is None
                     else opts.block_merge_threshold)
        supernodes, _ = merge_supernodes(
            pattern, supernodes, cost_model_for(opts, peaks),
            threshold=threshold, max_width=opts.block_max_width)
    return supernodes, tuned, opts


def analyze(a: CSRMatrix, options: Optional[LUOptions] = None, *,
            values: Optional[np.ndarray] = None,
            mesh=None, on_progress=None, peaks=None) -> LUPlan:
    """Symbolic analysis of ``a``: one fixpoint pass streams out the L/U
    counts, the supernode partition (fingerprints), and the sparse
    ``CSCPattern``; everything value-independent downstream (schedules,
    row-index gather maps, CSR scatter maps, store structure, solve DAGs)
    is precomputed into the returned ``LUPlan``.

    ``mesh`` (a ``jax.sharding.Mesh``; ``launch.mesh.make_flat_mesh``
    builds the flat one) shards the fixpoint's sources over the mesh
    devices inside shard_map and attaches a ``PanelPlacement`` that splits
    every level's panel work into per-device segments (DESIGN.md §11).
    ``LUOptions(distribute=True)`` builds the all-device flat mesh
    automatically.  The same code path runs at every device count —
    counts, supernodes, pattern, factors, and solutions are
    bitwise-identical to the mesh-less analysis, and the plan still
    pickles (it stores the placement, never the mesh).

    This never materializes a dense (n, n) pattern on the host *or on any
    shard* — memory stays O(nnz(L+U)) plus the streamed chunk masks, so
    it scales to the packed numeric path's n (tens of thousands and up).

    With ``LUOptions(pivot="static")`` the robust pre-pass runs first
    (DESIGN.md §15): a maximum-product transversal over ``values``
    (a *representative* value set — defaults to ``generic_values_csr(a)``,
    which weights pattern structure only; pass real values for
    value-informed pivoting) picks the row permutation, Ruiz equilibration
    the scalings, and the symbolic fixpoint + everything downstream run on
    the permuted pattern.  The transform is a plan property
    (``LUPlan.robust``), so refactorization remains a value-only O(nnz)
    gather + scale.

    With ``LUOptions(blocking=True)`` / ``LUOptions(autotune=True)`` the
    detected supernode partition additionally runs through the
    structure-aware blocking merge pass / roofline knob sweep (DESIGN.md
    §16) before schedules and storage are built; ``peaks`` optionally
    feeds the cost model a probed ``benchmarks/roofline.py``
    ``machine_peaks()`` dict (fixed representative constants otherwise, so
    tuning stays deterministic).  ``repro.replan`` re-derives all of this
    on an existing plan without re-running the fixpoint.
    """
    t0 = time.perf_counter()
    opts = options if options is not None else LUOptions()
    if mesh is None and opts.distribute:
        from repro.launch.mesh import make_flat_mesh

        mesh = make_flat_mesh()
    robust = None
    a_sym = a
    with _ot.ensure(opts.trace) as tr:
        mark = tr.mark() if tr is not None else 0
        if opts.pivot == "static":
            from repro.robust import build_robust_prepass

            with _ot.span("robust_prepass"):
                pivot_values = (values if values is not None
                                else generic_values_csr(a))
                a_sym, robust = build_robust_prepass(a, pivot_values)
        with _ot.span("analyze"):
            sym = _symbolic_factorize(
                a_sym, concurrency=opts.concurrency, backend=opts.backend,
                combined=opts.combined, bubble=opts.bubble,
                use_arena=opts.use_arena, budget_bytes=opts.budget_bytes,
                checkpoint_path=opts.checkpoint_path,
                detect_supernodes=True,
                supernode_relax=opts.supernode_relax,
                supernode_max_size=opts.supernode_max_size,
                collect_pattern=True, mesh=mesh, runtime=opts.runtime,
                on_progress=on_progress)
            pattern = sym.pattern
            supernodes, tuned, opts = _partition_with_blocking(
                pattern, sym.supernodes, sym.fingerprints, opts, peaks)
            with _ot.span("build_schedule"):
                schedule = build_schedule(pattern, supernodes,
                                          n_bins=opts.n_bins,
                                          policy=opts.policy)
                store_template = PanelStore(pattern, schedule.supernodes)
            with _ot.span("gather_maps"):
                gather_maps = build_gather_maps(store_template, schedule)
                csr_maps = store_template.csr_maps(a_sym)
            with _ot.span("solve_schedule"):
                solve_schedule = build_solve_schedule(store_template)
            placement = None
            if mesh is not None:
                n_devices = int(np.prod(list(mesh.shape.values())))
                placement = build_placement(schedule, n_devices,
                                            axis=mesh.axis_names[0])
            elif opts.runtime == "dynamic":
                # the dynamic runtime drove every visible device through
                # the analyze; give factorize/solve the matching per-device
                # segments (re-derivable later at any count via ``place``)
                from repro.launch.mesh import FLAT_AXIS, visible_device_count

                placement = build_placement(schedule,
                                            visible_device_count(),
                                            axis=FLAT_AXIS)
        stats = tr.summary(mark) if tr is not None else None
    return LUPlan(a=a, options=opts, sym=sym, pattern=pattern,
                  schedule=schedule, store_template=store_template,
                  gather_maps=gather_maps, csr_maps=csr_maps,
                  solve_schedule=solve_schedule,
                  analyze_s=time.perf_counter() - t0,
                  placement=placement, stats=stats,
                  robust=robust,
                  factored=a_sym if robust is not None else None,
                  tuned=tuned)


def replan(plan: LUPlan, options: Optional[LUOptions] = None, *,
           peaks=None) -> LUPlan:
    """Re-derive a plan under new partition knobs WITHOUT re-running the
    symbolic fixpoint (DESIGN.md §16).

    The expensive part of ``analyze`` is the label fixpoint; the supernode
    partition, schedules, gather/scatter maps, storage template, and solve
    DAGs are all cheap derivations from the retained O(n) column
    fingerprints and the sparse pattern.  ``replan`` re-runs exactly those
    derivations for ``options`` (defaults to the plan's own) — including
    the blocking merge pass and the autotune sweep — so comparing blocked
    vs. unblocked partitions, or autotuning a plan analyzed with defaults,
    costs seconds instead of the full analyze.  Returns a NEW independent
    ``LUPlan`` (the input plan is untouched); with knobs equal to the
    plan's own, the result factorizes bitwise-identically.

    Placement is re-derived at the plan's device count when one exists.
    Raises ``ValueError`` for plans pickled before fingerprint retention
    (pre-v1.7.0).
    """
    t0 = time.perf_counter()
    opts = options if options is not None else plan.options
    fp = getattr(plan.sym, "fingerprints", None)
    if fp is None:
        raise ValueError(
            "plan retains no column fingerprints (analyzed before v1.7.0, "
            "or symbolic ran without supernode detection); re-run "
            "repro.analyze() to rebuild it")
    pattern = plan.pattern
    with _ot.ensure(opts.trace) as tr:
        mark = tr.mark() if tr is not None else 0
        with _ot.span("replan"):
            from repro.supernodes.detect import detect_from_fingerprints

            supernodes = detect_from_fingerprints(
                fp, relax=opts.supernode_relax,
                max_size=opts.supernode_max_size)
            supernodes, tuned, opts = _partition_with_blocking(
                pattern, supernodes, fp, opts, peaks)
            with _ot.span("build_schedule"):
                schedule = build_schedule(pattern, supernodes,
                                          n_bins=opts.n_bins,
                                          policy=opts.policy)
                store_template = PanelStore(pattern, schedule.supernodes)
            with _ot.span("gather_maps"):
                gather_maps = build_gather_maps(store_template, schedule)
                csr_maps = store_template.csr_maps(plan.a_factored)
            with _ot.span("solve_schedule"):
                solve_schedule = build_solve_schedule(store_template)
            placement = None
            if plan.placement is not None:
                placement = build_placement(schedule,
                                            plan.placement.n_devices,
                                            axis=plan.placement.axis)
        stats = tr.summary(mark) if tr is not None else None
    return LUPlan(a=plan.a, options=opts, sym=plan.sym, pattern=pattern,
                  schedule=schedule, store_template=store_template,
                  gather_maps=gather_maps, csr_maps=csr_maps,
                  solve_schedule=solve_schedule,
                  analyze_s=plan.analyze_s + (time.perf_counter() - t0),
                  placement=placement, stats=stats,
                  robust=plan.robust, factored=plan.factored,
                  tuned=tuned)
