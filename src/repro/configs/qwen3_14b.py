"""Assigned architecture config (definition in archs.py)."""
from repro.configs.archs import qwen3_14b as CONFIG

__all__ = ["CONFIG"]
