"""Assigned architecture config (definition in archs.py)."""
from repro.configs.archs import rwkv6_7b as CONFIG

__all__ = ["CONFIG"]
