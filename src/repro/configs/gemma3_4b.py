"""Assigned architecture config (definition in archs.py)."""
from repro.configs.archs import gemma3_4b as CONFIG

__all__ = ["CONFIG"]
