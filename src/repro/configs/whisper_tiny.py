"""Assigned architecture config (definition in archs.py)."""
from repro.configs.archs import whisper_tiny as CONFIG

__all__ = ["CONFIG"]
