"""Assigned architecture config (definition in archs.py)."""
from repro.configs.archs import jamba_1_5_large as CONFIG

__all__ = ["CONFIG"]
