"""Config schema + registry for the assigned architectures and shapes.

One ``ModelConfig`` describes any of the ten families (dense / MoE / MLA /
SSM / hybrid / enc-dec / VLM backbone) via the ``pattern`` of per-layer
(mixer, ffn) kinds that the scan-over-groups transformer consumes
(models/transformer.py).  ``reduced()`` derives the CPU-smoke-test variant.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

# mixer kinds: "attn" (global), "local" (sliding window), "mla", "rwkv6", "mamba"
# ffn kinds:   "mlp" (swiglu), "moe", "none"
LayerKind = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int              # per-expert ffn hidden
    n_shared: int = 0          # shared (always-on) experts, deepseek-style
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention dims."""
    q_lora_rank: int
    kv_lora_rank: int
    rope_head_dim: int
    nope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    # rwkv6: head_size; mamba: d_state/expand/conv
    head_size: int = 64
    d_state: int = 16
    expand: int = 2
    d_conv: int = 4


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int
    enc_len: int               # precomputed frame embeddings (frontend stub)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # layer pattern, repeated to n_layers; default all ("attn", "mlp")
    pattern: Tuple[LayerKind, ...] = (("attn", "mlp"),)
    sliding_window: int = 1024
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: SSMConfig = SSMConfig()
    encdec: Optional[EncDecConfig] = None
    n_patches: int = 0                      # vlm: prepended patch embeddings
    norm_eps: float = 1e-6
    # distribution knobs (baseline; hillclimb may override)
    fsdp_axes: Tuple[str, ...] = ("data",)
    remat: bool = True
    layer_remat: bool = False               # nested per-layer remat (long patterns)
    micro_steps: int = 1                    # gradient-accumulation microbatches
    # activation sharding between layers: "rep" (replicated over model — the
    # Megatron default), "seq" (sequence dim over model — Megatron-SP),
    # "d" (hidden dim over model), "off" (let GSPMD propagate freely)
    act_shard: str = "rep"
    # shard the SDPA q-chunks over 'model' (wins when n_heads % tp != 0 and
    # head-TP is impossible; see EXPERIMENTS.md §Perf)
    seq_shard_attention: bool = False
    # zero-pad the query-head count to a TP-friendly multiple: wq/wo carry
    # zero blocks for the padded heads (their contribution is exactly zero),
    # head tensors become divisible by the model axis, and the backward-pass
    # resharding all-gathers at the head-reshape boundary disappear
    # (EXPERIMENTS.md §Perf, hillclimb #1)
    padded_heads: Optional[int] = None
    sub_quadratic: bool = False             # eligible for long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def hp(self) -> int:
        """Padded query-head count (== n_heads unless padded_heads set)."""
        return self.padded_heads or self.n_heads

    @property
    def full_pattern(self) -> Tuple[LayerKind, ...]:
        reps = self.n_layers // len(self.pattern)
        assert reps * len(self.pattern) == self.n_layers, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern {len(self.pattern)}")
        return self.pattern

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND roofline."""
        d, hd = self.d_model, self.hd
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.n_patches:
            total += self.n_patches * d
        if self.encdec:
            e = self.encdec
            enc_attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
            enc_mlp = 3 * d * self.d_ff
            total += e.n_enc_layers * (enc_attn + enc_mlp)
        for mixer, ffn in self.full_pattern:
            count = 0
            if mixer in ("attn", "local"):
                count += d * (self.n_heads * hd)            # q
                count += 2 * d * (self.n_kv_heads * hd)     # k, v
                count += (self.n_heads * hd) * d            # o
                if self.encdec:                             # cross-attn in decoder
                    count += d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                        + (self.n_heads * hd) * d
            elif mixer == "mla":
                m = self.mla
                count += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                    m.nope_head_dim + m.rope_head_dim)
                count += d * (m.kv_lora_rank + m.rope_head_dim)
                count += (m.kv_lora_rank * self.n_heads
                          * (m.nope_head_dim + m.v_head_dim))
                count += self.n_heads * m.v_head_dim * d
            elif mixer == "rwkv6":
                count += 5 * d * d + 2 * d * 64  # r,k,v,g,o + decay lora
            elif mixer == "mamba":
                di = self.ssm.expand * d
                count += 2 * d * di + di * d                # in (x,z), out
                count += di * (2 * self.ssm.d_state + 1)    # B, C, dt per channel-ish
                count += di * self.ssm.d_conv + 2 * di      # conv + A, D
            if ffn == "mlp":
                count += 3 * d * self.d_ff
            elif ffn == "moe":
                count += d * self.moe.n_experts             # router
                count += self.moe.n_experts * 3 * d * self.moe.d_expert
                count += self.moe.n_shared * 3 * d * self.moe.d_expert
            total += count * self.n_groups
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        per_expert = 3 * self.d_model * self.moe.d_expert
        n_moe_layers = (sum(1 for _, f in self.full_pattern if f == "moe")
                        * self.n_groups)
        inactive = n_moe_layers * (self.moe.n_experts - self.moe.top_k) * per_expert
        return full - inactive

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant: same family/pattern wiring, tiny dims."""
        changes: Dict = dict(
            n_layers=2 * len(self.pattern),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 * self.n_kv_heads // max(1, self.n_heads)),
            d_ff=128,
            vocab=256,
            head_dim=16,
            sliding_window=8,
            padded_heads=None,      # TP-16 head padding is meaningless at smoke scale
        )
        if self.moe:
            # capacity_factor high enough to never drop at smoke scale:
            # capacity drops are load-dependent, which would make the
            # decode-vs-teacher-forcing exactness tests flaky by design
            changes["moe"] = MoEConfig(n_experts=4, top_k=2, d_expert=32,
                                       n_shared=self.moe.n_shared and 1,
                                       capacity_factor=8.0)
        if self.mla:
            changes["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                       rope_head_dim=8, nope_head_dim=16, v_head_dim=16)
        if self.encdec:
            changes["encdec"] = EncDecConfig(n_enc_layers=2, enc_len=16)
        if self.n_patches:
            changes["n_patches"] = 8
        changes["ssm"] = SSMConfig(head_size=16, d_state=4, expand=2, d_conv=4)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        import repro.configs.archs  # noqa: F401  (populates the registry)
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    if not _REGISTRY:
        import repro.configs.archs  # noqa: F401
    return dict(_REGISTRY)


def cell_is_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §6 skip list)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full attention at 500k context (documented skip)"
    return True, ""
