"""Assigned architecture config (definition in archs.py)."""
from repro.configs.archs import smollm_135m as CONFIG

__all__ = ["CONFIG"]
