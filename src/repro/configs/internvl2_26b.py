"""Assigned architecture config (definition in archs.py)."""
from repro.configs.archs import internvl2_26b as CONFIG

__all__ = ["CONFIG"]
