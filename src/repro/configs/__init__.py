"""Architecture + shape configs."""
from repro.configs.base import (
    ModelConfig, ShapeConfig, SHAPES, get_config, all_configs, cell_is_supported,
)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config",
           "all_configs", "cell_is_supported"]
