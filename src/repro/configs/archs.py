"""The ten assigned architectures, exact dims from the assignment table.

Each also has a ``reduced()`` smoke variant (tests/test_models_smoke.py) and is
selectable via ``--arch <name>`` in the launch drivers.  Deviations from the
upstream checkpoints are noted inline and in DESIGN.md §6/§8.
"""
from repro.configs.base import (
    EncDecConfig, MLAConfig, ModelConfig, MoEConfig, SSMConfig, register,
)

L, G = ("local", "mlp"), ("attn", "mlp")

internvl2_26b = register(ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92553, head_dim=128, rope_theta=1e6,
    n_patches=256,      # InternViT frontend STUB: precomputed patch embeddings
    micro_steps=8,
))

whisper_tiny = register(ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab=51865, head_dim=64,
    encdec=EncDecConfig(n_enc_layers=4, enc_len=1500),  # conv frontend STUB
))

rwkv6_7b = register(ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab=65536, head_dim=64,
    pattern=(("rwkv6", "mlp"),),
    ssm=SSMConfig(head_size=64),
    micro_steps=2,
    sub_quadratic=True,          # O(1) state -> runs long_500k
))

# 34 layers at ~5:1 local:global (pattern period 17 = 14 local + 3 global,
# matching gemma3's interleave as closely as 34 admits); 1024-token window.
gemma3_4b = register(ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
    vocab=262144, head_dim=256, rope_theta=1e6, tie_embeddings=True,
    padded_heads=16,   # 8 heads -> TP-divisible
    pattern=(L, L, L, L, L, G, L, L, L, L, L, G, L, L, L, L, G),
    sliding_window=1024,
    micro_steps=4, layer_remat=True,
    sub_quadratic=True,          # sliding-window local layers bound the cache
))

qwen3_1_7b = register(ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=6144,
    vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    tie_embeddings=True,
    micro_steps=2,
))

smollm_135m = register(ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
    vocab=49152, head_dim=64, tie_embeddings=True,
    padded_heads=16,   # 9 heads: shard SDPA 16-way instead of replicating
))

qwen3_14b = register(ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=17408,
    vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    micro_steps=4,
    padded_heads=48,   # 40 heads % 16-way TP != 0 -> zero-pad (EXPERIMENTS §Perf)
))

moonshot_v1_16b = register(ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=163840, head_dim=128,
    pattern=(("attn", "moe"),),
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    micro_steps=4,
))

# MLA + 1 shared + 256 routed top-8.  Deviations: MTP head omitted; the
# first-3-dense-layers nuance folded into uniform MoE (DESIGN.md §8).
deepseek_v3_671b = register(ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab=129280,
    pattern=(("mla", "moe"),),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1),
    micro_steps=8,
    fsdp_axes=("pod", "data"),   # 671B must shard params over all 512 chips
))

# attn:mamba 1:7, MoE every other layer (period-8 block).
jamba_1_5_large = register(ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, head_dim=128,
    pattern=(("attn", "moe"), ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"),
             ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp")),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576),
    ssm=SSMConfig(d_state=16, expand=2, d_conv=4),
    micro_steps=8, layer_remat=True,
    fsdp_axes=("pod", "data"),
    sub_quadratic=True,          # 63/72 layers are O(1)-state mamba
))

ALL_ARCHS = [
    "internvl2-26b", "whisper-tiny", "rwkv6-7b", "gemma3-4b", "qwen3-1.7b",
    "smollm-135m", "qwen3-14b", "moonshot-v1-16b-a3b", "deepseek-v3-671b",
    "jamba-1.5-large-398b",
]
