"""Assigned architecture config (definition in archs.py)."""
from repro.configs.archs import qwen3_1_7b as CONFIG

__all__ = ["CONFIG"]
