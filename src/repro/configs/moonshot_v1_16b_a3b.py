"""Assigned architecture config (definition in archs.py)."""
from repro.configs.archs import moonshot_v1_16b as CONFIG

__all__ = ["CONFIG"]
