"""Assigned architecture config (definition in archs.py)."""
from repro.configs.archs import deepseek_v3_671b as CONFIG

__all__ = ["CONFIG"]
