"""repro: GSoFa (scalable sparse symbolic LU factorization) as a JAX framework.

Layers: core (the paper's algorithm), sparse (matrix substrate), numeric
(supernodal numeric LU consuming the symbolic panel partition), kernels
(Pallas TPU), models/train/data/checkpoint/runtime (LM framework substrate),
configs + launch (architectures, production mesh, dry-run drivers).

The end-to-end sparse LU entry points are re-exported lazily::

    from repro import solve, symbolic_factorize, numeric_factorize
    sym = symbolic_factorize(a, detect_supernodes=True)
    num = numeric_factorize(a, sym)     # O(nnz(L+U)) packed factors
    res = solve(a, b, sym=sym)          # x + relative-residual history
"""
__version__ = "1.2.0"

_LAZY_EXPORTS = {
    "symbolic_factorize": "repro.core.symbolic",
    "SymbolicResult": "repro.core.symbolic",
    "numeric_factorize": "repro.numeric",
    "NumericResult": "repro.numeric",
    "solve": "repro.numeric",
    "SolveResult": "repro.numeric",
    "PanelStore": "repro.numeric",
    "CSCPattern": "repro.numeric",
    "ZeroPivotError": "repro.sparse.numeric",
    "CSRMatrix": "repro.sparse",
}

__all__ = ["__version__", *_LAZY_EXPORTS]


def __getattr__(name):
    if name in _LAZY_EXPORTS:
        import importlib

        return getattr(importlib.import_module(_LAZY_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
