"""repro: GSoFa (scalable sparse symbolic LU factorization) as a JAX framework.

Layers: core (the paper's algorithm), sparse (matrix substrate), numeric
(supernodal numeric LU consuming the symbolic panel partition), kernels
(Pallas TPU), models/train/data/checkpoint/runtime (LM framework substrate),
configs + launch (architectures, production mesh, dry-run drivers).

The public entry point is the plan/factor session API (``repro.api``,
DESIGN.md §10): analyze a structure once, refactorize it many times with
new values, solve single or multi-RHS systems on the factors::

    import repro

    plan = repro.analyze(a, repro.LUOptions(supernode_relax=2))
    factor = plan.factorize(values)        # numeric sweep only
    result = factor.solve(b)               # b: (n,) or (n, k)

The legacy one-shot trio (``symbolic_factorize`` -> ``numeric_factorize``
-> ``solve``) still works for one release behind ``DeprecationWarning``
shims with bitwise-identical results.
"""
__version__ = "1.3.0"

_LAZY_EXPORTS = {
    # plan/factor session API (the supported surface)
    "analyze": "repro.api",
    "LUOptions": "repro.api",
    "LUPlan": "repro.api",
    "LUFactorization": "repro.api",
    # deprecated one-shot shims (DeprecationWarning for one release)
    "symbolic_factorize": "repro.api",
    "numeric_factorize": "repro.api",
    "solve": "repro.api",
    # result / substrate types
    "SymbolicResult": "repro.core.symbolic",
    "NumericResult": "repro.numeric",
    "SolveResult": "repro.numeric",
    "PanelStore": "repro.numeric",
    "CSCPattern": "repro.numeric",
    "ZeroPivotError": "repro.sparse.numeric",
    "CSRMatrix": "repro.sparse",
}

__all__ = ["__version__", *_LAZY_EXPORTS]


def __getattr__(name):
    if name in _LAZY_EXPORTS:
        import importlib

        return getattr(importlib.import_module(_LAZY_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
