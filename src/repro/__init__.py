"""repro: GSoFa (scalable sparse symbolic LU factorization) as a JAX framework.

Layers: core (the paper's algorithm), sparse (matrix substrate), kernels
(Pallas TPU), models/train/data/checkpoint/runtime (LM framework substrate),
configs + launch (architectures, production mesh, dry-run drivers).
"""
__version__ = "1.0.0"
