"""repro: GSoFa (scalable sparse symbolic LU factorization) as a JAX framework.

Layers: core (the paper's algorithm), sparse (matrix substrate), numeric
(supernodal numeric LU consuming the symbolic panel partition), kernels
(Pallas TPU), models/train/data/checkpoint/runtime (LM framework substrate),
configs + launch (architectures, production mesh, dry-run drivers).

The public entry point is the plan/factor session API (``repro.api``,
DESIGN.md §10-§11): analyze a structure once, refactorize it many times
with new values, solve single or multi-RHS systems on the factors — on
one device or with sources and panel work sharded over a device mesh::

    import repro

    plan = repro.analyze(a, repro.LUOptions(supernode_relax=2,
                                            distribute=True))
    factor = plan.factorize(values)        # numeric sweep only
    result = factor.solve(b)               # b: (n,) or (n, k)

The legacy one-shot trio (``symbolic_factorize`` -> ``numeric_factorize``
-> ``solve``) was removed in 1.4.0 after its announced one-release
``DeprecationWarning`` period; the engines remain importable from
``repro.core.symbolic`` and ``repro.numeric``.
"""
__version__ = "1.7.0"

_LAZY_EXPORTS = {
    # plan/factor session API (the supported surface)
    "analyze": "repro.api",
    "replan": "repro.api",
    "LUOptions": "repro.api",
    "LUPlan": "repro.api",
    "LUFactorization": "repro.api",
    "BatchedLUFactorization": "repro.api",
    # roofline autotune + structure-aware blocking (DESIGN.md §16)
    "RooflineCostModel": "repro.tune",
    "TuneReport": "repro.tune",
    "BlockingStats": "repro.supernodes",
    # serving front end (DESIGN.md §14)
    "SolverEngine": "repro.serve",
    "PlanCache": "repro.serve",
    "pattern_fingerprint": "repro.serve",
    # numerical robustness tier (DESIGN.md §15)
    "RobustPlan": "repro.robust",
    "QualityReport": "repro.robust",
    "StructurallySingularError": "repro.robust",
    # result / substrate types
    "SymbolicResult": "repro.core.symbolic",
    "NumericResult": "repro.numeric",
    "BatchedNumericResult": "repro.numeric",
    "SolveResult": "repro.numeric",
    "BatchedSolveResult": "repro.numeric",
    "PanelStore": "repro.numeric",
    "BatchedPanelStore": "repro.numeric",
    "PanelPlacement": "repro.numeric",
    "CSCPattern": "repro.numeric",
    "ZeroPivotError": "repro.sparse.numeric",
    "CSRMatrix": "repro.sparse",
}

__all__ = ["__version__", "obs", *_LAZY_EXPORTS]


def __getattr__(name):
    import importlib

    if name == "obs":
        # the observability subsystem is addressed as a module
        # (``repro.obs.tracing`` / ``repro.obs.registry``, DESIGN.md §12)
        return importlib.import_module("repro.obs")
    if name in _LAZY_EXPORTS:
        return getattr(importlib.import_module(_LAZY_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
