"""Checkpointing: sharded save/restore, retention, async writes, elastic
re-sharding onto a different mesh."""
from repro.checkpoint.io import (
    CheckpointManager, load_checkpoint, reshard_checkpoint, save_checkpoint,
)

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "reshard_checkpoint"]
