"""Sharded checkpoint I/O (no external deps — npz shards + JSON manifest).

Layout of one checkpoint directory::

    step_000123/
      manifest.json       # pytree structure, leaf paths, shapes, dtypes, step
      arrays.npz          # one entry per leaf (flattened path -> ndarray)
      done                # commit marker — written last (atomic completion)

Fault tolerance contract: a crash mid-write leaves no ``done`` marker, so
``latest_step`` never picks a torn checkpoint and restart falls back to the
previous complete one.  ``CheckpointManager`` adds retention, async writes
(the save runs on a worker thread off the training loop — the host-side
analogue of overlapping checkpoint I/O with compute), and data-pipeline
state capture.

Elastic scaling: ``reshard_checkpoint`` loads leaves host-side and
``device_put``s them under a *different* mesh/sharding — checkpoints are
mesh-independent by construction since we store full logical arrays.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory: str, step: int, tree, *,
                    extra: Optional[Dict] = None) -> str:
    """Write one complete checkpoint; returns its path."""
    path = os.path.join(directory, f"step_{step:09d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    # commit marker last: readers only trust directories containing it
    with open(os.path.join(path, "done"), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "done")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(directory: str, template, *, step: Optional[int] = None
                    ) -> Tuple[Any, int, Dict]:
    """Load into the structure of ``template``; returns (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_t = _flatten(template)
    leaves = []
    for key in flat_t:
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(data[key])
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, manifest["step"], manifest.get("extra", {})


def reshard_checkpoint(directory: str, template, shardings, *,
                       step: Optional[int] = None):
    """Elastic restart: place a checkpoint onto a (possibly different) mesh.

    The checkpoint stores full logical arrays, so re-sharding is a
    device_put under the target sharding — works across mesh shapes and
    device counts (e.g. resume a 512-chip run on 256 chips).
    """
    tree, step_loaded, extra = load_checkpoint(directory, template, step=step)
    placed = jax.tree.map(jax.device_put, tree, shardings)
    return placed, step_loaded, extra


class CheckpointManager:
    """Retention + async saves + pipeline-state capture."""

    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._worker: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _save(self, step: int, tree, extra):
        save_checkpoint(self.directory, step, tree, extra=extra)
        self._gc()

    def save(self, step: int, tree, *, extra: Optional[Dict] = None) -> None:
        tree = jax.tree.map(np.asarray, tree)   # snapshot off-device first
        self.wait()
        if self.async_save:
            self._worker = threading.Thread(
                target=self._save, args=(step, tree, extra), daemon=True)
            self._worker.start()
        else:
            self._save(step, tree, extra)

    def restore(self, template, *, shardings=None, step: Optional[int] = None):
        self.wait()
        if shardings is not None:
            return reshard_checkpoint(self.directory, template, shardings,
                                      step=step)
        return load_checkpoint(self.directory, template, step=step)

    def latest_step(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
