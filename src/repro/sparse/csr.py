"""CSR sparse-matrix container and conversions used by the symbolic-factorization core.

The graph G(A) of a square sparse matrix A has an edge u -> w for every structural
nonzero A[u, w] with u != w (diagonal entries are self-loops and are dropped — the
paper does the same, Fig 1).  The GSoFa fixpoint consumes the *in-neighbor* lists
(transpose graph) in padded ELL form so that one relaxation superstep is a dense
gather + masked min, which is the TPU-idiomatic shape (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """Structural CSR (pattern only — symbolic factorization ignores values)."""

    n: int
    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (nnz,) int32, column ids, sorted within each row

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def row(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i]:self.indptr[i + 1]]

    def out_degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def to_dense(self) -> np.ndarray:
        dense = np.zeros((self.n, self.n), dtype=bool)
        for i in range(self.n):
            dense[i, self.row(i)] = True
        return dense

    def struct_symmetry(self) -> float:
        """Fraction of off-diagonal nonzeros whose transpose position is
        also nonzero."""
        d = self.to_dense()
        np.fill_diagonal(d, False)
        total = int(d.sum())
        if total == 0:
            return 1.0
        return float((d & d.T).sum()) / total

    def validate(self) -> None:
        assert self.indptr.shape == (self.n + 1,)
        assert self.indptr[0] == 0 and self.indptr[-1] == len(self.indices)
        assert np.all(np.diff(self.indptr) >= 0)
        if len(self.indices):
            assert self.indices.min() >= 0 and self.indices.max() < self.n
        for i in range(self.n):
            r = self.row(i)
            assert np.all(np.diff(r) > 0), f"row {i} not strictly sorted"


def csr_from_coo(n: int, rows: np.ndarray, cols: np.ndarray, *,
                 drop_diagonal: bool = False) -> CSRMatrix:
    """Build a deduplicated, row-sorted structural CSR from COO index lists."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    if drop_diagonal:
        keep = rows != cols
        rows, cols = rows[keep], cols[keep]
    # dedup via linear keys
    keys = rows * n + cols
    keys = np.unique(keys)
    rows, cols = keys // n, keys % n
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRMatrix(n=n, indptr=indptr, indices=cols.astype(np.int32))


def csr_from_dense(dense: np.ndarray, *, drop_diagonal: bool = False) -> CSRMatrix:
    dense = np.asarray(dense) != 0
    rows, cols = np.nonzero(dense)
    return csr_from_coo(dense.shape[0], rows, cols, drop_diagonal=drop_diagonal)


def transpose_csr(a: CSRMatrix) -> CSRMatrix:
    """Pattern transpose (gives the in-neighbor graph)."""
    rows = np.repeat(np.arange(a.n, dtype=np.int64), np.diff(a.indptr))
    return csr_from_coo(a.n, a.indices.astype(np.int64), rows)


def csr_to_ell(a: CSRMatrix, *, pad_value: int | None = None,
               drop_diagonal: bool = True) -> Tuple[np.ndarray, int]:
    """Convert to padded ELL: (n, K) int32 neighbor table.

    ``pad_value`` defaults to ``n`` — the GSoFa relaxation masks neighbors with
    ``u < src``; since ``src < n`` always, a pad id of ``n`` is masked for free.
    """
    if pad_value is None:
        pad_value = a.n
    rows = []
    kmax = 1
    for i in range(a.n):
        r = a.row(i)
        if drop_diagonal:
            r = r[r != i]
        rows.append(r)
        kmax = max(kmax, len(r))
    ell = np.full((a.n, kmax), pad_value, dtype=np.int32)
    for i, r in enumerate(rows):
        ell[i, : len(r)] = r
    return ell, kmax


def drop_diagonal_csr(a: CSRMatrix) -> CSRMatrix:
    rows = np.repeat(np.arange(a.n, dtype=np.int64), np.diff(a.indptr))
    return csr_from_coo(a.n, rows, a.indices.astype(np.int64), drop_diagonal=True)


def union_csr(a: CSRMatrix, b: CSRMatrix) -> CSRMatrix:
    assert a.n == b.n
    ra = np.repeat(np.arange(a.n, dtype=np.int64), np.diff(a.indptr))
    rb = np.repeat(np.arange(b.n, dtype=np.int64), np.diff(b.indptr))
    return csr_from_coo(a.n, np.concatenate([ra, rb]),
                        np.concatenate([a.indices.astype(np.int64),
                                        b.indices.astype(np.int64)]))


def dense_block_adjacency(a: CSRMatrix, block: int, *,
                          transpose: bool = True) -> np.ndarray:
    """Dense (n_pad, n_pad) uint8 adjacency, padded up to a multiple of ``block``.

    ``adj[u, v] == 1`` iff edge u -> v (in the *original* orientation when
    ``transpose=False``; the relaxation kernel wants in-edges as rows of the
    u-axis so the default materializes A's own orientation: row u lists the
    vertices v that u points to — the kernel reduces over u).
    """
    n_pad = ((a.n + block - 1) // block) * block
    adj = np.zeros((n_pad, n_pad), dtype=np.uint8)
    for u in range(a.n):
        r = a.row(u)
        r = r[r != u]
        adj[u, r] = 1
    if transpose:
        pass  # row u -> columns v is already the reduce-over-u layout
    return adj
