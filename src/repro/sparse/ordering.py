"""Fill-reducing orderings.

The paper reorders with ParMETIS before symbolic factorization.  Ordering quality
is orthogonal to the symbolic *algorithm* (DESIGN.md §8.5); we provide RCM (via
scipy), natural, and random orderings so benchmarks can show the algorithm across
ordering regimes.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import reverse_cuthill_mckee

from repro.sparse.csr import CSRMatrix, csr_from_coo


def _to_scipy(a: CSRMatrix) -> sp.csr_matrix:
    data = np.ones(a.nnz, dtype=np.float32)
    return sp.csr_matrix((data, a.indices.astype(np.int64), a.indptr), shape=(a.n, a.n))


def natural_order(a: CSRMatrix) -> np.ndarray:
    return np.arange(a.n, dtype=np.int64)


def random_order(a: CSRMatrix, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(a.n).astype(np.int64)


def rcm_order(a: CSRMatrix) -> np.ndarray:
    """Reverse Cuthill-McKee on the symmetrized pattern (standard practice for
    nonsymmetric LU: order A + A^T)."""
    s = _to_scipy(a)
    sym = ((s + s.T) > 0).astype(np.float32)
    perm = reverse_cuthill_mckee(sp.csr_matrix(sym), symmetric_mode=True)
    return np.asarray(perm, dtype=np.int64)


def permute_csr(a: CSRMatrix, perm: np.ndarray) -> CSRMatrix:
    """Symmetric permutation: B = P A P^T, with
    B[new_i, new_j] = A[perm[new_i], perm[new_j]]."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(a.n, dtype=np.int64)
    rows = np.repeat(np.arange(a.n, dtype=np.int64), np.diff(a.indptr))
    cols = a.indices.astype(np.int64)
    return csr_from_coo(a.n, inv[rows], inv[cols])
