"""Numeric left-looking LU restricted to a predicted symbolic pattern.

End-to-end validation of the symbolic step (DESIGN.md §2): factorize a matrix
with generic values *inside* the predicted fill pattern and assert that no
update ever lands outside it.  With generic (random) values, accidental
cancellation has probability zero, so pattern(LU) == predicted pattern.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.sparse.csr import CSRMatrix


def generic_values(a: CSRMatrix, seed: int = 0) -> np.ndarray:
    """Dense matrix with random values on A's pattern, diagonally dominant so
    pivot-free elimination is numerically safe."""
    rng = np.random.default_rng(seed)
    dense = np.zeros((a.n, a.n), dtype=np.float64)
    for i in range(a.n):
        cols = a.row(i)
        dense[i, cols] = rng.uniform(0.5, 1.5, size=len(cols))
    np.fill_diagonal(dense, np.abs(dense).sum(axis=1) + 1.0)
    return dense


def lu_nopivot(dense: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Plain right-looking LU without pivoting. Returns (L with unit diag, U)."""
    n = dense.shape[0]
    m = dense.astype(np.float64).copy()
    for k in range(n - 1):
        piv = m[k, k]
        m[k + 1:, k] /= piv
        m[k + 1:, k + 1:] -= np.outer(m[k + 1:, k], m[k, k + 1:])
    l = np.tril(m, -1) + np.eye(n)
    u = np.triu(m)
    return l, u


def factor_pattern(dense: np.ndarray, tol: float = 1e-12) -> np.ndarray:
    """Boolean pattern of L+U after elimination (excluding the unit diagonal of L)."""
    l, u = lu_nopivot(dense)
    filled = (np.abs(np.tril(l, -1)) > tol) | (np.abs(u) > tol)
    return filled


def validate_symbolic(a: CSRMatrix, predicted: np.ndarray, seed: int = 0) -> dict:
    """Factorize with generic values and compare against the predicted pattern.

    ``predicted``: dense bool (n, n), True where the symbolic step predicts a
    structural nonzero of L+U (original entries included).  Returns a report
    with both inclusion directions.
    """
    dense = generic_values(a, seed=seed)
    actual = factor_pattern(dense)
    np.fill_diagonal(actual, True)
    pred = predicted.copy()
    np.fill_diagonal(pred, True)
    missed = actual & ~pred       # fatal: numeric fill the symbolic step missed
    spurious = pred & ~actual     # benign only if caused by exact cancellation
    return {
        "ok": not missed.any(),
        "exact": not missed.any() and not spurious.any(),
        "n_missed": int(missed.sum()),
        "n_spurious": int(spurious.sum()),
        "nnz_actual": int(actual.sum()),
        "nnz_predicted": int(pred.sum()),
    }
