"""Numeric left-looking LU restricted to a predicted symbolic pattern.

End-to-end validation of the symbolic step (DESIGN.md §2): factorize a matrix
with generic values *inside* the predicted fill pattern and assert that no
update ever lands outside it.  With generic (random) values, accidental
cancellation has probability zero, so pattern(LU) == predicted pattern.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.sparse.csr import CSRMatrix


class ZeroPivotError(ArithmeticError):
    """No-pivot elimination hit a zero / near-zero / non-finite pivot.

    Raised instead of letting numpy divide through (a silent RuntimeWarning
    that propagates inf/NaN into factor_pattern / validate_symbolic verdicts
    on non-diagonally-dominant inputs).  ``k`` is the global pivot column;
    the sweep that owns the failure annotates where it happened:
    ``panel``/``level`` from the supernodal level schedule, and ``system``
    (the batch index) when the batched-systems tier trips it.
    """

    def __init__(self, k: int, piv: float, tol: float, *,
                 panel: int | None = None, level: int | None = None,
                 system: int | None = None):
        self.k = int(k)
        self.piv = float(piv)
        self.tol = float(tol)
        self.panel = None if panel is None else int(panel)
        self.level = None if level is None else int(level)
        self.system = None if system is None else int(system)
        super().__init__(self._message())

    def _message(self) -> str:
        where = "".join(
            f" {name} {val}" for name, val in
            (("panel", self.panel), ("level", self.level),
             ("system", self.system)) if val is not None)
        return (f"zero pivot at column {self.k}"
                + (f" [{where.strip()}]" if where else "")
                + f": |{self.piv:.3e}| <= tol {self.tol:.3e} "
                f"(matrix needs pivoting or is singular; "
                f"LUOptions(pivot='static', perturb=True) enables the "
                f"robust tier)")

    def with_context(self, *, panel: int | None = None,
                     level: int | None = None,
                     system: int | None = None) -> "ZeroPivotError":
        """Annotate in-flight attribution (sweep loops know panel/level; the
        inner kernels don't) and refresh the message.  Returns ``self`` so
        callers can ``raise e.with_context(...)`` without a new traceback."""
        if panel is not None:
            self.panel = int(panel)
        if level is not None:
            self.level = int(level)
        if system is not None:
            self.system = int(system)
        self.args = (self._message(),)
        return self


def pivot_tolerance(scale: float) -> float:
    """Default near-zero pivot threshold: machine epsilon at the matrix scale."""
    return np.finfo(np.float64).eps * max(float(scale), 0.0)


#: Default tiny-pivot perturbation magnitude relative to the matrix scale —
#: sqrt(machine eps), the SuperLU_DIST choice: large enough that 1/piv stays
#: harmless, small enough that one step of iterative refinement recovers the
#: lost accuracy (DESIGN.md §15).
PERTURB_EPS = float(np.sqrt(np.finfo(np.float64).eps))


class PerturbState:
    """Mutable sweep-scope accumulator for tiny-pivot perturbation.

    ``threshold`` is the absolute replacement magnitude eps·‖A‖ — a scalar
    for the single-system sweeps, a (B,) array for the batched-systems
    tier.  ``count`` accumulates how many pivots were bumped (int or (B,)
    int64 to match).  Non-finite pivots are never perturbed — they mean the
    update sweep already diverged, and hiding that would corrupt the
    factors silently.
    """

    __slots__ = ("threshold", "count")

    def __init__(self, threshold):
        if np.ndim(threshold) == 0:
            self.threshold = float(threshold)
            self.count = 0
        else:
            self.threshold = np.asarray(threshold, dtype=np.float64)
            self.count = np.zeros(len(self.threshold), dtype=np.int64)

    def total(self) -> int:
        return int(np.sum(self.count))


def perturb_threshold(scale: float, eps: float | None = None) -> float:
    """Replacement magnitude for tiny pivots: ``eps·max|A|`` (``eps``
    defaults to ``PERTURB_EPS``)."""
    return (PERTURB_EPS if eps is None else float(eps)) * max(float(scale), 0.0)


def check_pivot(k: int, piv: float, piv_tol: float) -> None:
    """The single pivot contract shared by the dense oracle, the supernodal
    panel factor, and the column-at-a-time baseline."""
    if not np.isfinite(piv) or abs(piv) <= piv_tol:
        raise ZeroPivotError(k, piv, piv_tol)


def generic_values(a: CSRMatrix, seed: int = 0) -> np.ndarray:
    """Dense matrix with random values on A's pattern, diagonally dominant so
    pivot-free elimination is numerically safe."""
    rng = np.random.default_rng(seed)
    dense = np.zeros((a.n, a.n), dtype=np.float64)
    for i in range(a.n):
        cols = a.row(i)
        dense[i, cols] = rng.uniform(0.5, 1.5, size=len(cols))
    np.fill_diagonal(dense, np.abs(dense).sum(axis=1) + 1.0)
    return dense


def generic_values_csr(a: CSRMatrix, seed: int = 0) -> np.ndarray:
    """CSR-aligned (nnz,) form of ``generic_values`` — bitwise the same
    values (same rng stream, same diagonal-dominance rule) without ever
    materializing (n, n); the packed numeric path consumes this at large n.

    Requires every diagonal entry to be structurally present (all
    ``sparse.matrices`` generators guarantee it)."""
    rng = np.random.default_rng(seed)
    vals = np.empty(a.nnz, dtype=np.float64)
    diag_pos = np.full(a.n, -1, dtype=np.int64)
    row_abs_sum = np.zeros(a.n, dtype=np.float64)
    for i in range(a.n):
        lo, hi = int(a.indptr[i]), int(a.indptr[i + 1])
        cols = a.indices[lo:hi]
        v = rng.uniform(0.5, 1.5, size=len(cols))
        vals[lo:hi] = v
        row_abs_sum[i] = np.abs(v).sum()
        d = np.searchsorted(cols, i)
        if d >= len(cols) or cols[d] != i:
            raise ValueError(
                f"generic_values_csr needs a structural diagonal; row {i} "
                f"has none")
        diag_pos[i] = lo + d
    vals[diag_pos] = row_abs_sum + 1.0
    return vals


def csr_matvec(a: CSRMatrix, vals: np.ndarray, x: np.ndarray) -> np.ndarray:
    """y = A @ x with CSR-aligned values — the O(nnz) matvec iterative
    refinement uses on the sparse path.  ``x`` may be a single vector (n,)
    or a multi-RHS block (n, k); the result matches its shape."""
    vals = np.asarray(vals, dtype=np.float64)
    x = np.asarray(x, dtype=np.float64)
    row_of = np.repeat(np.arange(a.n, dtype=np.int64), np.diff(a.indptr))
    if x.ndim == 1:
        return np.bincount(row_of, weights=vals * x[a.indices],
                           minlength=a.n)
    out = np.empty((a.n, x.shape[1]), dtype=np.float64)
    for c in range(x.shape[1]):
        out[:, c] = np.bincount(row_of, weights=vals * x[a.indices, c],
                                minlength=a.n)
    return out


def lu_inplace(m: np.ndarray, piv_tol: float, *, col0: int = 0,
               perturb: PerturbState | None = None) -> None:
    """In-place no-pivot right-looking elimination of the packed block ``m``
    (L strictly below, U on/above the diagonal) — shared by the dense oracle
    and the supernodal diagonal-block factor (repro.numeric).  Pivots are
    checked with ``check_pivot`` and reported at global column ``col0 + t``.

    With ``perturb``, a finite pivot with |piv| <= perturb.threshold is
    replaced by the signed threshold (sign of the pivot; +1 for an exact
    zero) before the check — the factorization completes and iterative
    refinement recovers the accuracy (robust tier, DESIGN.md §15).  When
    ``perturb`` is None the float operations are exactly the historical
    ones (bitwise-parity contract).
    """
    w = m.shape[0]
    for t in range(w):
        piv = m[t, t]
        if (perturb is not None and perturb.threshold > 0.0
                and np.isfinite(piv) and abs(piv) <= perturb.threshold):
            piv = perturb.threshold if piv >= 0.0 else -perturb.threshold
            m[t, t] = piv
            perturb.count += 1
        check_pivot(col0 + t, piv, piv_tol)
        if t < w - 1:
            m[t + 1:, t] /= piv
            m[t + 1:, t + 1:] -= np.outer(m[t + 1:, t], m[t, t + 1:])


def lu_inplace_batched(m: np.ndarray, piv_tol: np.ndarray, *,
                       col0: int = 0,
                       perturb: PerturbState | None = None) -> None:
    """``lu_inplace`` broadcast over a leading batch axis: ``m`` is
    (B, w, w), one same-structure diagonal block per system, ``piv_tol``
    the (B,) per-system pivot threshold.  Every float op is elementwise
    (scale + outer-product update), so each slice is bitwise-identical to
    ``lu_inplace`` on that system alone — the batched tier's conformance
    contract (DESIGN.md §14).  ``perturb`` (per-system (B,) thresholds and
    counts) applies the same tiny-pivot replacement as the scalar kernel,
    masked per system.

    Pivots are checked for every system at every column; the first failing
    (column, system) raises the same ``ZeroPivotError`` the per-system
    sweep would, carrying the failing system index.
    """
    w = m.shape[1]
    for t in range(w):
        piv = m[:, t, t]
        if perturb is not None:
            thr = perturb.threshold
            tiny = (np.isfinite(piv) & (np.abs(piv) <= thr) & (thr > 0.0))
            if tiny.any():
                bumped = np.where(piv >= 0.0, thr, -thr)
                piv = np.where(tiny, bumped, piv)
                m[:, t, t] = piv
                perturb.count += tiny
        bad = ~np.isfinite(piv) | (np.abs(piv) <= piv_tol)
        if bad.any():
            i = int(np.flatnonzero(bad)[0])
            raise ZeroPivotError(col0 + t, piv[i], piv_tol[i], system=i)
        if t < w - 1:
            m[:, t + 1:, t] /= piv[:, None]
            m[:, t + 1:, t + 1:] -= (m[:, t + 1:, t, None]
                                     * m[:, t, None, t + 1:])


def lu_nopivot(dense: np.ndarray, *,
               piv_tol: float | None = None) -> Tuple[np.ndarray, np.ndarray]:
    """Plain right-looking LU without pivoting. Returns (L with unit diag, U).

    Every pivot (including the last diagonal of U) is checked against
    ``piv_tol`` (default: eps at the matrix scale) and a ``ZeroPivotError``
    is raised on zero / near-zero / non-finite pivots — the supernodal
    factorization (repro.numeric) surfaces the same error per panel.
    """
    n = dense.shape[0]
    m = dense.astype(np.float64).copy()
    if piv_tol is None:
        piv_tol = pivot_tolerance(np.abs(m).max() if m.size else 0.0)
    lu_inplace(m, piv_tol)
    l = np.tril(m, -1) + np.eye(n)
    u = np.triu(m)
    return l, u


def factor_pattern(dense: np.ndarray, tol: float = 1e-12) -> np.ndarray:
    """Boolean pattern of L+U after elimination (excluding the unit diagonal of L)."""
    l, u = lu_nopivot(dense)
    filled = (np.abs(np.tril(l, -1)) > tol) | (np.abs(u) > tol)
    return filled


def validate_symbolic(a: CSRMatrix, predicted: np.ndarray, seed: int = 0) -> dict:
    """Factorize with generic values and compare against the predicted pattern.

    ``predicted``: dense bool (n, n), True where the symbolic step predicts a
    structural nonzero of L+U (original entries included).  Returns a report
    with both inclusion directions.
    """
    dense = generic_values(a, seed=seed)
    actual = factor_pattern(dense)
    np.fill_diagonal(actual, True)
    pred = predicted.copy()
    np.fill_diagonal(pred, True)
    missed = actual & ~pred       # fatal: numeric fill the symbolic step missed
    spurious = pred & ~actual     # benign only if caused by exact cancellation
    return {
        "ok": not missed.any(),
        "exact": not missed.any() and not spurious.any(),
        "n_missed": int(missed.sum()),
        "n_spurious": int(spurious.sum()),
        "nnz_actual": int(actual.sum()),
        "nnz_predicted": int(pred.sum()),
    }
