"""Sparse-matrix substrate: CSR containers, generators, ordering, numeric LU.

This subpackage is host-side (numpy/scipy) infrastructure feeding the JAX core.
"""
from repro.sparse.csr import (
    CSRMatrix, csr_from_coo, csr_from_dense, csr_to_ell, transpose_csr,
)
from repro.sparse.matrices import (
    grid2d_laplacian,
    grid3d_laplacian,
    circuit_like,
    economic_like,
    chemical_like,
    random_pattern,
    banded_full,
    banded_random,
    bordered_block_diagonal,
    indefinite,
    indefinite_values_csr,
    shuffled_dominant,
    shuffled_dominant_values_csr,
    paper_dataset_analogue,
    PAPER_DATASETS,
)
from repro.sparse.ordering import rcm_order, permute_csr, natural_order, random_order

__all__ = [
    "CSRMatrix", "csr_from_coo", "csr_from_dense", "csr_to_ell", "transpose_csr",
    "grid2d_laplacian", "grid3d_laplacian", "circuit_like", "economic_like",
    "chemical_like", "random_pattern", "banded_full", "banded_random",
    "bordered_block_diagonal", "indefinite", "indefinite_values_csr",
    "shuffled_dominant", "shuffled_dominant_values_csr",
    "paper_dataset_analogue",
    "PAPER_DATASETS", "rcm_order", "permute_csr", "natural_order", "random_order",
]
