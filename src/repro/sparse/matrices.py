"""Synthetic sparse-matrix generators.

The paper evaluates on 14 SuiteSparse matrices (Table I).  This container has no
network access, so we synthesize *analogues* that match the application domains
and the structural statistics that matter to the algorithm under test:
order, nnz/row, structural symmetry, and fill-heaviness.  `PAPER_DATASETS`
maps the paper's dataset codes to scaled-down analogues with the same character.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.sparse.csr import CSRMatrix, csr_from_coo


def _with_diagonal(n: int, rows, cols):
    rows = np.concatenate([np.asarray(rows, dtype=np.int64),
                           np.arange(n, dtype=np.int64)])
    cols = np.concatenate([np.asarray(cols, dtype=np.int64),
                           np.arange(n, dtype=np.int64)])
    return rows, cols


def grid2d_laplacian(nx: int, ny: int | None = None) -> CSRMatrix:
    """5-point stencil on an nx × ny grid — structural-problem analogue (BC, AU)."""
    ny = ny or nx
    idx = np.arange(nx * ny).reshape(nx, ny)
    rows, cols = [], []
    for di, dj in ((0, 1), (1, 0)):
        a = idx[: nx - di, : ny - dj].ravel()
        b = idx[di:, dj:].ravel()
        rows += [a, b]
        cols += [b, a]
    rows, cols = np.concatenate(rows), np.concatenate(cols)
    rows, cols = _with_diagonal(nx * ny, rows, cols)
    return csr_from_coo(nx * ny, rows, cols)


def grid3d_laplacian(nx: int, ny: int | None = None,
                     nz: int | None = None) -> CSRMatrix:
    """7-point stencil — CFD/electromagnetics analogue (RM, DI)."""
    ny = ny or nx
    nz = nz or nx
    idx = np.arange(nx * ny * nz).reshape(nx, ny, nz)
    rows, cols = [], []
    for d in ((0, 0, 1), (0, 1, 0), (1, 0, 0)):
        a = idx[: nx - d[0], : ny - d[1], : nz - d[2]].ravel()
        b = idx[d[0]:, d[1]:, d[2]:].ravel()
        rows += [a, b]
        cols += [b, a]
    rows, cols = np.concatenate(rows), np.concatenate(cols)
    rows, cols = _with_diagonal(nx * ny * nz, rows, cols)
    return csr_from_coo(nx * ny * nz, rows, cols)


def circuit_like(n: int, *, avg_deg: float = 4.0, hub_fraction: float = 0.002,
                 hub_deg: int = 64, seed: int = 0) -> CSRMatrix:
    """Circuit-simulation analogue (G3, HM, PR, TT): sparse, a few high-degree
    rails (power/ground nets), low-ish structural symmetry."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg)
    rows = rng.integers(0, n, size=m)
    cols = rng.integers(0, n, size=m)
    # local coupling: most connections are near-diagonal (placement locality)
    local = rng.integers(0, n, size=m)
    off = rng.integers(1, max(2, n // 100), size=m)
    rows = np.concatenate([rows, local])
    cols = np.concatenate([cols, np.minimum(n - 1, local + off)])
    n_hubs = max(1, int(n * hub_fraction))
    hubs = rng.choice(n, size=n_hubs, replace=False)
    hub_deg = min(hub_deg, n // 2)
    for h in hubs:
        tied = rng.choice(n, size=hub_deg, replace=False)
        rows = np.concatenate([rows, np.full(hub_deg, h), tied])
        cols = np.concatenate([cols, tied, np.full(hub_deg, h)])
    rows, cols = _with_diagonal(n, rows, cols)
    return csr_from_coo(n, rows, cols)


def economic_like(n: int, *, block: int = 32, coupling: float = 3.0,
                  seed: int = 0) -> CSRMatrix:
    """Economic-modelling analogue (G7, MK): highly *asymmetric* block couplings
    (struct. symm ~0.03-0.07 in Table I)."""
    rng = np.random.default_rng(seed)
    m = int(n * coupling)
    # directed inter-block flows: i in block b reads from block b' (one-way)
    rows = rng.integers(0, n, size=m)
    shift = (rng.integers(1, max(2, n // block), size=m) * block)
    cols = (rows + shift) % n
    # sparse intra-block (bidirectional, small)
    r2 = rng.integers(0, n, size=m // 4)
    c2 = (r2 // block) * block + rng.integers(0, block, size=m // 4)
    c2 = np.minimum(c2, n - 1)
    rows = np.concatenate([rows, r2, c2])
    cols = np.concatenate([cols, c2, r2])
    rows, cols = _with_diagonal(n, rows, cols)
    return csr_from_coo(n, rows, cols)


def chemical_like(n: int, *, stage: int = 24, seed: int = 0) -> CSRMatrix:
    """Chemical-engineering analogue (LH): cascaded stages, near-zero symmetry."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for s in range(0, n - stage, stage):
        # each stage couples forward into the next stage only (flowsheet)
        r = np.repeat(np.arange(s, s + stage), 3)
        c = s + stage + rng.integers(0, stage, size=3 * stage)
        c = np.minimum(c, n - 1)
        rows.append(r)
        cols.append(c)
        # dense-ish lower stage block
        r2 = s + rng.integers(0, stage, size=4 * stage)
        c2 = s + rng.integers(0, stage, size=4 * stage)
        rows.append(r2)
        cols.append(c2)
    rows, cols = np.concatenate(rows), np.concatenate(cols)
    rows, cols = _with_diagonal(n, rows, cols)
    return csr_from_coo(n, rows, cols)


def random_pattern(n: int, *, density: float = 0.01, symmetric: bool = False,
                   seed: int = 0) -> CSRMatrix:
    rng = np.random.default_rng(seed)
    m = max(n, int(n * n * density))
    rows = rng.integers(0, n, size=m)
    cols = rng.integers(0, n, size=m)
    if symmetric:
        rows, cols = np.concatenate([rows, cols]), np.concatenate([cols, rows])
    rows, cols = _with_diagonal(n, rows, cols)
    return csr_from_coo(n, rows, cols)


def banded_full(n: int, *, band: int = 8) -> CSRMatrix:
    """Full band of half-width ``band`` (every |i-j| <= band present).

    No-pivot LU of a dense band fills nothing outside it, so the filled
    L+U pattern is the matrix's own pattern
    (``numeric.storage.CSCPattern.banded`` is the exact prediction) — the
    large-n generator for exercising the packed O(nnz(L+U)) numeric path
    without a dense symbolic pass."""
    offs = np.arange(-band, band + 1)
    rows = np.repeat(np.arange(n), len(offs))
    cols = rows + np.tile(offs, n)
    keep = (cols >= 0) & (cols < n)
    return csr_from_coo(n, rows[keep], cols[keep])


def bordered_block_diagonal(n: int, *, block: int = 16, border: int = 64,
                            couple: int = 4, seed: int = 0) -> CSRMatrix:
    """Bordered block-diagonal (BBD) matrix: independent dense-ish diagonal
    blocks plus ``border`` global rail rows/columns at the *end* of the
    index space, each coupled to ``couple`` random interior positions.

    This is the canonical partitioned-circuit structure (SPICE-style BBD
    ordering): fill stays O(nnz) — confined to the blocks, the rail
    rows/columns, and the border corner — and the graph diameter is tiny
    (any interior vertex reaches anything else only through the rails), so
    the symbolic fixpoint converges in a handful of supersteps at any n.
    The large-n generator for driving the full analyze -> refactorize
    pipeline end to end."""
    rng = np.random.default_rng(seed)
    interior = n - border
    if interior <= 0:
        raise ValueError(f"need n > border, got n={n} border={border}")
    # dense-ish random blocks: ~3 entries per row inside each block
    b_rows = rng.integers(0, interior, size=3 * interior)
    b_cols = ((b_rows // block) * block
              + rng.integers(0, block, size=3 * interior))
    b_cols = np.minimum(b_cols, interior - 1)
    # rails: border row h couples symmetrically to `couple` interior spots
    rails = np.repeat(np.arange(interior, n), couple)
    tied = rng.integers(0, interior, size=border * couple)
    rows = np.concatenate([b_rows, b_cols, rails, tied])
    cols = np.concatenate([b_cols, b_rows, tied, rails])
    rows, cols = _with_diagonal(n, rows, cols)
    return csr_from_coo(n, rows, cols)


def banded_random(n: int, *, band: int = 8, fill: float = 0.5,
                  seed: int = 0) -> CSRMatrix:
    rng = np.random.default_rng(seed)
    m = int(n * band * fill)
    rows = rng.integers(0, n, size=m)
    off = rng.integers(-band, band + 1, size=m)
    cols = np.clip(rows + off, 0, n - 1)
    rows, cols = _with_diagonal(n, rows, cols)
    return csr_from_coo(n, rows, cols)


def indefinite(n: int, *, band: int = 8, fill: float = 0.7,
               seed: int = 0) -> CSRMatrix:
    """Symmetric-structure banded pattern for *indefinite* systems
    (saddle-point / KKT character).  The pattern alone is unremarkable —
    pair it with ``indefinite_values_csr``, which mixes signs and zeroes
    out periodic diagonal entries so the pivot-free sweep fails without
    the robust tier (``LUOptions(pivot="static", perturb=True)``)."""
    rng = np.random.default_rng(seed)
    m = int(n * band * fill)
    rows = rng.integers(0, n, size=m)
    off = rng.integers(1, band + 1, size=m) * rng.choice([-1, 1], size=m)
    cols = np.clip(rows + off, 0, n - 1)
    rows, cols = np.concatenate([rows, cols]), np.concatenate([cols, rows])
    rows, cols = _with_diagonal(n, rows, cols)
    return csr_from_coo(n, rows, cols)


def indefinite_values_csr(a: CSRMatrix, *, zero_diag_period: int = 7,
                          seed: int = 0) -> np.ndarray:
    """CSR-aligned values that make ``indefinite`` live up to its name:
    sign-mixed off-diagonals, small non-dominant diagonals, and every
    ``zero_diag_period``-th diagonal entry (including column 0) exactly
    zero — so plain no-pivot elimination hits an exact zero pivot at
    column 0 while the matrix itself stays generically nonsingular."""
    rng = np.random.default_rng(seed)
    vals = np.empty(a.nnz, dtype=np.float64)
    for i in range(a.n):
        lo, hi = int(a.indptr[i]), int(a.indptr[i + 1])
        cols = a.row(i)
        v = (rng.uniform(0.5, 1.5, size=len(cols))
             * rng.choice([-1.0, 1.0], size=len(cols)))
        d = np.searchsorted(cols, i)
        if d >= len(cols) or cols[d] != i:
            raise ValueError(f"indefinite_values_csr needs a structural "
                             f"diagonal; row {i} has none")
        if i % zero_diag_period == 0:
            v[d] = 0.0
        else:
            v[d] = float(rng.uniform(0.05, 0.2)) * (1.0 if v[d] >= 0 else -1.0)
        vals[lo:hi] = v
    return vals


def _shuffled_dominant_system(n: int, band: int, shift: int | None,
                              seed: int):
    """Shared builder: a diagonally dominant banded system whose rows are
    rotated by ``shift`` — dominance lands on an off-diagonal stripe, and
    any row whose original diagonal fell outside the band after rotation
    gets a *structural* diagonal entry holding an exact 0.0 (so the seed
    no-pivot path dies on an exact zero pivot, not just a tiny one)."""
    from repro.sparse.numeric import generic_values_csr
    if shift is None:
        shift = band + 3
    base = banded_random(n, band=band, fill=0.9, seed=seed)
    vals = generic_values_csr(base, seed=seed)
    row_of = np.repeat(np.arange(n, dtype=np.int64), np.diff(base.indptr))
    new_rows = (row_of - shift) % n
    cols = base.indices.astype(np.int64)
    have_diag = np.zeros(n, dtype=bool)
    have_diag[new_rows[new_rows == cols]] = True
    miss = np.flatnonzero(~have_diag)
    rows_all = np.concatenate([new_rows, miss])
    cols_all = np.concatenate([cols, miss])
    vals_all = np.concatenate([vals, np.zeros(len(miss))])
    order = np.lexsort((cols_all, rows_all))
    rows_all, cols_all, vals_all = (rows_all[order], cols_all[order],
                                    vals_all[order])
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, rows_all + 1, 1)
    a = CSRMatrix(n=n, indptr=np.cumsum(indptr),
                  indices=cols_all.astype(np.int32))
    return a, vals_all


def shuffled_dominant(n: int, *, band: int = 6, shift: int | None = None,
                      seed: int = 0) -> CSRMatrix:
    """Row-rotated diagonally dominant band: structurally every diagonal is
    present, but with ``shuffled_dominant_values_csr`` the dominant entries
    sit ``shift`` positions off the diagonal and several diagonal values
    are exact zeros.  The max-product transversal recovers the rotation
    exactly, making this the canonical static-pivoting rescue case."""
    return _shuffled_dominant_system(n, band, shift, seed)[0]


def shuffled_dominant_values_csr(a: CSRMatrix, *, band: int = 6,
                                 shift: int | None = None,
                                 seed: int = 0) -> np.ndarray:
    """Values matching ``shuffled_dominant`` called with the same
    (n, band, shift, seed) — the two are views of one rotated system."""
    mat, vals = _shuffled_dominant_system(a.n, band, shift, seed)
    if mat.nnz != a.nnz or not np.array_equal(mat.indices, a.indices):
        raise ValueError("pattern was not produced by shuffled_dominant with "
                         "the same (n, band, shift, seed)")
    return vals


# ---------------------------------------------------------------------------
# Paper Table I analogues (scaled to CPU-tractable sizes, same character).
# key: (generator, kwargs, description)
# ---------------------------------------------------------------------------
PAPER_DATASETS: Dict[str, tuple] = {
    "BB": (grid3d_laplacian, dict(nx=12), "CFD analogue of BBMAT"),
    "BC": (grid2d_laplacian, dict(nx=40), "structural analogue of BCSSTK18"),
    "EP": (grid2d_laplacian, dict(nx=36, ny=28), "thermal analogue of EPB2"),
    "G7": (economic_like, dict(n=1536, seed=7), "economic analogue of G7JAC200SC"),
    "LH": (chemical_like, dict(n=1800, seed=3), "chem-eng analogue of LHR71C"),
    "MK": (economic_like, dict(n=1280, block=16, seed=11),
           "economic analogue of MARK3JAC140SC"),
    "RM": (grid3d_laplacian, dict(nx=11), "CFD analogue of RMA10"),
    "AU": (grid3d_laplacian, dict(nx=13), "structural analogue of AUDIKW_1"),
    "DI": (grid3d_laplacian, dict(nx=12, ny=12, nz=10),
           "EM analogue of DIELFILTERV2REAL"),
    "G3": (circuit_like, dict(n=2048, seed=5), "circuit analogue of G3_CIRCUIT"),
    "HM": (circuit_like, dict(n=2048, avg_deg=2.0, seed=9),
           "circuit analogue of HAMRLE3"),
    "PR": (circuit_like, dict(n=1600, hub_deg=96, seed=13), "circuit analogue of PRE2"),
    "ST": (grid3d_laplacian, dict(nx=12, ny=11, nz=11),
           "bioengineering analogue of STOMACH"),
    "TT": (circuit_like, dict(n=1200, avg_deg=5.0, seed=17),
           "circuit analogue of TWOTONE"),
}


def paper_dataset_analogue(code: str) -> CSRMatrix:
    gen, kwargs, _ = PAPER_DATASETS[code]
    return gen(**kwargs)
