"""Version compat for the pinned jax (0.4.x vs >= 0.5 API moves).

Single home for every cross-version branch so a future jax bump deletes them
in one place (ROADMAP "Open items"):

* ``shard_map``      — moved from jax.experimental.shard_map to the jax top
                       level; ``check_rep`` was renamed ``check_vma``.
* ``axis_size``      — ``jax.lax.axis_size`` did not exist; the classic
                       spelling is ``lax.psum(1, axis)`` (static when the
                       mesh is concrete).
* mesh construction  — ``jax.sharding.AxisType`` and the ``axis_types=``
                       kwarg of make_mesh/AbstractMesh are post-0.4.x; on
                       older jax every axis is implicitly Auto, so the
                       builders drop the argument (see launch/mesh.py
                       compat_make_mesh / compat_abstract_mesh).
"""
from __future__ import annotations

import jax

AXIS_TYPE = getattr(jax.sharding, "AxisType", None)

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
    SHARD_MAP_NOCHECK_KW = {"check_vma": False}
else:  # pragma: no cover - depends on the pinned jax
    from jax.experimental.shard_map import shard_map  # noqa: F401
    SHARD_MAP_NOCHECK_KW = {"check_rep": False}


def axis_size(axis_name: str):
    """Size of a mapped mesh axis, callable inside shard_map on any jax."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
