"""Training substrate: sharding rules, AdamW+ZeRO-1, step factories,
gradient compression."""
