"""Step factories: jitted train / prefill / decode steps with full sharding.

``make_*_step`` returns a ``Step`` bundle: the jitted function, the input
ShapeDtypeStructs (ready for ``.lower()`` — the multi-pod dry-run never
allocates), and the shardings.  The same factories serve the real training
driver (launch/train.py) and the dry-run (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.train import sharding as shd
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw

AUX_LOSS_WEIGHT = 0.01


@dataclasses.dataclass
class Step:
    fn: Callable                      # jitted
    args: Tuple[Any, ...]             # ShapeDtypeStruct pytrees, jit-ready
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    meta: Dict[str, Any]


def _q_chunk(seq_len: int) -> Optional[int]:
    return 1024 if seq_len > 1024 else None


def _replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStructs for one global batch (train / prefill)."""
    b, s = shape.global_batch, shape.seq_len
    s_text = s - cfg.n_patches if cfg.n_patches else s
    specs: Dict = {"tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.n_patches:
        specs["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), dtype)
    if cfg.encdec is not None:
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encdec.enc_len, cfg.d_model), dtype)
    return specs


def param_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda k: tf.init_params(k, cfg, dtype), jax.random.key(0))


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(tf.init_caches, cfg, batch, cache_len, dtype))


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                    dtype=jnp.bfloat16, acfg: AdamWConfig = AdamWConfig(),
                    scan: bool = True, unroll: bool = False,
                    q_chunk: Optional[int] = None, donate: bool = True,
                    micro_steps: Optional[int] = None) -> Step:
    if q_chunk is None:
        q_chunk = _q_chunk(shape.seq_len)
    if micro_steps is None:
        micro_steps = cfg.micro_steps
    while shape.global_batch % micro_steps:
        micro_steps //= 2          # smoke shapes: clamp to a divisor
    micro_steps = max(1, micro_steps)

    def loss_fn(params, batch):
        with shd.step_context(mesh, cfg):
            hidden, _, aux = tf.forward(
                params, cfg, batch["tokens"], patches=batch.get("patches"),
                frames=batch.get("frames"), mode="train", q_chunk=q_chunk,
                unroll=unroll, scan=scan)
            loss = tf.ce_loss(params, cfg, hidden, batch["labels"],
                              unroll=unroll)
        total = loss + AUX_LOSS_WEIGHT * aux[0]
        return total, {"loss": loss, "moe_aux": aux[0], "moe_drop": aux[1]}

    def train_step(params, opt, batch):
        if micro_steps == 1:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            # gradient accumulation: microbatch scan bounds the live
            # activation set to one microbatch (grads accumulate in f32)
            mb = jax.tree.map(
                lambda x: x.reshape((micro_steps, x.shape[0] // micro_steps)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": 0.0, "moe_aux": 0.0, "moe_drop": 0.0}

            def body(carry, micro):
                gsum, msum = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, micro)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gsum, g)
                msum = jax.tree.map(lambda a, b: a + b / micro_steps, msum, m)
                return (gsum, msum), None

            (grads, metrics), _ = jax.lax.scan(body, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / micro_steps, grads)
        params, opt, opt_metrics = adamw_update(params, grads, opt, acfg)
        metrics.update(opt_metrics)
        return params, opt, metrics

    p_specs = param_specs(cfg, dtype)
    o_specs = jax.eval_shape(init_adamw, p_specs)
    b_specs = batch_specs(cfg, shape, dtype)

    p_sh = shd.param_shardings(p_specs, mesh, cfg)
    o_sh = {"master": shd.opt_shardings(p_sh, p_specs, mesh),
            "m": shd.opt_shardings(p_sh, p_specs, mesh),
            "v": shd.opt_shardings(p_sh, p_specs, mesh),
            "count": _replicated(mesh)}
    b_sh = shd.batch_shardings(b_specs, mesh, cfg)
    metric_sh = jax.tree.map(lambda _: _replicated(mesh),
                             {"loss": 0, "moe_aux": 0, "moe_drop": 0,
                              "grad_norm": 0, "lr": 0})

    fn = jax.jit(train_step,
                 in_shardings=(p_sh, o_sh, b_sh),
                 out_shardings=(p_sh, o_sh, metric_sh),
                 donate_argnums=(0, 1) if donate else ())
    return Step(fn=fn, args=(p_specs, o_specs, b_specs),
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, metric_sh),
                meta={"q_chunk": q_chunk, "dtype": dtype, "kind": "train"})


# ---------------------------------------------------------------------------
# prefill / decode (serving)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                      dtype=jnp.bfloat16, scan: bool = True,
                      unroll: bool = False, cache_len: Optional[int] = None,
                      q_chunk: Optional[int] = None) -> Step:
    if q_chunk is None:
        q_chunk = _q_chunk(shape.seq_len)
    if cache_len is None:
        cache_len = shape.seq_len

    def prefill_step(params, batch):
        with shd.step_context(mesh, cfg):
            hidden, caches, _ = tf.forward(
                params, cfg, batch["tokens"], patches=batch.get("patches"),
                frames=batch.get("frames"), mode="prefill",
                cache_len=cache_len, q_chunk=q_chunk, unroll=unroll, scan=scan)
            logits = tf.logits_last(params, cfg, hidden)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    p_specs = param_specs(cfg, dtype)
    b_specs = batch_specs(cfg, shape, dtype)
    c_specs = cache_specs(cfg, shape.global_batch, cache_len, dtype)

    p_sh = shd.param_shardings(p_specs, mesh, cfg)
    b_sh = shd.batch_shardings(b_specs, mesh, cfg)
    c_sh = shd.cache_shardings(c_specs, mesh, cfg)
    tok_sh = NamedSharding(mesh, shd.batch_pspec((shape.global_batch,), mesh, cfg))

    fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                 out_shardings=(tok_sh, c_sh))
    return Step(fn=fn, args=(p_specs, b_specs),
                in_shardings=(p_sh, b_sh), out_shardings=(tok_sh, c_sh),
                meta={"q_chunk": q_chunk, "dtype": dtype, "kind": "prefill",
                      "cache_len": cache_len})


def make_decode_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                     dtype=jnp.bfloat16, scan: bool = True,
                     unroll: bool = False, donate: bool = True) -> Step:
    """serve_step: one new token against a seq_len-deep cache."""
    b = shape.global_batch
    cache_len = shape.seq_len

    def decode_step(params, caches, tokens):
        with shd.step_context(mesh, cfg):
            hidden, caches, _ = tf.forward(params, cfg, tokens, mode="decode",
                                           caches=caches, scan=scan,
                                           unroll=unroll)
            logits = tf.logits_last(params, cfg, hidden)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    p_specs = param_specs(cfg, dtype)
    c_specs = cache_specs(cfg, b, cache_len, dtype)
    t_specs = jax.ShapeDtypeStruct((b, 1), jnp.int32)

    p_sh = shd.param_shardings(p_specs, mesh, cfg)
    c_sh = shd.cache_shardings(c_specs, mesh, cfg)
    t_sh = NamedSharding(mesh, shd.batch_pspec((b, 1), mesh, cfg))
    tok_sh = NamedSharding(mesh, shd.batch_pspec((b,), mesh, cfg))

    fn = jax.jit(decode_step, in_shardings=(p_sh, c_sh, t_sh),
                 out_shardings=(tok_sh, c_sh),
                 donate_argnums=(1,) if donate else ())
    return Step(fn=fn, args=(p_specs, c_specs, t_specs),
                in_shardings=(p_sh, c_sh, t_sh), out_shardings=(tok_sh, c_sh),
                meta={"dtype": dtype, "kind": "decode", "cache_len": cache_len})


def make_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, **kw) -> Step:
    if shape.kind == "train":
        return make_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape, **kw)
    return make_decode_step(cfg, mesh, shape, **kw)
