"""Sharding rules: params (TP + optional FSDP), optimizer state (ZeRO-1),
caches, batches, and in-model activation constraints.

The production mesh is ('data', 'model') single-pod / ('pod', 'data',
'model') multi-pod (launch/mesh.py).  Baseline layout:

* batch over ('pod', 'data');
* tensor parallelism over 'model': attention head projections, FFN hidden,
  MoE expert axis (EP), vocab of the (un)embedding;
* FSDP (param + gradient sharding over cfg.fsdp_axes) for archs whose
  weights exceed a single chip (deepseek-v3, jamba);
* ZeRO-1: optimizer moments/master sharded over 'data' even when the param
  itself is replicated there;
* long-context decode caches: sequence dimension sharded over whatever axes
  the batch cannot use (batch=1 at long_500k).

Divisibility is checked per rule and the rule silently degrades to
replication when it fails (e.g. qwen3's 40 heads on a 16-wide model axis
shard as flattened head*dim columns instead).

``constrain``/``set_context`` give model code mesh-independent activation
annotations: models call ``constrain(x, ("batch", None, "model"))`` and the
names resolve (or no-op) against the ambient step context, so the same model
file serves the 1-device smoke test and the 512-device dry-run.
"""
from __future__ import annotations

import math
import threading
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

_FSDP_MIN_SIZE = 1 << 16    # don't FSDP-shard tiny tensors

_tls = threading.local()


class _Ctx:
    def __init__(self, mesh: Mesh, cfg: ModelConfig):
        self.mesh = mesh
        self.cfg = cfg
        self.batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def set_context(mesh: Optional[Mesh], cfg: Optional[ModelConfig]):
    _tls.ctx = _Ctx(mesh, cfg) if mesh is not None else None


def get_context() -> Optional[_Ctx]:
    return getattr(_tls, "ctx", None)


@contextmanager
def step_context(mesh: Mesh, cfg: ModelConfig):
    prev = get_context()
    set_context(mesh, cfg)
    try:
        yield
    finally:
        _tls.ctx = prev


def _resolve(kind, ctx: _Ctx) -> Tuple[str, ...]:
    """Map a rule name to concrete mesh axes."""
    if kind is None:
        return ()
    if isinstance(kind, tuple):
        out = []
        for k in kind:
            out.extend(_resolve(k, ctx))
        return tuple(out)
    if kind == "batch":
        return ctx.batch_axes
    if kind in ctx.mesh.axis_names:
        return (kind,)
    return ()


def auto_spec(shape: Sequence[int], prefs, ctx: _Ctx) -> P:
    """Pick, per dim, the first preference whose axes are unused and divide
    the dim.  ``prefs[i]`` is None | name | tuple | list-of-candidates."""
    used: set = set()
    spec = []
    for size, pref in zip(shape, prefs):
        cands = pref if isinstance(pref, list) else [pref]
        chosen = None
        for cand in cands:
            axes = _resolve(cand, ctx)
            if not axes or any(a in used for a in axes):
                continue
            total = math.prod(ctx.mesh.shape[a] for a in axes)
            if total > 1 and size % total == 0:
                chosen = axes
                break
        if chosen:
            used.update(chosen)
            spec.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            spec.append(None)
    return P(*spec)


def constrain(x: jax.Array, prefs) -> jax.Array:
    """Mesh-independent with_sharding_constraint; no-op without a context."""
    ctx = get_context()
    if ctx is None or x.ndim != len(prefs):
        return x
    spec = auto_spec(x.shape, prefs, ctx)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# parameter shardings
# ---------------------------------------------------------------------------

_COL = {"wq", "wk", "wv", "wg", "wr", "w_gate", "w_up", "w_in", "wq_b",
        "wkv_b", "w_lora_a", "w_dt"}          # (in, out): TP on out
_ROW = {"wo", "w_down", "w_out"}              # (in, out): TP on in
_IN_ONLY = {"w_xproj", "a_log"}               # (di, *): TP on dim 0
_CH_VEC = {"conv_b", "d_skip", "dt_bias"}     # (di,): TP
_LORA_IN = {"wq_a", "wkv_a"}                  # (d, r): FSDP on d only


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", k)) for k in path)


def param_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh,
                cfg: ModelConfig) -> P:
    names = path.split("/")
    name = names[-1]
    grouped = names[0] in ("groups", "encoder")
    dims = list(shape[1:]) if grouped else list(shape)
    model = mesh.shape["model"] if "model" in mesh.axis_names else 1
    fsdp_axes = tuple(a for a in cfg.fsdp_axes if a in mesh.axis_names)
    fsdp = math.prod(mesh.shape[a] for a in fsdp_axes) if fsdp_axes else 1
    big = math.prod(dims) >= _FSDP_MIN_SIZE

    def m(i):  # model axis if divisible
        return "model" if model > 1 and dims[i] % model == 0 else None

    def f(i):  # fsdp axes if divisible and worthwhile
        return fsdp_axes if fsdp > 1 and big and dims[i] % fsdp == 0 else None

    # seq-sharded attention replaces head-TP when n_heads % tp != 0: the
    # attention projections then skip model sharding (FSDP only) and the
    # SDPA q-chunks shard over 'model' instead (attention.py)
    attn_no_tp = (cfg.seq_shard_attention
                  and name in ("wq", "wk", "wv", "wo")
                  and "mixer" in names)

    spec = [None] * len(dims)
    if name == "table" and len(dims) == 2:                  # (V, d) embed/head
        spec = [m(0), f(1)]
    elif name in _COL and len(dims) == 2:                   # (d, out)
        spec = [f(0), None if attn_no_tp else m(1)]
    elif name in _ROW and len(dims) == 2:                   # (in, d)
        spec = [None if attn_no_tp else m(0), f(1)]
    elif name in ("w_gate", "w_up") and len(dims) == 3:     # (E, d, de) experts
        spec = [m(0), f(1), None]
    elif name == "w_down" and len(dims) == 3:               # (E, de, d)
        spec = [m(0), None, f(2)]
    elif name in _IN_ONLY and len(dims) == 2:               # (di, *)
        spec = [m(0), None]
    elif name == "conv_w" and len(dims) == 2:               # (d_conv, di)
        spec = [None, m(1)]
    elif name in _CH_VEC and len(dims) == 1:                # (di,)
        spec = [m(0)]
    elif name in _LORA_IN and len(dims) == 2:               # (d, r)
        spec = [f(0), None]
    # everything else (norms, router, u, mix, w_base) replicates
    if grouped:
        spec = [None] + spec
    return P(*spec)


def param_shardings(param_shapes, mesh: Mesh, cfg: ModelConfig):
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStruct/arrays."""
    def one(path, leaf):
        return NamedSharding(mesh, param_pspec(_path_str(path), leaf.shape, mesh, cfg))
    return jax.tree_util.tree_map_with_path(one, param_shapes)


def zero1_pspec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: additionally shard optimizer state over 'data' (largest
    still-unsharded divisible dim).  Params already FSDP'd keep their spec."""
    if "data" not in mesh.axis_names:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a:
                used.add(a)
    if "data" in used:
        return spec
    d = mesh.shape["data"]
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if entries[i] is None and shape[i] % d == 0 and shape[i] >= d:
            entries[i] = "data"
            return P(*entries)
    return spec


def opt_shardings(param_shardings_tree, param_shapes, mesh: Mesh):
    def one(sh, leaf):
        return NamedSharding(mesh, zero1_pspec(sh.spec, leaf.shape, mesh))
    return jax.tree.map(one, param_shardings_tree, param_shapes)


# ---------------------------------------------------------------------------
# cache / batch shardings
# ---------------------------------------------------------------------------

_SEQ_PREFS = [("data", "model"), ("data",), ("model",)]   # for seq-dim sharding


def cache_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh,
                cfg: ModelConfig) -> P:
    """Caches are stacked (n_groups leading).  Batch shards first; KV heads
    over 'model' when divisible; otherwise the sequence dim picks up the
    spare axes (sequence-sharded cache for long_500k's batch=1)."""
    ctx = _Ctx(mesh, cfg)
    name = path.split("/")[-1]
    dims = shape[1:]                                     # drop group axis
    if name in ("k", "v") and len(dims) == 4:            # (B, Hkv, T, hd)
        spec = auto_spec(dims, ["batch", "model", _SEQ_PREFS, None], ctx)
    elif name == "pos" and len(dims) == 2:               # (B, T)
        spec = auto_spec(dims, ["batch", _SEQ_PREFS], ctx)
    elif name == "ckv" and len(dims) == 3:               # (B, T, r)
        spec = auto_spec(dims, ["batch", _SEQ_PREFS, None], ctx)
    elif name == "krope" and len(dims) == 4:             # (B, 1, T, rd)
        spec = auto_spec(dims, ["batch", None, _SEQ_PREFS, None], ctx)
    elif name == "s" and len(dims) == 4:                 # rwkv (B, H, K, K)
        spec = auto_spec(dims, ["batch", "model", None, None], ctx)
    elif name == "h" and len(dims) == 3:                 # mamba (B, di, N)
        spec = auto_spec(dims, ["batch", "model", None], ctx)
    elif name == "conv" and len(dims) == 3:              # (B, dc-1, di)
        spec = auto_spec(dims, ["batch", None, "model"], ctx)
    elif name == "x_prev" and len(dims) == 2:            # (B, d)
        spec = auto_spec(dims, ["batch", "model"], ctx)
    else:                                                # idx and friends
        spec = P()
    return P(*([None] + list(spec)))


def cache_shardings(cache_shapes, mesh: Mesh, cfg: ModelConfig):
    def one(path, leaf):
        return NamedSharding(mesh, cache_pspec(_path_str(path), leaf.shape, mesh, cfg))
    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def batch_pspec(shape: Tuple[int, ...], mesh: Mesh, cfg: ModelConfig) -> P:
    ctx = _Ctx(mesh, cfg)
    return auto_spec(shape, ["batch"] + [None] * (len(shape) - 1), ctx)


def batch_shardings(batch_shapes, mesh: Mesh, cfg: ModelConfig):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_pspec(leaf.shape, mesh, cfg)),
        batch_shapes)
