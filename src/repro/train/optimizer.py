"""AdamW with fp32 master weights and ZeRO-1 state sharding.

State layout: ``{"master": fp32 params, "m": fp32, "v": fp32, "count": ()}``.
Model params may be bf16 (compute copy); the update runs in fp32 against the
master and re-casts.  Sharding: the master/m/v leaves take the param's spec
plus a 'data'-axis shard on the largest free dim (sharding.zero1_pspec) — the
classic ZeRO-1 partitioning expressed declaratively (GSPMD inserts the
reduce-scatter/all-gather pair around the update).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(acfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, acfg.warmup_steps)
    prog = jnp.clip((s - acfg.warmup_steps)
                    / jnp.maximum(1.0, acfg.decay_steps - acfg.warmup_steps), 0, 1)
    cos = acfg.min_lr_frac + (1 - acfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return acfg.lr * jnp.where(s < acfg.warmup_steps, warm, cos)


def init_adamw(params) -> Dict:
    def f32(t):
        return jax.tree.map(lambda x: x.astype(jnp.float32), t)

    def zeros(t):
        return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)

    return {"master": f32(params), "m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt: Dict, acfg: AdamWConfig
                 ) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_opt, metrics)."""
    count = opt["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, acfg.grad_clip / (gnorm + 1e-12))
    lr = schedule(acfg, count)
    b1c = 1 - acfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - acfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        m = acfg.b1 * m + (1 - acfg.b1) * g
        v = acfg.b2 * v + (1 - acfg.b2) * g * g
        step = (m / b1c) / (jnp.sqrt(v / b2c) + acfg.eps)
        master = master - lr * (step + acfg.weight_decay * master)
        return m, v, master, master.astype(p.dtype)

    flat = jax.tree.map(upd, grads, opt["m"], opt["v"], opt["master"], params)
    m = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_p = jax.tree.map(lambda t: t[3], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_opt = {"master": master, "m": m, "v": v, "count": count}
    return new_p, new_opt, {"grad_norm": gnorm, "lr": lr}
