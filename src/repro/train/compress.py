"""Int8 gradient compression with error feedback.

Distributed-optimization trick for the cross-pod gradient reduce: gradients
are quantized per-leaf to int8 with a single fp32 scale (max-abs / 127), and
the quantization error is carried into the next step ("error feedback" /
EF-SGD), which restores convergence to near-fp32 quality.

Two integration points:

* ``compress_decompress`` — inside a single jit step, applied at the
  optimizer boundary (what the bundled train driver uses; the reduction
  itself is handled by GSPMD, so this demonstrates the numerics);
* ``runtime.collectives.ring_allreduce(compress=True)`` — the explicit
  shard_map ring where the int8 payload is what actually crosses the links
  (4x ICI traffic cut on the 'pod' axis; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, err):
    """Returns (dequantized grads, new error feedback)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize(g32)
        deq = dequantize(q, scale)
        return deq, g32 - deq

    pairs = jax.tree.map(one, grads, err)
    deq = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err
