"""Supernodal left-looking numeric LU on packed CSC-panel storage
(DESIGN.md §4, storage layout §9).

This is the step the symbolic phase exists to feed: ``CSRMatrix`` values plus
a ``SymbolicResult`` (counts, supernodes) in, unit-lower L and upper U out,
factorized panel-by-panel **directly in O(nnz(L+U)) packed storage**
(``storage.PanelStore``) — no dense (n, n) working matrix:

* **Panel gather** — each supernode J = [s, e) owns one contiguous
  (rows_J, w) block; ancestor U rows and L panels are gathered into dense
  operands through the store's sorted row-index maps (absent rows are
  structural zeros), which is what dense hardware wants (GLU3.0-style
  batched updates; structure-aware blocking per arXiv:2512.04389).
* **Left-looking updates** — ancestors K of J (supernodes with a structural
  ``U(K, J)`` block, schedule.py) are consumed in ascending order: solve
  ``U(K, J) = L(K, K)^{-1} X(K, J)``, scatter the rank-|K| update into the
  rows of *later* ancestors, and **defer the whole trailing update to one
  accumulated GEMM** ``X(s:, J) -= L(s:, anc) @ U(anc, J)`` over the gathered
  ancestor columns — the MXU panel-update kernel
  (``kernels/panel_update.py``; numpy float64 BLAS on the default backend)
  reads and writes the packed blocks.
* **Panel factor** — dense no-pivot LU of the diagonal block (raising
  ``ZeroPivotError`` with the global column on zero/near-zero pivots), then
  one triangular solve for the below-panel L rows.
* **Level schedule** — panels are processed by dependency level
  (schedule.py); within a level they are independent and grouped by the
  ``pack_panels`` bins.  The factors are bitwise invariant to the packing
  policy (LPT vs contiguous) because per-panel math never reads same-level
  data.

Structural exactness: updates and solves only ever touch the structural rows
of the predicted pattern, so entries outside the symbolic prediction are
*exactly* zero except at a panel's explicit-zero padding (union rows /
relaxed T3 merges), which is bounded by ``pattern_tol`` and zeroed —
anything larger escaping the pattern raises (that would be a symbolic bug,
the ``validate_symbolic`` contract).  Updates that would land on a row
absent from the target panel's structure are tracked the same way instead
of being silently dropped.

``sparse/numeric.py::lu_nopivot`` stays the dense O(n^2) test oracle
(``NumericResult.l`` / ``.u`` reconstruct dense factors on demand so the
parity tests stay bitwise-meaningful); ``factorize_columns`` is the honest
column-at-a-time sparse baseline the benchmark compares against.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Optional, Tuple

import numpy as np
from scipy.linalg import solve_triangular

from repro.numeric.schedule import PanelSchedule, build_panel_maps, build_schedule
from repro.numeric.storage import BatchedPanelStore, CSCPattern, PanelStore
from repro.obs import metrics as _om
from repro.obs import trace as _ot
from repro.sparse.csr import CSRMatrix
from repro.sparse.numeric import (
    PerturbState, ZeroPivotError, check_pivot, generic_values_csr,
    lu_inplace, lu_inplace_batched, perturb_threshold, pivot_tolerance,
)

_BACKENDS = ("numpy", "kernel")


@dataclasses.dataclass
class NumericResult:
    """Factors + scheduling/perf counters of one supernodal factorization.

    The factors live in packed CSC-panel storage (``store``); ``l``/``u``
    are dense reconstructions materialized on demand for oracle-parity
    tests and small-n consumers — do not touch them at large n.
    """

    n: int
    store: PanelStore
    schedule: PanelSchedule
    backend: str
    elapsed_s: float
    n_updates: int               # ancestor panel updates consumed
    gemm_flops: int              # flops of the accumulated trailing GEMMs
    outside_max: float           # largest |value| found outside the pattern
    perturbed_pivots: int = 0    # tiny pivots bumped by the robust tier
    _dense_lu: Optional[Tuple[np.ndarray, np.ndarray]] = \
        dataclasses.field(default=None, repr=False)

    @property
    def n_supernodes(self) -> int:
        return self.schedule.n_panels

    @property
    def n_levels(self) -> int:
        return self.schedule.n_levels

    @property
    def store_entries(self) -> int:
        """Allocated packed slots — O(nnz(L+U)), the whole point."""
        return self.store.total_entries

    def _dense(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._dense_lu is None:
            self._dense_lu = self.store.dense_lu()
        return self._dense_lu

    @property
    def l(self) -> np.ndarray:
        """Dense unit-lower L — test/oracle reconstruction helper."""
        return self._dense()[0]

    @property
    def u(self) -> np.ndarray:
        """Dense upper U — test/oracle reconstruction helper."""
        return self._dense()[1]

    def reconstruct(self) -> np.ndarray:
        """L @ U — for residual checks against the assembled matrix."""
        return self.l @ self.u


def _solve_unit_lower(block: np.ndarray, rhs: np.ndarray) -> np.ndarray:
    """X with (I + strict_lower(block)) @ X = rhs (block stores L\\U packed)."""
    if block.shape[0] == 1:
        return rhs.copy()
    return solve_triangular(block, rhs, lower=True, unit_diagonal=True,
                            check_finite=False)


def _solve_upper_right(block: np.ndarray, rhs: np.ndarray) -> np.ndarray:
    """X with X @ triu(block) = rhs (below-panel L rows)."""
    if block.shape[0] == 1:
        return rhs / block[0, 0]
    return solve_triangular(block, rhs.T, lower=False, trans="T",
                            check_finite=False).T


def _panel_prepare(store: PanelStore, schedule: PanelSchedule, j: int,
                   maps=None):
    """Phase A of panel j: per-ancestor solves + U-row scatter.

    Runs the ascending per-ancestor unit-lower solves and rank updates on
    the gathered target rows, writes the solved U(anc, J) rows back into
    the packed block, and assembles the trailing-GEMM operands.  Reads only
    strictly-earlier-level blocks, so phase A of every panel in a level can
    run before any same-level GEMM/finish — the batched segment sweep's
    legality contract.

    Returns (lp, b, dropped, flops): the gathered (M, K) ancestor L panel
    and solved (K, w) U rows — the trailing-GEMM operands — plus the
    largest |value| the solves produced on a row absent from the panel's
    structure and the analytic GEMM flop count.  ``(None, None, 0.0, 0)``
    when the panel has no ancestors.
    """
    s, e = schedule.supernodes[j]
    w = e - s
    anc = schedule.ancestors[j]
    block = store.blocks[j]
    d = int(store.diag[j])
    if not len(anc):
        return None, None, 0.0, 0
    if maps is None:
        maps = build_panel_maps(store, schedule, j)
    offs = maps.offs
    anc_rows = maps.anc_rows

    # ascending per-ancestor solves + rank-|K| updates on the gathered
    # target rows; each ancestor's L strip (its own diagonal block + the
    # later ancestor rows) is gathered through the row-index maps only
    # while in use, so working memory stays O(K * max_w) — never a dense
    # (K, K) ancestor sub-matrix (rows absent from a panel's structure
    # gather as exact zeros)
    b = store.gather_rows_mapped(j, maps.idx_j, maps.hit_j)  # (K, w)
    for idx, k in enumerate(anc):
        r0, r1 = offs[idx], offs[idx + 1]
        strip = store.gather_rows_mapped(int(k), *maps.strip_maps[idx])
        b[r0:r1] = _solve_unit_lower(strip[:r1 - r0], b[r0:r1])
        if r1 < len(anc_rows):
            b[r1:] -= strip[r1 - r0:] @ b[r0:r1]
    idx_j, hit_j = maps.idx_j, maps.hit_j         # solved U(anc, J)
    block[idx_j[hit_j]] = b[hit_j]
    dropped = 0.0
    if not hit_j.all():
        miss = np.abs(b[~hit_j])
        if miss.size:
            dropped = float(miss.max())

    # trailing-GEMM operands: the gathered ancestor L panels against the
    # solved U rows, targeting the packed block rows >= s
    below = store.rows[j][d:]
    lp = np.empty((len(below), len(anc_rows)), dtype=np.float64)
    for idx, k in enumerate(anc):
        lp[:, offs[idx]:offs[idx + 1]] = store.gather_rows_mapped(
            int(k), *maps.below_maps[idx])
    flops = 2 * len(below) * len(anc_rows) * w
    return lp, b, dropped, flops


def _panel_finish(store: PanelStore, schedule: PanelSchedule, j: int,
                  piv_tol: float,
                  perturb: PerturbState | None = None) -> None:
    """Phase B of panel j: diagonal-block factor + below-panel solve."""
    s, e = schedule.supernodes[j]
    w = e - s
    block = store.blocks[j]
    d = int(store.diag[j])
    lu_inplace(block[d:d + w], piv_tol, col0=s, perturb=perturb)
    if block.shape[0] > d + w:
        block[d + w:] = _solve_upper_right(block[d:d + w], block[d + w:])


def _factor_panel(store: PanelStore, schedule: PanelSchedule, j: int,
                  piv_tol: float, backend: str,
                  maps=None,
                  perturb: PerturbState | None = None
                  ) -> Tuple[int, int, float]:
    """Factor panel j in place on its packed block (per-panel dispatch).

    ``maps`` (a ``schedule.PanelMaps``) supplies the panel's precomputed
    row-index gather/scatter maps — the plan/factor API builds them once per
    analysis; when omitted they are derived on the fly (one-shot path).  The
    float operations are identical either way, so the factors are bitwise
    the same.

    Returns (#ancestor updates, trailing flops, largest |value| the solves
    produced on a row absent from the panel's structure — nonzero beyond
    roundoff means symbolic under-prediction).
    """
    lp, b, dropped, flops = _panel_prepare(store, schedule, j, maps=maps)
    if lp is not None:
        # accumulated trailing update: one GEMM over the gathered ancestor
        # L panels against the solved U rows (MXU kernel on TPU), writing
        # straight back into the packed block rows >= s
        block = store.blocks[j]
        d = int(store.diag[j])
        acc = block[d:]
        if backend == "kernel":
            from repro.kernels import ops as kops

            upd = np.asarray(kops.panel_update(acc, lp, b), dtype=np.float64)
        else:
            upd = acc - lp @ b
        block[d:] = upd
    _panel_finish(store, schedule, j, piv_tol, perturb=perturb)
    return len(schedule.ancestors[j]), flops, dropped


def _factor_segment_batched(store: PanelStore, schedule: PanelSchedule,
                            seg, piv_tol: float, backend: str, maps=None,
                            perturb: PerturbState | None = None):
    """Factor one (level, device) panel segment with same-shape GEMMs
    stacked into single batched dispatches (DESIGN.md §13).

    Three phases over the whole segment: prepare operands for every panel
    (``_panel_prepare``), apply the trailing GEMMs — panels sharing an
    (M, K, N) operand shape go through ONE stacked dispatch
    (``np.matmul`` on the numpy backend, the vmapped
    ``kernels.ops.panel_update_batched`` Pallas launch on the kernel
    backend) instead of one call each — then run every diagonal factor
    (``_panel_finish``) in segment order.  Panels within a level only read
    strictly-earlier levels and write their own block, so the phase split
    and the shape grouping cannot change a single float op: the batched
    stacks are bitwise-identical to per-panel dispatch (per-slice
    ``np.matmul`` parity on CPU, per-slice grid parity under ``vmap`` on
    the Pallas side).

    Returns per-panel ``(j, n_updates, flops, dropped)`` tuples so the
    caller's accounting matches the per-panel path exactly.
    """
    out = []
    operands = {}
    groups: dict = {}
    for j in seg:
        j = int(j)
        lp, b, dropped, flops = _panel_prepare(
            store, schedule, j, maps=maps[j] if maps is not None else None)
        out.append((j, len(schedule.ancestors[j]), flops, dropped))
        if lp is None:
            continue
        operands[j] = (lp, b)
        groups.setdefault(lp.shape + (b.shape[1],), []).append(j)

    obs_on = _ot.ENABLED
    batched_calls = 0
    batched_panels = 0
    for (m, k, w), js in groups.items():
        if len(js) == 1:
            # singleton shape: plain per-panel dispatch (identical floats)
            j = js[0]
            lp, b = operands[j]
            block = store.blocks[j]
            d = int(store.diag[j])
            acc = block[d:]
            if backend == "kernel":
                from repro.kernels import ops as kops

                upd = np.asarray(kops.panel_update(acc, lp, b),
                                 dtype=np.float64)
            else:
                upd = acc - lp @ b
            block[d:] = upd
            continue
        # stacked same-shape group: one dispatch covers the whole stack,
        # device-resident on the kernel backend (the segment's
        # jax.default_device context owns the transfer + launch)
        accs = np.stack([store.blocks[j][int(store.diag[j]):] for j in js])
        lps = np.stack([operands[j][0] for j in js])
        bs = np.stack([operands[j][1] for j in js])
        if backend == "kernel":
            from repro.kernels import ops as kops

            upds = np.asarray(kops.panel_update_batched(accs, lps, bs),
                              dtype=np.float64)
        else:
            upds = accs - np.matmul(lps, bs)
        for bi, j in enumerate(js):
            d = int(store.diag[j])
            store.blocks[j][d:] = upds[bi]
        batched_calls += 1
        batched_panels += len(js)
        if obs_on:
            reg = _om.registry()
            reg.count("gemm.batched.flops", 2 * len(js) * m * k * w)
            reg.count("gemm.batched.bytes",
                      8 * len(js) * (m * k + k * w + 2 * m * w))
    if obs_on and batched_calls:
        reg = _om.registry()
        reg.count("gemm.batched.calls", batched_calls)
        reg.count("gemm.batched.panels", batched_panels)

    for j in seg:
        _panel_finish(store, schedule, int(j), piv_tol, perturb=perturb)
    return out


def factor_on_store(a: Optional[CSRMatrix], values: np.ndarray,
                    store: PanelStore, schedule: PanelSchedule, *,
                    backend: str = "numpy",
                    piv_tol: Optional[float] = None,
                    check_pattern: bool = True,
                    pattern_tol: Optional[float] = None,
                    maps=None, csr_maps=None,
                    store_is_zeroed: bool = False,
                    placement=None,
                    segment_batch: bool = True,
                    perturb: bool = False,
                    perturb_eps: Optional[float] = None) -> NumericResult:
    """Scatter ``values`` into ``store`` and run the level-scheduled panel
    sweep — the value-dependent core shared by one-shot
    ``numeric_factorize`` and plan-based ``LUPlan.factorize`` (which passes
    precomputed ``maps``/``csr_maps`` so nothing value-independent is
    rebuilt).  Both paths execute identical float operations, so the
    factors are bitwise-identical by construction.

    ``placement`` (a ``schedule.PanelPlacement``) splits every level into
    per-device panel segments (DESIGN.md §11): segments are the dispatch
    unit — on the "kernel" backend each segment's accumulated GEMMs are
    issued under its device's ``jax.default_device`` so XLA overlaps the
    per-device streams; on the "numpy" backend segments order the sweep.
    Panels within a level only ever read strictly-earlier levels and write
    their own block, so segment grouping cannot change a single float op:
    factors stay bitwise-identical at every device count.

    ``segment_batch`` (default on) routes each segment through
    ``_factor_segment_batched``: same-shape panels issue ONE stacked GEMM
    dispatch instead of one per panel — bitwise-identical floats, far
    fewer kernel launches (DESIGN.md §13).  Off = legacy per-panel
    dispatch, kept as the benchmark comparison point.

    ``perturb`` enables tiny-pivot perturbation (DESIGN.md §15): pivots
    with |piv| <= ``perturb_eps``·max|A| (default sqrt(machine eps)) are
    replaced by the signed threshold instead of raising; the count lands in
    ``NumericResult.perturbed_pivots`` and iterative refinement downstream
    recovers the accuracy.  Off (default), the float operations are the
    historical ones bit for bit."""
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; pick from {_BACKENDS}")
    n = store.n
    if pattern_tol is None:
        # float32 MXU updates leave f32-roundoff garbage at the explicit
        # zeros of relaxed panels; the float64 path stays at f64 roundoff
        pattern_tol = 1e-4 if backend == "kernel" else 1e-8
    t0 = time.perf_counter()

    values = np.asarray(values, dtype=np.float64)
    with _ot.span("scatter_values"):
        if values.ndim == 2:
            if values.shape != (n, n):
                raise ValueError(
                    f"values must be ({n}, {n}), got {values.shape}")
            input_outside = store.set_dense(values)
        else:
            if csr_maps is None and a is None:
                raise ValueError(
                    "CSR-aligned values need the matrix `a` or precomputed "
                    "`csr_maps` to locate their slots")
            nnz = csr_maps.nnz if csr_maps is not None else a.nnz
            if values.shape != (nnz,):
                raise ValueError(
                    f"values must be dense ({n}, {n}) or CSR-aligned "
                    f"({nnz},), got {values.shape}")
            input_outside = (
                store.set_csr_mapped(values, csr_maps,
                                     zero=not store_is_zeroed)
                if csr_maps is not None else store.set_csr(a, values))

    scale = float(np.abs(values).max()) if values.size else 0.0
    if piv_tol is None:
        piv_tol = pivot_tolerance(scale)
    pstate = PerturbState(perturb_threshold(scale, perturb_eps)) \
        if perturb else None

    # per-device dispatch contexts: only the jax kernel backend has device
    # placement to exploit; numpy BLAS segments are a pure scheduling order
    devices = None
    if (placement is not None and placement.n_devices > 1
            and backend == "kernel"):
        import jax

        if len(jax.devices()) >= placement.n_devices:
            devices = jax.devices()[:placement.n_devices]

    n_updates = 0
    gemm_flops = 0
    dropped_max = input_outside
    # obs accounting (only touched when tracing is enabled): analytic GEMM
    # traffic accumulates from shapes the sweep already knows — never a
    # per-panel timer, so the disabled path and the ratio gates see zero cost
    obs_on = _ot.ENABLED
    gemm_bytes = 0
    sweep_t0 = time.perf_counter() if obs_on else 0.0
    for li, level in enumerate(schedule.levels):
        if placement is None or placement.n_devices <= 1:
            segments = ((None, level),)
        else:
            segments = tuple(
                (d, seg) for d, seg in enumerate(placement.segments(level))
                if len(seg))
        seg_times = [] if obs_on and len(segments) > 1 else None
        with _ot.span("factor_level"):
            for d, seg in segments:
                ctx = (jax.default_device(devices[d])
                       if devices is not None and d is not None
                       else contextlib.nullcontext())
                track = f"device {d}" if d is not None else None
                seg_t0 = time.perf_counter() if seg_times is not None else 0.0
                with ctx, _ot.span("factor_segment", track=track):
                    try:
                        if segment_batch and len(seg) > 1:
                            panel_stats = _factor_segment_batched(
                                store, schedule, seg, piv_tol, backend,
                                maps=maps, perturb=pstate)
                        else:
                            panel_stats = [
                                (int(j),) + _factor_panel(
                                    store, schedule, int(j), piv_tol, backend,
                                    maps=maps[j] if maps is not None else None,
                                    perturb=pstate)
                                for j in seg]
                    except ZeroPivotError as e:
                        raise e.with_context(
                            panel=int(store.sup_of_col[e.k]), level=li)
                    for j, upd, flops, dropped in panel_stats:
                        n_updates += upd
                        gemm_flops += flops
                        dropped_max = max(dropped_max, dropped)
                        if obs_on and flops:
                            s_, e_ = schedule.supernodes[j]
                            w_ = int(e_ - s_)
                            nb = (len(store.rows[j]) - int(store.diag[j]))
                            k_ = flops // (2 * nb * w_)
                            # gathered L panel + solved U rows read, target
                            # block read + written, all float64
                            gemm_bytes += 8 * (nb * k_ + k_ * w_ + 2 * nb * w_)
                if seg_times is not None:
                    seg_times.append(time.perf_counter() - seg_t0)
        if seg_times is not None and len(seg_times) > 1:
            mean_t = sum(seg_times) / len(seg_times)
            if mean_t > 0:
                _om.registry().observe("factor.level_imbalance_measured",
                                       max(seg_times) / mean_t)
    if obs_on:
        reg = _om.registry()
        reg.count("gemm.flops", gemm_flops)
        reg.count("gemm.bytes", gemm_bytes)
        reg.count("gemm.seconds", time.perf_counter() - sweep_t0)
        if pstate is not None and pstate.count:
            reg.count("robust.perturbed_pivots", int(pstate.count))

    outside_max = max(store.padding_max(), dropped_max)
    if check_pattern and outside_max > pattern_tol * scale:
        raise ValueError(
            f"numeric factorization escaped the symbolic prediction: "
            f"|{outside_max:.3e}| outside the pattern (tol "
            f"{pattern_tol * scale:.3e}) — symbolic under-prediction")
    store.zero_padding()

    return NumericResult(n=n, store=store, schedule=schedule, backend=backend,
                         elapsed_s=time.perf_counter() - t0,
                         n_updates=n_updates, gemm_flops=gemm_flops,
                         outside_max=outside_max,
                         perturbed_pivots=(pstate.count if pstate else 0))


@dataclasses.dataclass
class BatchedNumericResult:
    """Factors of B same-pattern value sets in one ``BatchedPanelStore``
    (DESIGN.md §14).

    ``n_updates``/``gemm_flops`` are *per system* — the sweep structure is
    value-independent, so every system does identical work and the numbers
    match what a standalone ``factor_on_store`` of any one system reports.
    ``outside_max`` is the (B,) per-system escape check.  ``system(i)``
    wraps system i's zero-copy store view as a plain ``NumericResult`` so
    per-system consumers (solve, dense oracle reconstruction, parity
    tests) run unchanged on batched factors.
    """

    n: int
    batch: int
    store: BatchedPanelStore
    schedule: PanelSchedule
    backend: str
    elapsed_s: float
    n_updates: int               # ancestor panel updates, per system
    gemm_flops: int              # trailing-GEMM flops, per system
    outside_max: np.ndarray      # (B,) largest |value| outside the pattern
    perturbed_pivots: Optional[np.ndarray] = None   # (B,) per-system counts

    @property
    def n_supernodes(self) -> int:
        return self.schedule.n_panels

    @property
    def n_levels(self) -> int:
        return self.schedule.n_levels

    def system(self, i: int) -> NumericResult:
        return NumericResult(n=self.n, store=self.store.system(i),
                             schedule=self.schedule, backend=self.backend,
                             elapsed_s=0.0, n_updates=self.n_updates,
                             gemm_flops=self.gemm_flops,
                             outside_max=float(self.outside_max[i]),
                             perturbed_pivots=(
                                 int(self.perturbed_pivots[i])
                                 if self.perturbed_pivots is not None else 0))


def _panel_prepare_batched(bstore: BatchedPanelStore,
                           schedule: PanelSchedule, j: int, maps=None):
    """``_panel_prepare`` broadcast over the system axis of a
    ``BatchedPanelStore``: one gather / rank-update pass serves all B
    systems.  Gathers and rank updates are batched (fancy indexing and
    stacked ``np.matmul`` are per-slice bitwise-identical to their 2D
    forms); the per-ancestor unit-lower solves stay per-system LAPACK
    calls, so every float op matches ``_panel_prepare`` on that system
    alone — the batched tier's conformance contract (DESIGN.md §14).

    Returns (lp (B, M, K), b (B, K, w), dropped (B,), flops-per-system).
    """
    s, e = schedule.supernodes[j]
    w = e - s
    anc = schedule.ancestors[j]
    block = bstore.blocks[j]
    d = int(bstore.diag[j])
    bsz = bstore.batch
    if not len(anc):
        return None, None, np.zeros(bsz, dtype=np.float64), 0
    if maps is None:
        maps = build_panel_maps(bstore.template, schedule, j)
    offs = maps.offs
    anc_rows = maps.anc_rows

    b = bstore.gather_rows_mapped(j, maps.idx_j, maps.hit_j)  # (B, K, w)
    for idx, k in enumerate(anc):
        r0, r1 = offs[idx], offs[idx + 1]
        strip = bstore.gather_rows_mapped(int(k), *maps.strip_maps[idx])
        if r1 - r0 > 1:           # 1-row solves are identity (unit lower)
            head = strip[:, :r1 - r0]
            for i in range(bsz):
                b[i, r0:r1] = solve_triangular(head[i], b[i, r0:r1],
                                               lower=True,
                                               unit_diagonal=True,
                                               check_finite=False)
        if r1 < len(anc_rows):
            b[:, r1:] -= np.matmul(strip[:, r1 - r0:], b[:, r0:r1])
    idx_j, hit_j = maps.idx_j, maps.hit_j         # solved U(anc, J)
    block[:, idx_j[hit_j]] = b[:, hit_j]
    dropped = np.zeros(bsz, dtype=np.float64)
    if not hit_j.all():
        miss = b[:, ~hit_j]
        if miss.size:
            dropped = np.abs(miss.reshape(bsz, -1)).max(axis=1)

    below = bstore.rows[j][d:]
    lp = np.empty((bsz, len(below), len(anc_rows)), dtype=np.float64)
    for idx, k in enumerate(anc):
        lp[:, :, offs[idx]:offs[idx + 1]] = bstore.gather_rows_mapped(
            int(k), *maps.below_maps[idx])
    flops = 2 * len(below) * len(anc_rows) * w
    return lp, b, dropped, flops


def _panel_finish_batched(bstore: BatchedPanelStore,
                          schedule: PanelSchedule, j: int,
                          piv_tol: np.ndarray,
                          perturb: PerturbState | None = None) -> None:
    """``_panel_finish`` over the system axis: elementwise batched
    diagonal LU (``lu_inplace_batched``) + per-system LAPACK below-panel
    solves; ``piv_tol`` is the (B,) per-system threshold."""
    s, e = schedule.supernodes[j]
    w = e - s
    block = bstore.blocks[j]
    d = int(bstore.diag[j])
    lu_inplace_batched(block[:, d:d + w], piv_tol, col0=s, perturb=perturb)
    if block.shape[1] > d + w:
        diag = block[:, d:d + w]
        for i in range(bstore.batch):
            block[i, d + w:] = _solve_upper_right(diag[i], block[i, d + w:])


def factor_batch_on_store(a: Optional[CSRMatrix], values_batch: np.ndarray,
                          bstore: BatchedPanelStore,
                          schedule: PanelSchedule, *,
                          backend: str = "numpy",
                          piv_tol: Optional[float] = None,
                          check_pattern: bool = True,
                          pattern_tol: Optional[float] = None,
                          maps=None, csr_maps=None,
                          store_is_zeroed: bool = False,
                          perturb: bool = False,
                          perturb_eps: Optional[float] = None
                          ) -> BatchedNumericResult:
    """``factor_on_store`` vmapped over B same-pattern value sets
    (DESIGN.md §14): scatter the (B, nnz) CSR-aligned stack into the
    batched store and run ONE level-scheduled sweep whose every per-panel
    operation carries a leading system axis.

    System i's factors are **bitwise-identical** to
    ``factor_on_store(a, values_batch[i], ...)`` on a standalone store:
    gathers/scatters and the trailing GEMMs broadcast over the batch
    (per-slice ``np.matmul`` parity on CPU, per-slice grid parity of the
    stacked Pallas dispatch on the kernel backend), the diagonal LU is the
    elementwise ``lu_inplace_batched``, and the triangular solves stay
    per-system LAPACK calls.  Pivot tolerance, the pattern-escape check,
    and ``ZeroPivotError`` are all per system (``piv_tol=None`` derives
    each system's threshold from its own value scale).

    Same-shape panels of a level additionally stack across the batch into
    one (panels x B)-deep GEMM dispatch — the within-plan segment batching
    of DESIGN.md §13 composed with the system axis.  Only CSR-aligned
    (B, nnz) values are supported (the batch tier is the refactorization
    server path; dense (n, n) stacks would defeat its memory point).
    """
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; pick from {_BACKENDS}")
    n = bstore.n
    bsz = bstore.batch
    if pattern_tol is None:
        pattern_tol = 1e-4 if backend == "kernel" else 1e-8
    t0 = time.perf_counter()

    values_batch = np.asarray(values_batch, dtype=np.float64)
    if csr_maps is None:
        if a is None:
            raise ValueError(
                "batched CSR values need the matrix `a` or precomputed "
                "`csr_maps` to locate their slots")
        csr_maps = bstore.template.csr_maps(a)
    if values_batch.shape != (bsz, csr_maps.nnz):
        raise ValueError(
            f"values_batch must be ({bsz}, {csr_maps.nnz}) CSR-aligned, "
            f"got {values_batch.shape}")
    with _ot.span("scatter_values"):
        input_outside = bstore.set_csr_mapped(values_batch, csr_maps,
                                              zero=not store_is_zeroed)

    scale = (np.abs(values_batch).max(axis=1) if values_batch.size
             else np.zeros(bsz, dtype=np.float64))
    if piv_tol is None:
        # vectorized pivot_tolerance: eps at each system's own value scale
        piv_tol_sys = np.finfo(np.float64).eps * np.maximum(scale, 0.0)
    else:
        piv_tol_sys = np.full(bsz, float(piv_tol))
    eps = np.float64(perturb_threshold(1.0, perturb_eps))
    pstate = PerturbState(eps * np.maximum(scale, 0.0)) if perturb else None

    n_updates = 0
    gemm_flops = 0
    dropped_max = input_outside.copy()
    obs_on = _ot.ENABLED
    sweep_t0 = time.perf_counter() if obs_on else 0.0
    batched_calls = 0
    batched_panels = 0
    for li, level in enumerate(schedule.levels):
        with _ot.span("factor_level"):
            operands = {}
            groups: dict = {}
            for j in level:
                j = int(j)
                lp, b, dropped, flops = _panel_prepare_batched(
                    bstore, schedule, j,
                    maps=maps[j] if maps is not None else None)
                n_updates += len(schedule.ancestors[j])
                gemm_flops += flops
                np.maximum(dropped_max, dropped, out=dropped_max)
                if lp is None:
                    continue
                operands[j] = (lp, b)
                groups.setdefault(lp.shape[1:] + (b.shape[2],), []).append(j)

            for (m, k, w), js in groups.items():
                if len(js) == 1:
                    # one panel, B systems: the (B, ., .) stack IS the batch
                    j = js[0]
                    lp, b = operands[j]
                    d = int(bstore.diag[j])
                    acc = bstore.blocks[j][:, d:]
                    if backend == "kernel":
                        from repro.kernels import ops as kops

                        upd = np.asarray(
                            kops.panel_update_systems(acc, lp, b),
                            dtype=np.float64)
                    else:
                        upd = acc - np.matmul(lp, b)
                    bstore.blocks[j][:, d:] = upd
                    continue
                # same-shape panel group x system batch: one stacked dispatch
                accs = np.concatenate(
                    [bstore.blocks[j][:, int(bstore.diag[j]):] for j in js])
                lps = np.concatenate([operands[j][0] for j in js])
                bs = np.concatenate([operands[j][1] for j in js])
                if backend == "kernel":
                    from repro.kernels import ops as kops

                    upds = np.asarray(
                        kops.panel_update_systems(accs, lps, bs),
                        dtype=np.float64)
                else:
                    upds = accs - np.matmul(lps, bs)
                for gi, j in enumerate(js):
                    d = int(bstore.diag[j])
                    bstore.blocks[j][:, d:] = upds[gi * bsz:(gi + 1) * bsz]
                batched_calls += 1
                batched_panels += len(js)
                if obs_on:
                    reg = _om.registry()
                    reg.count("gemm.batched.flops",
                              2 * len(js) * bsz * m * k * w)
                    reg.count("gemm.batched.bytes",
                              8 * len(js) * bsz * (m * k + k * w + 2 * m * w))

            for j in level:
                try:
                    _panel_finish_batched(bstore, schedule, int(j),
                                          piv_tol_sys, perturb=pstate)
                except ZeroPivotError as e:
                    raise e.with_context(panel=int(j), level=li)
    if obs_on:
        reg = _om.registry()
        if batched_calls:
            reg.count("gemm.batched.calls", batched_calls)
            reg.count("gemm.batched.panels", batched_panels)
        reg.count("gemm.flops", gemm_flops * bsz)
        reg.count("gemm.seconds", time.perf_counter() - sweep_t0)
        if pstate is not None and pstate.total():
            reg.count("robust.perturbed_pivots", pstate.total())

    outside_max = np.maximum(bstore.padding_max(), dropped_max)
    bad = outside_max > pattern_tol * scale
    if check_pattern and bad.any():
        i = int(np.flatnonzero(bad)[0])
        raise ValueError(
            f"numeric factorization escaped the symbolic prediction: "
            f"system {i} has |{outside_max[i]:.3e}| outside the pattern "
            f"(tol {pattern_tol * scale[i]:.3e}) — symbolic "
            f"under-prediction")
    bstore.zero_padding()

    return BatchedNumericResult(n=n, batch=bsz, store=bstore,
                                schedule=schedule, backend=backend,
                                elapsed_s=time.perf_counter() - t0,
                                n_updates=n_updates, gemm_flops=gemm_flops,
                                outside_max=outside_max,
                                perturbed_pivots=(
                                    pstate.count if pstate is not None
                                    else np.zeros(bsz, dtype=np.int64)))


def numeric_factorize(a: CSRMatrix, sym=None, *,
                      values: Optional[np.ndarray] = None,
                      pattern=None,
                      supernodes: Optional[np.ndarray] = None,
                      n_bins: int = 8, policy: str = "lpt",
                      backend: str = "numpy",
                      piv_tol: Optional[float] = None,
                      check_pattern: bool = True,
                      pattern_tol: Optional[float] = None,
                      segment_batch: bool = True) -> NumericResult:
    """Supernodal left-looking LU of ``values`` on A's structure, factored
    in O(nnz(L+U)) packed CSC-panel storage.

    ``a``: structural CSR; ``sym``: a ``SymbolicResult`` from
    ``symbolic_factorize(a, detect_supernodes=True)`` (computed on the fly
    when omitted; without a supernode partition the serial detector runs on
    the pattern).  ``supernodes``: explicit (k, 2) panel ranges, overriding
    ``sym`` — any contiguous partition is valid (padding absorbs
    non-uniform structure exactly like relaxed T3 merges).

    ``values``: either dense (n, n) float64 on A's pattern (legacy
    oracle-friendly form) or CSR-aligned (nnz,) float64 pairing
    ``a.indices`` — the sparse form never materializes (n, n) and is the
    one to use at large n (defaults to ``generic_values_csr(a)``).
    ``pattern``: the predicted L+U pattern as dense (n, n) bool or a
    ``storage.CSCPattern`` (recomputed from the graph when omitted — a
    dense small-n convenience).  ``backend``: "numpy" (float64 BLAS,
    default) or "kernel" (float32 Pallas MXU panel updates — TPU precision
    documented in DESIGN.md §4).

    Raises ``ZeroPivotError`` (global column index) on zero/near-zero pivots
    and ``ValueError`` if any value above ``pattern_tol * scale`` escapes the
    symbolic prediction (the ``validate_symbolic`` contract).

    This rebuilds the schedule, the packed store structure, and the gather
    maps from scratch on *every* call; refactorization workloads (same
    pattern, new values) should use ``repro.analyze`` once and
    ``LUPlan.factorize`` per value set instead (repro.api, DESIGN.md §10).
    """
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; pick from {_BACKENDS}")
    t0 = time.perf_counter()
    n = a.n

    if values is None:
        values = generic_values_csr(a)
    values = np.asarray(values, dtype=np.float64)
    if values.ndim == 2:
        if values.shape != (n, n):
            raise ValueError(f"values must be ({n}, {n}), got {values.shape}")
    elif values.shape != (a.nnz,):
        raise ValueError(
            f"values must be dense ({n}, {n}) or CSR-aligned ({a.nnz},), "
            f"got {values.shape}")

    if pattern is None:
        from repro.core.gsofa import dense_pattern, prepare_graph

        pattern = dense_pattern(prepare_graph(a))
    if not isinstance(pattern, CSCPattern):
        pattern = np.asarray(pattern, dtype=bool)
        if pattern.shape != (n, n):
            raise ValueError(f"pattern must be ({n}, {n}), got "
                             f"{pattern.shape}")
        pattern = CSCPattern.from_dense(pattern)
    else:
        pattern = pattern.with_diagonal()
    if pattern.n != n:
        raise ValueError(f"pattern is for n={pattern.n}, matrix has n={n}")

    if supernodes is None:
        if sym is None:
            from repro.core.symbolic import symbolic_factorize

            sym = symbolic_factorize(a, detect_supernodes=True)
        if sym.n != n:
            raise ValueError(
                f"symbolic result is for n={sym.n}, matrix has n={n}")
        supernodes = sym.supernodes
        if supernodes is None:
            from repro.core.symbolic import detect_supernodes as _detect

            supernodes = _detect(pattern.to_dense())

    schedule = build_schedule(pattern, supernodes, n_bins=n_bins,
                              policy=policy)
    store = PanelStore(pattern, schedule.supernodes)
    result = factor_on_store(a, values, store, schedule, backend=backend,
                             piv_tol=piv_tol, check_pattern=check_pattern,
                             pattern_tol=pattern_tol,
                             segment_batch=segment_batch)
    result.elapsed_s = time.perf_counter() - t0
    return result


def factorize_columns(values: np.ndarray, pattern: np.ndarray, *,
                      piv_tol: Optional[float] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Column-at-a-time left-looking sparse LU — the pre-supernodal baseline
    (one axpy per structural U entry, no panel batching), used by
    ``benchmarks/bench_numeric.py`` as the comparison point and by tests as
    an independent implementation.  Same pivot contract as the supernodal
    path."""
    values = np.asarray(values, dtype=np.float64)
    n = values.shape[0]
    pattern = np.asarray(pattern, dtype=bool).copy()
    np.fill_diagonal(pattern, True)
    m = values.copy()
    if piv_tol is None:
        piv_tol = pivot_tolerance(np.abs(m).max() if m.size else 0.0)
    # CSC-style below-diagonal structure of every L column, precomputed
    lrows = [j + 1 + np.flatnonzero(pattern[j + 1:, j]) for j in range(n)]
    for j in range(n):
        for k in np.flatnonzero(pattern[:j, j]):
            rows = lrows[k]
            m[rows, j] -= m[rows, k] * m[k, j]
        piv = m[j, j]
        check_pivot(j, piv, piv_tol)
        m[lrows[j], j] /= piv
    m[~pattern] = 0.0
    l = np.tril(m, -1) + np.eye(n)
    u = np.triu(m)
    return l, u
