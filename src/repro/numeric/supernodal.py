"""Supernodal left-looking numeric LU consuming the panel partition
(DESIGN.md §4).

This is the step the symbolic phase exists to feed: ``CSRMatrix`` values plus
a ``SymbolicResult`` (counts, supernodes) in, unit-lower L and upper U out,
factorized panel-by-panel:

* **Panel gather** — each supernode J = [s, e) is a dense (rows, w) block;
  the gathered structural rows of L(s:, J) and the ancestor U rows live as
  contiguous dense operands, which is what dense hardware wants (GLU3.0-style
  batched updates; structure-aware blocking per arXiv:2512.04389).
* **Left-looking updates** — ancestors K of J (supernodes with a structural
  ``U(K, J)`` block, schedule.py) are consumed in ascending order: solve
  ``U(K, J) = L(K, K)^{-1} X(K, J)``, scatter the rank-|K| update into the
  rows of *later* ancestors, and **defer the whole trailing update to one
  accumulated GEMM** ``X(s:, J) -= L(s:, anc) @ U(anc, J)`` over the gathered
  ancestor columns — the MXU panel-update kernel
  (``kernels/panel_update.py``; numpy float64 BLAS on the default backend).
* **Panel factor** — dense no-pivot LU of the diagonal block (raising
  ``ZeroPivotError`` with the global column on zero/near-zero pivots), then
  one triangular solve for the below-panel L rows.
* **Level schedule** — panels are processed by dependency level
  (schedule.py); within a level they are independent and grouped by the
  ``pack_panels`` bins.  The factors are bitwise invariant to the packing
  policy (LPT vs contiguous) because per-panel math never reads same-level
  data.

Structural exactness: updates and solves are restricted to the structural
rows of the predicted pattern, so entries outside the symbolic prediction
are *exactly* zero except under relaxed (T3) merges, where the explicit-zero
padding of a panel is bounded by ``pattern_tol`` and zeroed (anything larger
escaping the pattern raises — that would be a symbolic bug, the
``validate_symbolic`` contract).

``sparse/numeric.py::lu_nopivot`` stays the dense O(n^2) test oracle;
``factorize_columns`` here is the honest column-at-a-time sparse baseline
the benchmark compares against.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import numpy as np
from scipy.linalg import solve_triangular

from repro.numeric.schedule import PanelSchedule, build_schedule
from repro.sparse.csr import CSRMatrix
from repro.sparse.numeric import (
    check_pivot, generic_values, lu_inplace, pivot_tolerance,
)

_BACKENDS = ("numpy", "kernel")


@dataclasses.dataclass
class NumericResult:
    """Factors + scheduling/perf counters of one supernodal factorization."""

    n: int
    l: np.ndarray                # (n, n) float64, unit lower (diag = 1)
    u: np.ndarray                # (n, n) float64, upper incl. diagonal
    schedule: PanelSchedule
    backend: str
    elapsed_s: float
    n_updates: int               # ancestor panel updates consumed
    gemm_flops: int              # flops of the accumulated trailing GEMMs
    outside_max: float           # largest |value| found outside the pattern

    @property
    def n_supernodes(self) -> int:
        return self.schedule.n_panels

    @property
    def n_levels(self) -> int:
        return self.schedule.n_levels

    def reconstruct(self) -> np.ndarray:
        """L @ U — for residual checks against the assembled matrix."""
        return self.l @ self.u


def _solve_unit_lower(block: np.ndarray, rhs: np.ndarray) -> np.ndarray:
    """X with (I + strict_lower(block)) @ X = rhs (block stores L\\U packed)."""
    if block.shape[0] == 1:
        return rhs.copy()
    return solve_triangular(block, rhs, lower=True, unit_diagonal=True,
                            check_finite=False)


def _solve_upper_right(block: np.ndarray, rhs: np.ndarray) -> np.ndarray:
    """X with X @ triu(block) = rhs (below-panel L rows)."""
    if block.shape[0] == 1:
        return rhs / block[0, 0]
    return solve_triangular(block, rhs.T, lower=False, trans="T",
                            check_finite=False).T


def _factor_panel(m: np.ndarray, pattern: np.ndarray, schedule: PanelSchedule,
                  j: int, piv_tol: float, backend: str) -> Tuple[int, int]:
    """Factor panel j in place; returns (#ancestor updates, trailing flops)."""
    s, e = schedule.supernodes[j]
    w = e - s
    cols = np.arange(s, e)
    anc = schedule.ancestors[j]
    rows_below = s + np.flatnonzero(pattern[s:, s:e].any(axis=1))
    flops = 0

    if len(anc):
        widths = schedule.supernodes[anc, 1] - schedule.supernodes[anc, 0]
        offs = np.concatenate([[0], np.cumsum(widths)])
        anc_rows = np.concatenate([np.arange(ks, ke)
                                   for ks, ke in schedule.supernodes[anc]])

        # 1. gather the ancestor sub-matrix and target rows into dense blocks
        #    ONCE; the ascending per-ancestor solves + rank-|K| updates then
        #    run on contiguous slices (non-ancestor rows above s are exact
        #    zeros — never touched)
        lsub = m[np.ix_(anc_rows, anc_rows)]          # (K, K) gathered L
        b = m[np.ix_(anc_rows, cols)]                 # (K, w) gathered X rows
        for idx in range(len(anc)):
            r0, r1 = offs[idx], offs[idx + 1]
            b[r0:r1] = _solve_unit_lower(lsub[r0:r1, r0:r1], b[r0:r1])
            if r1 < len(anc_rows):
                b[r1:] -= lsub[r1:, r0:r1] @ b[r0:r1]
        m[np.ix_(anc_rows, cols)] = b                 # solved U(anc, J)

        # 2. accumulated trailing update: one GEMM over the gathered ancestor
        #    L panel against the solved U rows (MXU kernel on TPU)
        lp = m[np.ix_(rows_below, anc_rows)]
        acc = m[np.ix_(rows_below, cols)]
        if backend == "kernel":
            from repro.kernels import ops as kops

            upd = np.asarray(kops.panel_update(acc, lp, b), dtype=np.float64)
        else:
            upd = acc - lp @ b
        m[np.ix_(rows_below, cols)] = upd
        flops = 2 * len(rows_below) * len(anc_rows) * w

    # 3. diagonal-block factor + below-panel triangular solve
    lu_inplace(m[s:e, s:e], piv_tol, col0=s)
    rows_gt = rows_below[rows_below >= e]
    if len(rows_gt):
        m[np.ix_(rows_gt, cols)] = _solve_upper_right(
            m[s:e, s:e], m[np.ix_(rows_gt, cols)])
    return len(anc), flops


def numeric_factorize(a: CSRMatrix, sym=None, *,
                      values: Optional[np.ndarray] = None,
                      pattern: Optional[np.ndarray] = None,
                      n_bins: int = 8, policy: str = "lpt",
                      backend: str = "numpy",
                      piv_tol: Optional[float] = None,
                      check_pattern: bool = True,
                      pattern_tol: Optional[float] = None) -> NumericResult:
    """Supernodal left-looking LU of ``values`` on A's structure.

    ``a``: structural CSR; ``sym``: a ``SymbolicResult`` from
    ``symbolic_factorize(a, detect_supernodes=True)`` (computed on the fly
    when omitted; without a supernode partition the serial detector runs on
    the pattern).  ``values``: dense (n, n) float64 on A's pattern (defaults
    to ``generic_values(a)``); ``pattern``: the dense predicted L+U pattern
    (recomputed from the graph when omitted).  ``backend``: "numpy" (float64
    BLAS, default) or "kernel" (float32 Pallas MXU panel updates — TPU
    precision documented in DESIGN.md §4).

    Raises ``ZeroPivotError`` (global column index) on zero/near-zero pivots
    and ``ValueError`` if any value above ``pattern_tol * scale`` escapes the
    symbolic prediction (the ``validate_symbolic`` contract).
    """
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; pick from {_BACKENDS}")
    if pattern_tol is None:
        # float32 MXU updates leave f32-roundoff garbage at the explicit
        # zeros of relaxed panels; the float64 path stays at f64 roundoff
        pattern_tol = 1e-4 if backend == "kernel" else 1e-8
    t0 = time.perf_counter()
    n = a.n
    if values is None:
        values = generic_values(a)
    values = np.asarray(values, dtype=np.float64)
    if values.shape != (n, n):
        raise ValueError(f"values must be ({n}, {n}), got {values.shape}")
    if pattern is None:
        from repro.core.gsofa import dense_pattern, prepare_graph

        pattern = dense_pattern(prepare_graph(a))
    pattern = np.asarray(pattern, dtype=bool).copy()
    if pattern.shape != (n, n):
        raise ValueError(f"pattern must be ({n}, {n}), got {pattern.shape}")
    np.fill_diagonal(pattern, True)

    if sym is None:
        from repro.core.symbolic import symbolic_factorize

        sym = symbolic_factorize(a, detect_supernodes=True)
    if sym.n != n:
        raise ValueError(f"symbolic result is for n={sym.n}, matrix has n={n}")
    supernodes = sym.supernodes
    if supernodes is None:
        from repro.core.symbolic import detect_supernodes

        supernodes = detect_supernodes(pattern)

    schedule = build_schedule(pattern, supernodes, n_bins=n_bins,
                              policy=policy)
    scale = float(np.abs(values).max()) if values.size else 0.0
    if piv_tol is None:
        piv_tol = pivot_tolerance(scale)

    m = values.copy()
    n_updates = 0
    gemm_flops = 0
    for level in schedule.levels:
        for j in level:
            upd, flops = _factor_panel(m, pattern, schedule, int(j),
                                       piv_tol, backend)
            n_updates += upd
            gemm_flops += flops

    outside = ~pattern
    outside_max = float(np.abs(m[outside]).max()) if outside.any() else 0.0
    if check_pattern and outside_max > pattern_tol * scale:
        raise ValueError(
            f"numeric factorization escaped the symbolic prediction: "
            f"|{outside_max:.3e}| outside the pattern (tol "
            f"{pattern_tol * scale:.3e}) — symbolic under-prediction")
    m[outside] = 0.0

    l = np.tril(m, -1) + np.eye(n)
    u = np.triu(m)
    return NumericResult(n=n, l=l, u=u, schedule=schedule, backend=backend,
                         elapsed_s=time.perf_counter() - t0,
                         n_updates=n_updates, gemm_flops=gemm_flops,
                         outside_max=outside_max)


def factorize_columns(values: np.ndarray, pattern: np.ndarray, *,
                      piv_tol: Optional[float] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Column-at-a-time left-looking sparse LU — the pre-supernodal baseline
    (one axpy per structural U entry, no panel batching), used by
    ``benchmarks/bench_numeric.py`` as the comparison point and by tests as
    an independent implementation.  Same pivot contract as the supernodal
    path."""
    values = np.asarray(values, dtype=np.float64)
    n = values.shape[0]
    pattern = np.asarray(pattern, dtype=bool).copy()
    np.fill_diagonal(pattern, True)
    m = values.copy()
    if piv_tol is None:
        piv_tol = pivot_tolerance(np.abs(m).max() if m.size else 0.0)
    # CSC-style below-diagonal structure of every L column, precomputed
    lrows = [j + 1 + np.flatnonzero(pattern[j + 1:, j]) for j in range(n)]
    for j in range(n):
        for k in np.flatnonzero(pattern[:j, j]):
            rows = lrows[k]
            m[rows, j] -= m[rows, k] * m[k, j]
        piv = m[j, j]
        check_pivot(j, piv, piv_tol)
        m[lrows[j], j] /= piv
    m[~pattern] = 0.0
    l = np.tril(m, -1) + np.eye(n)
    u = np.triu(m)
    return l, u
