"""Panel-level scheduling for supernodal numeric LU (DESIGN.md §4).

The symbolic step hands over a supernode partition — contiguous ``[start,
end)`` column ranges with identical below-diagonal structure — and the
numeric step must factor those panels in an order that respects column
dependencies.  Panel J depends on panel K < J iff the filled pattern has a
structural nonzero in the U block ``U(K, J)`` (rows of K, columns of J):
exactly then does K's L panel update J.  That is the supernodal elimination
DAG (the condensation of the column etree onto supernodes).

``build_schedule`` derives, from the predicted pattern (dense bool (n, n)
or the sparse ``storage.CSCPattern`` — the sparse form is what the
O(nnz(L+U)) packed path feeds it, nothing here materializes (n, n)):

* ``ancestors[j]`` — the update list of panel j (ascending supernode ids);
  left-looking consumes it in order: solve ``U(K, J)`` against L(K, K),
  scatter into the rows of *later* ancestors, and defer the trailing rows to
  one accumulated GEMM (supernodal.py);
* ``level``/``levels`` — longest-path dependency levels: panels within a
  level share no ancestor relation and can be factored independently (batch
  unit for MXU dispatch / device assignment);
* ``partition`` — the ``pack_panels`` bin assignment (LPT or contiguous) the
  scheduler uses to group independent panels within a level; the numeric
  result is invariant to the packing policy (tests assert bitwise equality),
  only the batching/placement changes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.numeric.storage import CSCPattern
from repro.obs import metrics as _om
from repro.obs import trace as _ot
from repro.supernodes.balance import PanelPartition, pack_panels


@dataclasses.dataclass
class PanelSchedule:
    """Dependency-levelled execution plan over the supernode partition."""

    supernodes: np.ndarray        # (k, 2) [start, end) column ranges
    ancestors: List[np.ndarray]   # per panel: ascending ids of update panels
    level: np.ndarray             # (k,) dependency level of each panel
    levels: List[np.ndarray]      # panel ids per level, in execution order
    partition: PanelPartition     # pack_panels bins (batching/placement)
    col_counts: np.ndarray        # (n,) below-diagonal column counts of L

    @property
    def n_panels(self) -> int:
        return len(self.supernodes)

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def stats(self) -> dict:
        widths = self.supernodes[:, 1] - self.supernodes[:, 0]
        n_updates = sum(len(a) for a in self.ancestors)
        return {
            "n_panels": self.n_panels,
            "n_levels": self.n_levels,
            "mean_level_width": (self.n_panels / max(1, self.n_levels)),
            "max_panel_cols": int(widths.max()) if len(widths) else 0,
            "n_updates": n_updates,
            "balance_ratio": self.partition.balance_ratio,
        }


@dataclasses.dataclass(frozen=True)
class PanelPlacement:
    """Device assignment of panels for multi-device factorize/solve
    (DESIGN.md §11).

    Derived from ``pack_panels`` bins computed *per dependency level*:
    each level's panels — which are exactly the independent work of one
    sweep step — are LPT-packed by predicted L-panel nnz into
    ``n_devices`` bins, so every level's critical path is within one
    panel weight of optimal.  Within a level panels are independent
    (left-looking panels only read strictly-earlier levels), so *any*
    segment execution order yields bitwise-identical factors — placement
    changes scheduling/dispatch, never math; that is what makes factors
    invariant to the device count (the conformance-tier contract).

    Plain numpy only — plans stay picklable; the mesh itself is never
    stored (rebuild one with ``launch.mesh.make_flat_mesh`` where needed).
    """

    n_devices: int
    axis: str                      # mesh axis name (launch.mesh.FLAT_AXIS)
    device_of_panel: np.ndarray    # (k,) int64 device id per panel

    def segments(self, members: np.ndarray) -> List[np.ndarray]:
        """Per-device panel lists of one level (ascending ids within each
        segment; devices without work get empty segments)."""
        members = np.asarray(members, dtype=np.int64)
        dev = self.device_of_panel[members]
        return [np.sort(members[dev == d]) for d in range(self.n_devices)]

    def level_loads(self, schedule: "PanelSchedule") -> np.ndarray:
        """(n_levels, n_devices) packed panel weight per device per level —
        the placement-quality surface bench_distributed reports."""
        from repro.supernodes.balance import supernode_weights

        weights = supernode_weights(schedule.supernodes, schedule.col_counts)
        out = np.zeros((schedule.n_levels, self.n_devices), dtype=np.int64)
        for lv, members in enumerate(schedule.levels):
            np.add.at(out[lv], self.device_of_panel[members],
                      weights[members])
        return out


def build_placement(schedule: PanelSchedule, n_devices: int, *,
                    axis: str = "shards",
                    policy: str = "lpt") -> PanelPlacement:
    """Panel -> device assignment from per-level ``pack_panels`` bins (see
    ``PanelPlacement``).  ``n_devices=1`` degenerates to everything on
    device 0 — the same code path the conformance tier runs at every
    count."""
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    with _ot.span("placement"):
        device_of_panel = np.zeros(schedule.n_panels, dtype=np.int64)
        for members in schedule.levels:
            if not len(members):
                continue
            part = pack_panels(schedule.supernodes[members],
                               schedule.col_counts,
                               min(n_devices, len(members)), policy=policy)
            device_of_panel[members] = part.assignment
        placement = PanelPlacement(n_devices=n_devices, axis=axis,
                                   device_of_panel=device_of_panel)
        if _ot.ENABLED and n_devices > 1:
            # modeled per-level imbalance: max/mean packed bin weight of the
            # LPT assignment — the planning-time counterpart of the measured
            # segment-time imbalance factor_on_store records
            loads = placement.level_loads(schedule)
            reg = _om.registry()
            for lv in range(loads.shape[0]):
                busy = loads[lv][loads[lv] > 0]
                if len(busy):
                    reg.observe("placement.imbalance_modeled",
                                float(busy.max()) / float(busy.mean()))
        return placement


@dataclasses.dataclass
class PanelMaps:
    """Value-independent row-index maps of one panel's ancestor updates.

    Everything ``supernodal._factor_panel`` would otherwise re-derive with
    ``searchsorted`` on every factorization: the concatenated ancestor
    diagonal rows, each ancestor's (idx, hit) gather map for its L strip at
    those rows and at the panel's >= s rows, and the scatter map of the
    solved U rows back into the panel block.  Built once per analysis
    (``build_gather_maps``), replayed on every ``LUPlan.factorize`` —
    bitwise-identical math, none of the map reconstruction.
    """

    anc_rows: np.ndarray                 # concatenated ancestor diag rows
    offs: np.ndarray                     # (len(anc)+1,) strip offsets
    strip_maps: List[tuple]              # per ancestor: (idx, hit) at anc_rows[r0:]
    below_maps: List[tuple]              # per ancestor: (idx, hit) at rows >= s
    idx_j: np.ndarray                    # scatter of solved U(anc, J) into block j
    hit_j: np.ndarray


def build_panel_maps(store, schedule: PanelSchedule,
                     j: int) -> Optional[PanelMaps]:
    """Maps for one panel (``None`` when it has no ancestors)."""
    anc = schedule.ancestors[j]
    if not len(anc):
        return None
    widths = schedule.supernodes[anc, 1] - schedule.supernodes[anc, 0]
    offs = np.concatenate([[0], np.cumsum(widths)])
    anc_rows = np.concatenate([np.arange(ks, ke)
                               for ks, ke in schedule.supernodes[anc]])
    below = store.rows[j][int(store.diag[j]):]
    strip_maps = [store.local_rows(int(k), anc_rows[offs[idx]:])
                  for idx, k in enumerate(anc)]
    below_maps = [store.local_rows(int(k), below) for k in anc]
    idx_j, hit_j = store.local_rows(j, anc_rows)
    return PanelMaps(anc_rows=anc_rows, offs=offs, strip_maps=strip_maps,
                     below_maps=below_maps, idx_j=idx_j, hit_j=hit_j)


def build_gather_maps(store, schedule: PanelSchedule) -> List[Optional[PanelMaps]]:
    """Precompute every panel's ancestor gather/scatter maps from the packed
    row structure — the value-independent half of ``supernodal
    ._factor_panel``, built once per analysis and replayed per factorize."""
    return [build_panel_maps(store, schedule, j)
            for j in range(schedule.n_panels)]


def _validate_supernodes(supernodes: np.ndarray, n: int) -> np.ndarray:
    supernodes = np.asarray(supernodes, dtype=np.int64)
    if supernodes.ndim != 2 or supernodes.shape[1] != 2:
        raise ValueError(f"supernodes must be (k, 2) ranges, got "
                         f"{supernodes.shape}")
    if len(supernodes):
        if supernodes[0, 0] != 0 or supernodes[-1, 1] != n:
            raise ValueError("supernode ranges must cover [0, n)")
        if not (supernodes[1:, 0] == supernodes[:-1, 1]).all():
            raise ValueError("supernode ranges must be contiguous")
        if not (supernodes[:, 1] > supernodes[:, 0]).all():
            raise ValueError("supernode ranges must be non-empty")
    elif n:
        raise ValueError(f"no supernodes for an order-{n} matrix")
    return supernodes


def build_schedule(pattern, supernodes: np.ndarray, *,
                   n_bins: int = 8, policy: str = "lpt") -> PanelSchedule:
    """Schedule from the predicted L+U pattern and supernode ranges.

    ``pattern``: dense (n, n) bool (diagonal included — what
    ``core.gsofa.dense_pattern`` returns) or a ``storage.CSCPattern``; the
    sparse form keeps scheduling O(nnz(L+U)) for the packed storage path.
    ``n_bins``: pack_panels bin count for within-level grouping (clamped to
    the panel count so small problems don't over-provision).
    """
    if not isinstance(pattern, CSCPattern):
        pattern = CSCPattern.from_dense(pattern)
    n = pattern.n
    supernodes = _validate_supernodes(supernodes, n)
    k = len(supernodes)

    sup_of_col = np.repeat(np.arange(k, dtype=np.int64),
                           supernodes[:, 1] - supernodes[:, 0])
    col_counts = pattern.below_diag_counts()

    ancestors: List[np.ndarray] = []
    level = np.zeros(k, dtype=np.int64)
    for j, (s, e) in enumerate(supernodes):
        seg = pattern.rowind[pattern.indptr[s]:pattern.indptr[e]]
        anc = np.unique(sup_of_col[seg[seg < s]])
        ancestors.append(anc)
        level[j] = level[anc].max() + 1 if len(anc) else 0

    partition = pack_panels(supernodes, col_counts,
                            max(1, min(n_bins, k)) if k else max(0, n_bins),
                            policy=policy)

    levels: List[np.ndarray] = []
    for lv in range(int(level.max()) + 1 if k else 0):
        members = np.flatnonzero(level == lv)
        # group by pack_panels bin (batch/placement unit), stable within bin
        order = np.lexsort((members, partition.assignment[members]))
        levels.append(members[order])

    return PanelSchedule(supernodes=supernodes, ancestors=ancestors,
                         level=level, levels=levels, partition=partition,
                         col_counts=col_counts)
