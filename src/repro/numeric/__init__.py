"""Supernodal numeric LU + solver consuming the symbolic panel partition.

Pipeline (DESIGN.md §4, §9): ``symbolic_factorize(a, detect_supernodes=True)``
predicts the L/U structure and the supernode ranges -> schedule.py condenses
the column dependencies onto panels (ancestor lists + dependency levels +
``pack_panels`` bins) -> storage.py allocates one packed (rows_J, w_J) block
per panel straight from the prediction (O(nnz(L+U)) working memory, no dense
(n, n) scratch) -> supernodal.py factorizes panel-by-panel with accumulated
dense GEMM updates on the packed blocks (Pallas MXU kernel
``kernels/panel_update.py`` on TPU, float64 BLAS by default) -> solve.py runs
supernodal triangular substitution + iterative refinement on the factors.

    from repro.core.symbolic import symbolic_factorize
    from repro.numeric import solve
    sym = symbolic_factorize(a, detect_supernodes=True)
    res = solve(a, b, sym=sym)               # ||A res.x - b|| / ||b|| <= 1e-10

(The supported public surface is the plan/factor session API,
``repro.analyze`` — this layer is the engine room.)

``sparse/numeric.py::lu_nopivot`` remains the dense test oracle;
``factorize_columns`` is the column-at-a-time baseline the benchmark
(``benchmarks/bench_numeric.py``) compares against.
"""
from repro.numeric.schedule import (
    PanelMaps, PanelPlacement, PanelSchedule, build_gather_maps,
    build_placement, build_schedule,
)
from repro.numeric.solve import (
    BatchedSolveResult, SolveResult, SolveSchedule, backward_substitute,
    backward_substitute_batch, build_solve_schedule, forward_substitute,
    forward_substitute_batch, solve, solve_batch, solve_factored,
    solve_factored_batch,
)
from repro.numeric.storage import (
    BatchedPanelStore, CSCPattern, CsrScatterMaps, PanelStore,
    uniform_supernodes,
)
from repro.numeric.supernodal import (
    BatchedNumericResult, NumericResult, factor_batch_on_store,
    factor_on_store, factorize_columns, numeric_factorize,
)
from repro.sparse.numeric import ZeroPivotError

__all__ = [
    "PanelMaps", "PanelPlacement", "PanelSchedule", "build_gather_maps",
    "build_placement", "build_schedule",
    "CSCPattern", "CsrScatterMaps", "PanelStore", "BatchedPanelStore",
    "uniform_supernodes",
    "NumericResult", "BatchedNumericResult", "factor_on_store",
    "factor_batch_on_store", "factorize_columns", "numeric_factorize",
    "SolveResult", "BatchedSolveResult", "SolveSchedule",
    "build_solve_schedule",
    "forward_substitute", "backward_substitute", "solve", "solve_factored",
    "forward_substitute_batch", "backward_substitute_batch", "solve_batch",
    "solve_factored_batch",
    "ZeroPivotError",
]
