"""Supernodal numeric LU consuming the symbolic panel partition.

Pipeline (DESIGN.md §4): ``symbolic_factorize(a, detect_supernodes=True)``
predicts the L/U structure and the supernode ranges -> schedule.py condenses
the column dependencies onto panels (ancestor lists + dependency levels +
``pack_panels`` bins) -> supernodal.py factorizes panel-by-panel with
accumulated dense GEMM updates (Pallas MXU kernel
``kernels/panel_update.py`` on TPU, float64 BLAS by default).

    from repro import numeric_factorize, symbolic_factorize
    sym = symbolic_factorize(a, detect_supernodes=True)
    num = numeric_factorize(a, sym)          # num.l @ num.u == A (on pattern)

``sparse/numeric.py::lu_nopivot`` remains the dense test oracle;
``factorize_columns`` is the column-at-a-time baseline the benchmark
(``benchmarks/bench_numeric.py``) compares against.
"""
from repro.numeric.schedule import PanelSchedule, build_schedule
from repro.numeric.supernodal import (
    NumericResult, factorize_columns, numeric_factorize,
)
from repro.sparse.numeric import ZeroPivotError

__all__ = [
    "PanelSchedule", "build_schedule",
    "NumericResult", "factorize_columns", "numeric_factorize",
    "ZeroPivotError",
]
