"""CSC-panel working storage for the supernodal numeric LU (DESIGN.md §9).

The symbolic phase predicts the filled L+U structure, so numeric working
memory can be allocated *from that prediction* instead of as a dense (n, n)
scratch matrix (GLU3.0-style compressed panel storage): one contiguous
``(rows_J, w_J)`` float64 block per supernode panel J = [s, e), holding every
structural row of the panel's columns — U rows above the diagonal block, the
packed L\\U diagonal block, and the below-panel L rows:

    global rows          local layout of ``blocks[j]`` (sorted ascending)
    r0 < r1 < ... < s    [0 : diag[j]]          U(r, J) rows of ancestors
    s .. e-1             [diag[j] : diag[j]+w]  diagonal block (L\\U packed)
    rk > ... > e-1       [diag[j]+w : ]         below-panel L(r, J) rows

Peak working memory is O(nnz(L+U)) plus the per-panel row padding (a column
stores the *union* of the panel's row patterns, exactly like relaxed T3
supernode merges pad the dense path), which lifts the numeric size ceiling
from n ≲ few thousand (dense scratch) to n in the tens of thousands.

Row-index maps: panel rows are kept sorted, so a gather of arbitrary global
rows out of a panel is one ``searchsorted`` + validity mask (absent rows are
structural zeros and gather as 0.0) — this is how ancestor-panel gathers feed
the accumulated Pallas GEMM (``kernels/panel_update.py``) with dense packed
operands without ever slicing an n×n array.

``CSCPattern`` is the sparse (per-column rows) form of the predicted L+U
pattern that the store and the scheduler consume; ``to_dense`` /
``dense_lu`` are *test/oracle* helpers — nothing on the factorization or
solve path materializes (n, n).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSCPattern:
    """Per-column structural rows of the predicted L+U pattern.

    ``indptr``/``rowind`` follow compressed-sparse-column convention: column
    j's rows are ``rowind[indptr[j]:indptr[j+1]]``, strictly ascending.  The
    diagonal is always present (``with_diagonal`` enforces it), matching the
    dense path's ``np.fill_diagonal(pattern, True)``.
    """

    n: int
    indptr: np.ndarray   # (n+1,) int64
    rowind: np.ndarray   # (nnz,) int64, sorted within each column

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def col(self, j: int) -> np.ndarray:
        return self.rowind[self.indptr[j]:self.indptr[j + 1]]

    @classmethod
    def from_dense(cls, pattern: np.ndarray) -> "CSCPattern":
        """From a dense bool (n, n) pattern (diagonal forced True)."""
        pattern = np.asarray(pattern, dtype=bool).copy()
        n = pattern.shape[0]
        if pattern.shape != (n, n):
            raise ValueError(f"pattern must be square, got {pattern.shape}")
        np.fill_diagonal(pattern, True)
        cols, rows = np.nonzero(pattern.T)      # column-major order
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, cols + 1, 1)
        return cls(n=n, indptr=np.cumsum(indptr),
                   rowind=rows.astype(np.int64))

    @classmethod
    def banded(cls, n: int, lower: int, upper: Optional[int] = None
               ) -> "CSCPattern":
        """Full-band pattern: column j holds rows [j-upper, j+lower] clipped.

        The filled pattern of a dense-band matrix *is* its band (no-pivot LU
        of bandwidth (p, q) fills nothing outside it), so this doubles as
        the exact symbolic prediction for ``sparse.matrices.banded_full``.
        """
        if upper is None:
            upper = lower
        js = np.arange(n, dtype=np.int64)
        lo = np.maximum(js - upper, 0)
        hi = np.minimum(js + lower, n - 1)
        counts = hi - lo + 1
        indptr = np.concatenate([[0], np.cumsum(counts)])
        rowind = np.concatenate([np.arange(a, b + 1)
                                 for a, b in zip(lo, hi)]).astype(np.int64)
        return cls(n=n, indptr=indptr, rowind=rowind)

    def with_diagonal(self) -> "CSCPattern":
        """Self if every diagonal entry is present, else a copy that adds
        the missing ones (the dense path's fill_diagonal contract)."""
        col_of = np.repeat(np.arange(self.n, dtype=np.int64),
                           np.diff(self.indptr))
        has_diag = np.zeros(self.n, dtype=bool)
        has_diag[col_of[self.rowind == col_of]] = True
        missing = np.flatnonzero(~has_diag)
        if not len(missing):
            return self
        rows = np.concatenate([self.rowind, missing])
        cols = np.concatenate([col_of, missing])
        order = np.lexsort((rows, cols))
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(indptr, cols + 1, 1)
        return CSCPattern(n=self.n, indptr=np.cumsum(indptr),
                          rowind=rows[order])

    def below_diag_counts(self) -> np.ndarray:
        """(n,) strictly-below-diagonal count per column (pack weights)."""
        col_of = np.repeat(np.arange(self.n, dtype=np.int64),
                           np.diff(self.indptr))
        return np.bincount(col_of[self.rowind > col_of],
                           minlength=self.n).astype(np.int64)

    def to_dense(self) -> np.ndarray:
        """Dense bool (n, n) — test helper only."""
        out = np.zeros((self.n, self.n), dtype=bool)
        col_of = np.repeat(np.arange(self.n), np.diff(self.indptr))
        out[self.rowind, col_of] = True
        return out


def uniform_supernodes(n: int, width: int) -> np.ndarray:
    """Contiguous fixed-width panel partition [0,w), [w,2w), ... covering n
    — for driving the packed path when no detector output is available
    (padding makes any contiguous partition valid, like T3 merges)."""
    if width <= 0:
        raise ValueError(f"panel width must be positive, got {width}")
    starts = np.arange(0, n, width, dtype=np.int64)
    ends = np.minimum(starts + width, n)
    return np.stack([starts, ends], axis=1)


@dataclasses.dataclass(frozen=True)
class CsrScatterMaps:
    """Precomputed CSR -> packed-block scatter of one (matrix, store
    structure) pair: value-independent, built once by ``PanelStore.csr_maps``
    and replayed by ``set_csr_mapped`` on every refactorization (the
    plan/factor API, DESIGN.md §10).

    ``row_idx``/``col_idx``/``pos`` are parallel and grouped by target
    panel (``panel_ptr`` bounds): CSR slot ``pos[t]`` lands at
    ``blocks[j][row_idx[t], col_idx[t]]`` for ``panel_ptr[j] <= t <
    panel_ptr[j+1]``.  ``missed`` holds CSR positions whose (row, col) slot
    the store lacks — nonzero values there escape the symbolic prediction.
    """

    nnz: int
    panel_ptr: np.ndarray  # (n_panels+1,) int64 per-panel segment bounds
    row_idx: np.ndarray    # (hits,) int64 local block row
    col_idx: np.ndarray    # (hits,) int64 local block column
    pos: np.ndarray        # (hits,) int64 CSR value position
    missed: np.ndarray     # (misses,) int64 CSR positions with no slot


class PanelStore:
    """Packed CSC-panel working storage: one (rows_J, w_J) block per panel.

    Attributes
    ----------
    supernodes : (k, 2) int64 — contiguous [start, end) column ranges.
    rows : per-panel sorted global row ids; the diagonal rows s..e-1 are
        always present, so ``rows[j][diag[j]:diag[j]+w]`` == arange(s, e).
    blocks : per-panel (len(rows[j]), w_j) float64 values (L\\U packed).
    in_pattern : per-panel bool mask of which block slots are in the
        *per-column* predicted pattern — False slots are panel padding
        (union rows / forced diagonal), kept explicitly zero.
    sup_of_col : (n,) panel id of every column (row-index map helper).
    """

    def __init__(self, pattern: CSCPattern, supernodes: np.ndarray):
        supernodes = np.asarray(supernodes, dtype=np.int64)
        self.n = pattern.n
        self.pattern = pattern
        self.supernodes = supernodes
        k = len(supernodes)
        widths = supernodes[:, 1] - supernodes[:, 0]
        self.sup_of_col = np.repeat(np.arange(k, dtype=np.int64), widths)
        self.rows: List[np.ndarray] = []
        self.blocks: List[np.ndarray] = []
        self.in_pattern: List[np.ndarray] = []
        self.diag = np.zeros(k, dtype=np.int64)
        for j, (s, e) in enumerate(supernodes):
            seg = pattern.rowind[pattern.indptr[s]:pattern.indptr[e]]
            rows = np.unique(np.concatenate([seg, np.arange(s, e)]))
            block = np.zeros((len(rows), e - s), dtype=np.float64)
            mask = np.zeros((len(rows), e - s), dtype=bool)
            for c in range(s, e):
                idx = np.searchsorted(rows, pattern.col(c))
                mask[idx, c - s] = True
            self.rows.append(rows)
            self.blocks.append(block)
            self.in_pattern.append(mask)
            self.diag[j] = np.searchsorted(rows, s)

    @classmethod
    def from_structure(cls, template: "PanelStore") -> "PanelStore":
        """A fresh store sharing ``template``'s value-independent structure
        (rows / in_pattern / diag / pattern — read-only by contract) with
        newly allocated zero blocks.  This is how ``LUPlan.factorize``
        reuses one analysis across many factorizations without rebuilding
        the per-column structure scan."""
        new = cls.__new__(cls)
        new.n = template.n
        new.pattern = template.pattern
        new.supernodes = template.supernodes
        new.sup_of_col = template.sup_of_col
        new.rows = template.rows
        new.in_pattern = template.in_pattern
        new.diag = template.diag
        new.blocks = [np.zeros_like(b) for b in template.blocks]
        return new

    # -- sizing ------------------------------------------------------------
    @property
    def n_panels(self) -> int:
        return len(self.supernodes)

    @property
    def total_entries(self) -> int:
        """Allocated float64 slots across all panel blocks (incl. padding)."""
        return int(sum(b.size for b in self.blocks))

    @property
    def nbytes(self) -> int:
        return int(sum(b.nbytes for b in self.blocks))

    @property
    def pad_entries(self) -> int:
        """Slots outside the per-column pattern (panel-union padding)."""
        return int(self.total_entries - self.pattern.nnz)

    # -- value scatter ------------------------------------------------------
    def set_dense(self, values: np.ndarray) -> float:
        """Scatter a dense (n, n) values matrix (legacy path).  Returns the
        largest |value| at a position *not* covered by the store — nonzero
        there means the input escapes the symbolic prediction."""
        values = np.asarray(values, dtype=np.float64)
        covered = np.zeros_like(values, dtype=bool)
        for j, (s, e) in enumerate(self.supernodes):
            self.blocks[j][...] = values[self.rows[j], s:e]
            covered[self.rows[j], s:e] = True
        dropped = values[~covered]
        return float(np.abs(dropped).max()) if dropped.size else 0.0

    def set_csr(self, a, values: np.ndarray) -> float:
        """Scatter CSR-aligned values (``values[p]`` pairs ``a.indices[p]``;
        sparse path — never touches (n, n)), zeroing all other slots.
        Returns the largest |value| whose (row, col) slot is absent from
        the store.  One-shot form of ``csr_maps`` + ``set_csr_mapped`` —
        a single scatter implementation, so the one-shot and plan-based
        paths cannot diverge."""
        return self.set_csr_mapped(values, self.csr_maps(a))

    def csr_maps(self, a) -> CsrScatterMaps:
        """Precompute the CSR -> block scatter (the value-independent half
        of ``set_csr``); replayed by ``set_csr_mapped`` per factorization."""
        rows_a = np.repeat(np.arange(a.n, dtype=np.int64),
                           np.diff(a.indptr))
        cols_a = a.indices.astype(np.int64)
        order = np.argsort(self.sup_of_col[cols_a], kind="stable")
        ra, ca = rows_a[order], cols_a[order]
        bounds = np.searchsorted(self.sup_of_col[ca],
                                 np.arange(self.n_panels + 1))
        row_idx, col_idx, pos, missed = [], [], [], []
        panel_ptr = np.zeros(self.n_panels + 1, dtype=np.int64)
        for j, (s, e) in enumerate(self.supernodes):
            lo, hi = bounds[j], bounds[j + 1]
            hits = 0
            if lo < hi:
                idx_c, hit = self.local_rows(j, ra[lo:hi])
                row_idx.append(idx_c[hit])
                col_idx.append(ca[lo:hi][hit] - s)
                pos.append(order[lo:hi][hit])
                missed.append(order[lo:hi][~hit])
                hits = int(hit.sum())
            panel_ptr[j + 1] = panel_ptr[j] + hits

        def cat(parts):
            return (np.concatenate(parts) if parts
                    else np.zeros(0, dtype=np.int64))

        return CsrScatterMaps(nnz=int(a.nnz), panel_ptr=panel_ptr,
                              row_idx=cat(row_idx), col_idx=cat(col_idx),
                              pos=cat(pos), missed=cat(missed))

    def set_csr_mapped(self, values: np.ndarray, maps: CsrScatterMaps, *,
                       zero: bool = True) -> float:
        """Replay a precomputed scatter (bitwise-identical to ``set_csr``),
        zeroing the blocks first so the same store buffers can be reused
        across factorizations (pass ``zero=False`` for blocks known to be
        freshly allocated — skips a redundant O(nnz) memset).  Returns the
        largest |value| with no slot."""
        values = np.asarray(values, dtype=np.float64)
        if values.shape != (maps.nnz,):
            raise ValueError(f"CSR values must be ({maps.nnz},), got "
                             f"{values.shape}")
        if zero:
            for block in self.blocks:
                block.fill(0.0)
        for j in range(self.n_panels):
            lo, hi = maps.panel_ptr[j], maps.panel_ptr[j + 1]
            if lo < hi:
                self.blocks[j][maps.row_idx[lo:hi],
                               maps.col_idx[lo:hi]] = values[maps.pos[lo:hi]]
        if maps.missed.size:
            return float(np.abs(values[maps.missed]).max())
        return 0.0

    # -- row-index-mapped gathers -------------------------------------------
    def local_rows(self, j: int, take: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """(local index, hit mask) of global rows ``take`` in panel j."""
        rows = self.rows[j]
        idx = np.searchsorted(rows, take)
        idx_c = np.minimum(idx, len(rows) - 1)
        return idx_c, rows[idx_c] == take

    def gather_rows(self, j: int, take: np.ndarray) -> np.ndarray:
        """(len(take), w_j) dense gather of panel j at global rows ``take``;
        rows absent from the panel's structure are structural zeros."""
        idx, hit = self.local_rows(j, take)
        return self.gather_rows_mapped(j, idx, hit)

    def gather_rows_mapped(self, j: int, idx: np.ndarray,
                           hit: np.ndarray) -> np.ndarray:
        """``gather_rows`` with the searchsorted row map precomputed — the
        hot path of plan-based refactorization (schedule.build_gather_maps
        caches the (idx, hit) pairs once per analysis)."""
        out = np.zeros((len(idx), self.blocks[j].shape[1]),
                       dtype=np.float64)
        out[hit] = self.blocks[j][idx[hit]]
        return out

    # -- pattern-padding bookkeeping ---------------------------------------
    def padding_max(self) -> float:
        """Largest |value| sitting on a padded (out-of-pattern) slot."""
        worst = 0.0
        for block, mask in zip(self.blocks, self.in_pattern):
            pad = block[~mask]
            if pad.size:
                worst = max(worst, float(np.abs(pad).max()))
        return worst

    def zero_padding(self) -> None:
        for block, mask in zip(self.blocks, self.in_pattern):
            block[~mask] = 0.0

    def system_view(self, blocks: List[np.ndarray]) -> "PanelStore":
        """A ``PanelStore`` sharing this store's value-independent structure
        but carrying the given ``blocks`` (typically *views* into one system
        of a ``BatchedPanelStore``, so no values are copied).  This is how
        the batched tier hands a single system to the per-system solve and
        reconstruction code paths unchanged."""
        new = PanelStore.__new__(PanelStore)
        new.n = self.n
        new.pattern = self.pattern
        new.supernodes = self.supernodes
        new.sup_of_col = self.sup_of_col
        new.rows = self.rows
        new.in_pattern = self.in_pattern
        new.diag = self.diag
        new.blocks = blocks
        return new

    # -- dense reconstruction (test/oracle helpers) -------------------------
    def to_dense(self) -> np.ndarray:
        """Dense (n, n) L\\U working matrix — test helper; the factorization
        and solve paths never call this."""
        out = np.zeros((self.n, self.n), dtype=np.float64)
        for j, (s, e) in enumerate(self.supernodes):
            out[self.rows[j], s:e] = self.blocks[j]
        return out

    def dense_lu(self) -> Tuple[np.ndarray, np.ndarray]:
        """(unit-lower L, upper U) dense factors — for the oracle-parity
        tests (`NumericResult.l` / `.u`)."""
        m = self.to_dense()
        l = np.tril(m, -1) + np.eye(self.n)
        u = np.triu(m)
        return l, u


class BatchedPanelStore:
    """Packed CSC-panel storage for B same-pattern systems at once
    (DESIGN.md §14): one (B, rows_J, w_J) float64 block per panel, sharing
    one plan's value-independent structure (rows / diag / in_pattern /
    pattern — read-only by contract) across the whole batch.

    This is the storage half of the many-matrix batched tier: circuit-style
    workloads factorize ONE sparsity pattern with thousands of value sets
    (Newton iterations, transient sweeps, Monte Carlo corners), so the
    batch axis is leading and every per-panel operation broadcasts over it.
    System ``i``'s slice of every block is bitwise-identical to what a
    standalone ``PanelStore`` holding only that system would carry —
    ``system(i)`` exposes exactly that as zero-copy views.
    """

    def __init__(self, template: PanelStore, batch: int):
        if batch <= 0:
            raise ValueError(f"batch must be positive, got {batch}")
        self.batch = batch
        self.n = template.n
        self.template = template
        self.blocks: List[np.ndarray] = [
            np.zeros((batch,) + b.shape, dtype=np.float64)
            for b in template.blocks]

    # structure accessors delegate to the shared template
    @property
    def supernodes(self) -> np.ndarray:
        return self.template.supernodes

    @property
    def rows(self) -> List[np.ndarray]:
        return self.template.rows

    @property
    def diag(self) -> np.ndarray:
        return self.template.diag

    @property
    def in_pattern(self) -> List[np.ndarray]:
        return self.template.in_pattern

    @property
    def n_panels(self) -> int:
        return self.template.n_panels

    @property
    def nbytes(self) -> int:
        return int(sum(b.nbytes for b in self.blocks))

    def system(self, i: int) -> PanelStore:
        """Zero-copy ``PanelStore`` view of system ``i`` — blocks are views
        into the batched buffers, so per-system consumers (solve, dense
        reconstruction, parity tests) run unchanged on batched factors."""
        if not 0 <= i < self.batch:
            raise IndexError(f"system {i} out of range for batch "
                             f"{self.batch}")
        return self.template.system_view([b[i] for b in self.blocks])

    def set_csr_mapped(self, values: np.ndarray, maps: CsrScatterMaps, *,
                       zero: bool = True) -> np.ndarray:
        """Replay the precomputed CSR scatter for all B systems at once
        (``values`` is (B, nnz)); per-slice bitwise-identical to
        ``PanelStore.set_csr_mapped`` on each system.  Returns the (B,)
        per-system largest |value| with no slot (the per-system
        ``validate_symbolic`` contract)."""
        values = np.asarray(values, dtype=np.float64)
        if values.shape != (self.batch, maps.nnz):
            raise ValueError(f"CSR values must be ({self.batch}, "
                             f"{maps.nnz}), got {values.shape}")
        if zero:
            for block in self.blocks:
                block.fill(0.0)
        for j in range(self.n_panels):
            lo, hi = maps.panel_ptr[j], maps.panel_ptr[j + 1]
            if lo < hi:
                self.blocks[j][:, maps.row_idx[lo:hi],
                               maps.col_idx[lo:hi]] = values[:,
                                                             maps.pos[lo:hi]]
        if maps.missed.size:
            return np.abs(values[:, maps.missed]).max(axis=1)
        return np.zeros(self.batch, dtype=np.float64)

    def gather_rows_mapped(self, j: int, idx: np.ndarray,
                           hit: np.ndarray) -> np.ndarray:
        """(B, len(idx), w_j) batched row gather — per-slice identical to
        ``PanelStore.gather_rows_mapped`` (absent rows gather as 0.0)."""
        out = np.zeros((self.batch, len(idx), self.blocks[j].shape[2]),
                       dtype=np.float64)
        out[:, hit] = self.blocks[j][:, idx[hit]]
        return out

    def padding_max(self) -> np.ndarray:
        """(B,) per-system largest |value| on a padded slot."""
        worst = np.zeros(self.batch, dtype=np.float64)
        for block, mask in zip(self.blocks, self.in_pattern):
            pad = block[:, ~mask]
            if pad.shape[1]:
                np.maximum(worst, np.abs(pad).max(axis=1), out=worst)
        return worst

    def zero_padding(self) -> None:
        for block, mask in zip(self.blocks, self.in_pattern):
            block[:, ~mask] = 0.0
