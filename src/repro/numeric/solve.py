"""End-to-end sparse solve on the packed supernodal factors (DESIGN.md §9).

``solve(a, b)`` closes the loop the symbolic phase opens: predict the fill,
factor in O(nnz(L+U)) packed panel storage (``supernodal.numeric_factorize``),
then run supernodal forward/backward triangular substitution over the packed
blocks plus iterative refinement:

* **Forward** (L y = b, unit diagonal): panels ascending — solve the packed
  diagonal block against y[s:e], then push ``y[below] -= L(below, J) @ y[s:e]``
  using the panel's below-diagonal rows.
* **Backward** (U x = y): panels descending — solve the upper-triangular
  diagonal block, then pull ``y[above] -= U(above, J) @ x[s:e]`` through the
  panel's above-diagonal (ancestor U) rows.
* **Level schedules** — substitution has its own dependency DAGs, *not* the
  factorization's: forward panel J waits on every panel whose below rows land
  in J's columns (L structure); backward is the reverse of the factorization's
  U-ancestor DAG.  ``build_solve_schedule`` levels both: a panel's diagonal
  *solve* never reads same-level data, so the solves within a level are
  independent (the batch/placement unit).  Their scatter pushes into later
  panels' rows may overlap, though — a parallel within-level implementation
  must combine them (segmented reduction / atomics); this serial sweep
  applies them in panel order.
* **Iterative refinement** — r = b - A x via the O(nnz) CSR matvec,
  re-solve on the factors, accept only improving corrections, so the
  recorded relative-residual history is non-increasing by construction.

Everything here reads the packed blocks; nothing materializes (n, n).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np
from scipy.linalg import solve_triangular

from repro.numeric.storage import BatchedPanelStore, PanelStore
from repro.numeric.supernodal import (
    BatchedNumericResult, NumericResult, numeric_factorize,
)
from repro.obs import trace as _ot
from repro.sparse.csr import CSRMatrix
from repro.sparse.numeric import csr_matvec, generic_values_csr


@dataclasses.dataclass
class SolveSchedule:
    """Dependency levels of the two substitution sweeps (panel ids per
    level, execution order: forward ascending, backward descending)."""

    fwd_levels: List[np.ndarray]
    bwd_levels: List[np.ndarray]

    @property
    def n_fwd_levels(self) -> int:
        return len(self.fwd_levels)

    @property
    def n_bwd_levels(self) -> int:
        return len(self.bwd_levels)


def build_solve_schedule(store: PanelStore) -> SolveSchedule:
    """Level both substitution DAGs from the packed row structure.

    Forward: K -> J iff panel K has below-diagonal rows inside J's column
    range (L block).  Backward: J -> K (J later) iff panel J has
    above-diagonal rows inside K's range (U block) — the reverse of the
    factorization's ancestor relation.
    """
    k = store.n_panels
    fwd = np.zeros(k, dtype=np.int64)
    bwd = np.zeros(k, dtype=np.int64)
    for j in range(k):
        s, e = store.supernodes[j]
        d = int(store.diag[j])
        w = e - s
        below = store.rows[j][d + w:]
        if len(below):
            tgt = np.unique(store.sup_of_col[below])
            fwd[tgt] = np.maximum(fwd[tgt], fwd[j] + 1)
    for j in range(k - 1, -1, -1):
        above = store.rows[j][:store.diag[j]]
        if len(above):
            tgt = np.unique(store.sup_of_col[above])
            bwd[tgt] = np.maximum(bwd[tgt], bwd[j] + 1)
    fwd_levels = [np.flatnonzero(fwd == lv)
                  for lv in range(int(fwd.max()) + 1 if k else 0)]
    bwd_levels = [np.flatnonzero(bwd == lv)
                  for lv in range(int(bwd.max()) + 1 if k else 0)]
    return SolveSchedule(fwd_levels=fwd_levels, bwd_levels=bwd_levels)


def _solve_schedule_of(store: PanelStore) -> SolveSchedule:
    sched = getattr(store, "_solve_schedule", None)
    if sched is None:
        sched = build_solve_schedule(store)
        store._solve_schedule = sched
    return sched


def _placement_of(store: PanelStore):
    return getattr(store, "_placement", None)


def _level_iter(store: PanelStore, level: np.ndarray):
    """Per-device segments of one level (the parallel dispatch unit,
    DESIGN.md §11) — a single all-panels segment without a placement.
    Diagonal solves within a level are independent and write disjoint
    ranges, so segment grouping never changes a float op."""
    placement = _placement_of(store)
    if placement is None or placement.n_devices <= 1:
        return (level,)
    return tuple(seg for seg in placement.segments(level) if len(seg))


def _batched_solve_unit_lower(mats: np.ndarray, rhs: np.ndarray) -> np.ndarray:
    """Forward substitution vmapped over stacked panels: ``mats`` (p, w, w)
    L\\U-packed unit-lower blocks against ``rhs`` (p, w, k), in place.
    One batched row-sweep per level-width group replaces p * k scalar
    triangular solves — numpy broadcasting is the vmap."""
    w = mats.shape[1]
    for i in range(1, w):
        rhs[:, i, :] -= np.einsum("pj,pjk->pk", mats[:, i, :i], rhs[:, :i, :])
    return rhs


def _batched_solve_upper(mats: np.ndarray, rhs: np.ndarray) -> np.ndarray:
    """Backward substitution vmapped over stacked panels (non-unit upper)."""
    w = mats.shape[1]
    for i in range(w - 1, -1, -1):
        if i + 1 < w:
            rhs[:, i, :] -= np.einsum("pj,pjk->pk", mats[:, i, i + 1:],
                                      rhs[:, i + 1:, :])
        rhs[:, i, :] /= mats[:, i, i][:, None]
    return rhs


def _diag_block(store: PanelStore, j: int) -> np.ndarray:
    s, e = store.supernodes[j]
    d = int(store.diag[j])
    return store.blocks[j][d:d + e - s]


def _level_diag_solves(store: PanelStore, level: np.ndarray, y: np.ndarray,
                       *, lower: bool, batched: bool) -> None:
    """Phase 1 of one substitution level: every panel's diagonal solve.

    ``batched=True`` groups the level's panels by width and runs ONE
    vmapped solve per group (multi-RHS ``y`` solves all columns in the
    same call); otherwise panels are walked per device segment with scipy
    BLAS.  Either way the solves are independent and touch disjoint
    ``y[s:e]`` ranges, so results do not depend on grouping or device
    count — only on which algorithm (batched sweep vs LAPACK trsm) ran.
    """
    widths = (store.supernodes[level, 1] - store.supernodes[level, 0])
    if batched:
        multi = y.ndim == 2
        for w in np.unique(widths):
            ids = level[widths == w]
            if not lower:          # scalar division handles w == 1 upper
                if w == 1:
                    diag = np.array([_diag_block(store, int(j))[0, 0]
                                     for j in ids])
                    starts = store.supernodes[ids, 0]
                    y[starts] = (y[starts].T / diag).T
                    continue
            if w == 1:
                continue           # unit lower: nothing to solve
            mats = np.stack([_diag_block(store, int(j)) for j in ids])
            rhs = np.stack([y[s:e] for s, e in store.supernodes[ids]])
            if not multi:
                rhs = rhs[:, :, None]
            rhs = (_batched_solve_unit_lower(mats, rhs) if lower
                   else _batched_solve_upper(mats, rhs))
            for i, (s, e) in enumerate(store.supernodes[ids]):
                y[s:e] = rhs[i] if multi else rhs[i, :, 0]
        return
    for seg in _level_iter(store, level):
        for j in seg:
            s, e = store.supernodes[j]
            w = e - s
            diag = _diag_block(store, int(j))
            if lower:
                if w > 1:
                    y[s:e] = solve_triangular(diag, y[s:e], lower=True,
                                              unit_diagonal=True,
                                              check_finite=False)
            else:
                if w == 1:
                    y[s] = y[s] / diag[0, 0]
                else:
                    y[s:e] = solve_triangular(diag, y[s:e], lower=False,
                                              check_finite=False)


def forward_substitute(store: PanelStore, b: np.ndarray, *,
                       batched: Optional[bool] = None) -> np.ndarray:
    """y with L y = b (unit-lower L in the packed blocks).

    Each level runs in two phases: the independent diagonal solves
    (grouped per device segment, or batched into one vmapped call per
    level-width group — ``batched=None`` auto-enables batching for
    multi-RHS ``b``), then the scatter pushes applied in ascending panel
    order.  Pushes from same-level panels may overlap on later rows, so
    the ascending application order is the deterministic combine that
    keeps results bitwise-identical at every device count.
    """
    y = np.asarray(b, dtype=np.float64).copy()
    if batched is None:
        batched = y.ndim == 2
    with _ot.span("solve_forward"):
        for level in _solve_schedule_of(store).fwd_levels:
            with _ot.span("fwd_level"):
                _level_diag_solves(store, level, y, lower=True,
                                   batched=batched)
                for j in level:               # ascending: fwd_levels sorted
                    s, e = store.supernodes[j]
                    d = int(store.diag[j])
                    below = store.rows[j][d + (e - s):]
                    if len(below):
                        y[below] -= store.blocks[j][d + (e - s):] @ y[s:e]
    return y


def backward_substitute(store: PanelStore, y: np.ndarray, *,
                        batched: Optional[bool] = None) -> np.ndarray:
    """x with U x = y (upper U in the packed blocks); same two-phase level
    structure as ``forward_substitute``."""
    x = np.asarray(y, dtype=np.float64).copy()
    if batched is None:
        batched = x.ndim == 2
    with _ot.span("solve_backward"):
        for level in _solve_schedule_of(store).bwd_levels:
            with _ot.span("bwd_level"):
                _level_diag_solves(store, level, x, lower=False,
                                   batched=batched)
                for j in level:
                    s, e = store.supernodes[j]
                    above = store.rows[j][:store.diag[j]]
                    if len(above):
                        x[above] -= store.blocks[j][:store.diag[j]] @ x[s:e]
    return x


def solve_factored(num: NumericResult, b: np.ndarray, *,
                   batched: Optional[bool] = None) -> np.ndarray:
    """x = U^{-1} L^{-1} b on the packed factors (no refinement)."""
    return backward_substitute(num.store,
                               forward_substitute(num.store, b,
                                                  batched=batched),
                               batched=batched)


# -- transposed substitution (robust tier, DESIGN.md §15) --------------------
#
# Hager's 1-norm condition estimator needs A^{-T} applied to a vector, which
# the packed factors give as L^{-T} U^{-T}.  The sweeps mirror the primal
# ones with reading and writing roles swapped: L^T pulls a panel's own range
# from its *below* rows (owned by later panels, so a plain descending panel
# walk is topologically correct — once a panel's diagonal solve ran, nothing
# later writes its range), U^T pulls from the *above* rows (earlier panels,
# ascending walk).  These are diagnostic paths (a handful of solves per
# quality estimate), so they stay serial and unscheduled.


def backward_substitute_t(store: PanelStore, b: np.ndarray) -> np.ndarray:
    """x with L^T x = b (unit-lower L in the packed blocks, transposed)."""
    x = np.asarray(b, dtype=np.float64).copy()
    with _ot.span("solve_backward_t"):
        for j in range(store.n_panels - 1, -1, -1):
            s, e = store.supernodes[j]
            w = e - s
            d = int(store.diag[j])
            below = store.rows[j][d + w:]
            if len(below):
                x[s:e] -= store.blocks[j][d + w:].T @ x[below]
            if w > 1:
                x[s:e] = solve_triangular(store.blocks[j][d:d + w], x[s:e],
                                          lower=True, unit_diagonal=True,
                                          trans="T", check_finite=False)
    return x


def forward_substitute_t(store: PanelStore, b: np.ndarray) -> np.ndarray:
    """w with U^T w = b (upper U in the packed blocks, transposed)."""
    y = np.asarray(b, dtype=np.float64).copy()
    with _ot.span("solve_forward_t"):
        for j in range(store.n_panels):
            s, e = store.supernodes[j]
            w = e - s
            d = int(store.diag[j])
            above = store.rows[j][:d]
            if len(above):
                y[s:e] -= store.blocks[j][:d].T @ y[above]
            diag = store.blocks[j][d:d + w]
            if w == 1:
                y[s] = y[s] / diag[0, 0]
            else:
                y[s:e] = solve_triangular(diag, y[s:e], lower=False,
                                          trans="T", check_finite=False)
    return y


def solve_factored_transposed(num: NumericResult, b: np.ndarray) -> np.ndarray:
    """z = A^{-T} b = L^{-T} U^{-T} b on the packed factors."""
    return backward_substitute_t(num.store,
                                 forward_substitute_t(num.store, b))


@dataclasses.dataclass
class SolveResult:
    """Solution + convergence history of one ``solve`` call.

    Timing is split so factorization is never conflated with substitution:
    ``factor_s`` is the numeric factorization built *by this call* (0.0 when
    a prebuilt ``num`` was reused), ``solve_s`` the substitution +
    refinement sweeps.  For multi-RHS solves ``x`` is (n, k) and each
    ``residuals`` entry is the worst (max) per-column relative residual.
    """

    x: np.ndarray
    residuals: List[float]       # relative 2-norm residuals: initial solve,
                                 # then after each *accepted* refinement
    num: NumericResult
    factor_s: float              # factorization time inside this call
    solve_s: float               # substitution + refinement time
    refine_accepted: int

    @property
    def residual(self) -> float:
        return self.residuals[-1]

    @property
    def elapsed_s(self) -> float:
        return self.factor_s + self.solve_s


def _col_residuals(matvec, x: np.ndarray, b: np.ndarray,
                   b_norms: np.ndarray) -> np.ndarray:
    """(k,) per-column relative 2-norm residuals ((1,) for vector RHS)."""
    r = b - matvec(x)
    if r.ndim == 1:
        return np.array([np.linalg.norm(r)]) / b_norms
    return np.linalg.norm(r, axis=0) / b_norms


def solve(a: CSRMatrix, b: np.ndarray, *, sym=None,
          values: Optional[np.ndarray] = None,
          pattern=None, supernodes: Optional[np.ndarray] = None,
          num: Optional[NumericResult] = None,
          refine_iters: int = 2, refine_tol: Optional[float] = None,
          n_bins: int = 8, policy: str = "lpt",
          backend: str = "numpy",
          batched: Optional[bool] = None,
          transform=None) -> SolveResult:
    """Solve A x = b through the symbolic -> packed-numeric -> substitution
    pipeline, with iterative refinement.

    ``b`` is a single right-hand side (n,) or a multi-RHS block (n, k) —
    the substitution sweeps and the refinement matvec are batched over the
    columns, so k systems cost one factorization plus k-column triangular
    solves (the circuit-simulation refactorization regime, DESIGN.md §10).
    ``batched`` picks the level-batched (vmapped) diagonal-solve path —
    ``None`` auto-enables it for multi-RHS ``b``; see
    ``forward_substitute``.

    ``a``/``sym``/``values``/``pattern``/``supernodes`` are forwarded to
    ``numeric_factorize`` (``values`` dense (n, n) or CSR-aligned (nnz,);
    defaults to ``generic_values_csr(a)``); pass ``num`` to reuse an
    existing factorization.  ``refine_iters`` bounds the refinement sweeps;
    a correction is accepted per column only if it lowers that column's
    relative residual, so the recorded (worst-column) ``residuals`` history
    is non-increasing; refinement stops early once every column is at or
    below ``refine_tol`` (default 1e-14 — a well-conditioned solve lands at
    machine precision immediately and skips the extra substitution + matvec
    sweeps; pass ``refine_tol=0.0`` to squeeze every accepted correction).

    ``transform`` (a ``repro.robust.RobustPlan``) wires the static-pivoting
    permutation/scalings around every inner factored solve (DESIGN.md §15):
    the factors are of ``A_f = Dr·P·A·Dc``, so each substitution runs on
    ``apply_rhs(rhs)`` and its result maps back through ``apply_solution``
    — while ``a``/``values``/``b`` stay the ORIGINAL system, which is what
    the refinement matvec iterates against.  ``None`` (default) leaves the
    float operations bitwise-identical to the historical path.

    Raises ``ZeroPivotError`` if the factorization hits a zero/near-zero
    pivot (propagated from ``numeric_factorize``).
    """
    t0 = time.perf_counter()
    b = np.asarray(b, dtype=np.float64)
    if (b.ndim not in (1, 2) or b.shape[0] != a.n
            or (b.ndim == 2 and b.shape[1] == 0)):
        raise ValueError(f"b must be ({a.n},) or ({a.n}, k>=1), "
                         f"got {b.shape}")
    if num is not None and values is None:
        # refinement computes residuals against `values`; silently defaulting
        # to generic values here would iterate against a different matrix
        # than the one `num` factored and corrupt the answer
        raise ValueError(
            "solve(num=...) needs the values the factorization was built "
            "from — pass the same `values` given to numeric_factorize")
    if values is None:
        values = generic_values_csr(a)
    values = np.asarray(values, dtype=np.float64)
    factor_s = 0.0
    if num is None:
        num = numeric_factorize(a, sym, values=values, pattern=pattern,
                                supernodes=supernodes, n_bins=n_bins,
                                policy=policy, backend=backend)
        factor_s = time.perf_counter() - t0

    if values.ndim == 2:
        def matvec(x):
            return values @ x
    else:
        def matvec(x):
            return csr_matvec(a, values, x)

    if refine_tol is None:
        refine_tol = 1e-14

    if transform is None:
        def fsolve(rhs):
            return solve_factored(num, rhs, batched=batched)
    else:
        def fsolve(rhs):
            return transform.apply_solution(
                solve_factored(num, transform.apply_rhs(rhs),
                               batched=batched))

    b_norms = (np.array([np.linalg.norm(b)]) if b.ndim == 1
               else np.linalg.norm(b, axis=0))
    b_norms = np.where(b_norms == 0.0, 1.0, b_norms)
    with _ot.span("solve"):
        x = fsolve(b)
        res_cols = _col_residuals(matvec, x, b, b_norms)
        residuals = [float(res_cols.max())]
        accepted = 0
        for _ in range(max(0, refine_iters)):
            if res_cols.max() <= refine_tol:
                break
            with _ot.span("refine"):
                r = b - matvec(x)
                x_try = x + fsolve(r)
                res_try = _col_residuals(matvec, x_try, b, b_norms)
                improve = res_try < res_cols
                if not improve.any():
                    break              # no column improving — keep best x
                if x.ndim == 1:
                    x = x_try
                else:                  # accept only the improving columns
                    x[:, improve] = x_try[:, improve]
                res_cols = np.where(improve, res_try, res_cols)
                residuals.append(float(res_cols.max()))
                accepted += 1
    return SolveResult(x=x, residuals=residuals, num=num, factor_s=factor_s,
                       solve_s=time.perf_counter() - t0 - factor_s,
                       refine_accepted=accepted)


# -- batched-over-systems tier (DESIGN.md §14) ------------------------------
#
# Substitution over a ``BatchedPanelStore``: every per-panel push and scatter
# carries a leading system axis (stacked ``np.matmul`` / fancy indexing —
# per-slice bitwise-identical to the 2D forms), while the per-panel diagonal
# solves follow exactly the algorithm the sequential path would pick for ONE
# system of the same RHS shape: per-system LAPACK for vector RHS
# (``batched=False``), the width-grouped einsum sweeps stacked over systems
# for multi-RHS (``batched=True``).  System i of every result is therefore
# bitwise-identical to a loop of ``forward/backward_substitute`` /
# ``solve`` over the systems.


def _level_diag_solves_batch(bstore: BatchedPanelStore, level: np.ndarray,
                             y: np.ndarray, *, lower: bool) -> None:
    """Phase 1 of one substitution level for all B systems: ``y`` is
    (B, n) (per-system LAPACK solves, the sequential vector path) or
    (B, n, k) (width-grouped einsum sweeps with the systems stacked into
    the panel axis, the sequential multi-RHS path)."""
    store = bstore.template
    bsz = bstore.batch
    if y.ndim == 3:
        widths = (store.supernodes[level, 1] - store.supernodes[level, 0])
        for w in np.unique(widths):
            ids = level[widths == w]
            if not lower:
                if w == 1:
                    diag = np.stack(
                        [bstore.blocks[int(j)][:, int(store.diag[j]), 0]
                         for j in ids], axis=1)            # (B, p)
                    starts = store.supernodes[ids, 0]
                    y[:, starts] /= diag[:, :, None]
                    continue
            if w == 1:
                continue           # unit lower: nothing to solve
            # (B, p, w, .) stacked over systems -> (B*p, w, .): the einsum
            # row sweeps contract per (panel, column) slice, so deepening
            # the panel axis with the batch cannot change a float op
            mats = np.stack(
                [bstore.blocks[int(j)][:, int(store.diag[j]):
                                       int(store.diag[j]) + w]
                 for j in ids], axis=1)
            rhs = np.stack([y[:, s:e] for s, e in store.supernodes[ids]],
                           axis=1)
            k = y.shape[2]
            mats = mats.reshape(bsz * len(ids), w, w)
            rhs = rhs.reshape(bsz * len(ids), w, k)
            rhs = (_batched_solve_unit_lower(mats, rhs) if lower
                   else _batched_solve_upper(mats, rhs))
            rhs = rhs.reshape(bsz, len(ids), w, k)
            for pi, (s, e) in enumerate(store.supernodes[ids]):
                y[:, s:e] = rhs[:, pi]
        return
    for j in level:
        s, e = store.supernodes[j]
        w = e - s
        d = int(store.diag[j])
        if lower:
            if w > 1:
                for i in range(bsz):
                    y[i, s:e] = solve_triangular(
                        bstore.blocks[j][i, d:d + w], y[i, s:e], lower=True,
                        unit_diagonal=True, check_finite=False)
        else:
            if w == 1:
                y[:, s] = y[:, s] / bstore.blocks[j][:, d, 0]
            else:
                for i in range(bsz):
                    y[i, s:e] = solve_triangular(
                        bstore.blocks[j][i, d:d + w], y[i, s:e], lower=False,
                        check_finite=False)


def forward_substitute_batch(bstore: BatchedPanelStore,
                             b: np.ndarray) -> np.ndarray:
    """y with L_i y_i = b_i for every system i; ``b`` is (B, n) or
    (B, n, k)."""
    y = np.asarray(b, dtype=np.float64).copy()
    store = bstore.template
    with _ot.span("solve_forward"):
        for level in _solve_schedule_of(store).fwd_levels:
            with _ot.span("fwd_level"):
                _level_diag_solves_batch(bstore, level, y, lower=True)
                for j in level:               # ascending: fwd_levels sorted
                    s, e = store.supernodes[j]
                    d = int(store.diag[j])
                    below = store.rows[j][d + (e - s):]
                    if len(below):
                        blk = bstore.blocks[j][:, d + (e - s):]
                        if y.ndim == 2:
                            y[:, below] -= np.matmul(
                                blk, y[:, s:e, None])[..., 0]
                        else:
                            y[:, below] -= np.matmul(blk, y[:, s:e])
    return y


def backward_substitute_batch(bstore: BatchedPanelStore,
                              y: np.ndarray) -> np.ndarray:
    """x with U_i x_i = y_i for every system i."""
    x = np.asarray(y, dtype=np.float64).copy()
    store = bstore.template
    with _ot.span("solve_backward"):
        for level in _solve_schedule_of(store).bwd_levels:
            with _ot.span("bwd_level"):
                _level_diag_solves_batch(bstore, level, x, lower=False)
                for j in level:
                    s, e = store.supernodes[j]
                    above = store.rows[j][:store.diag[j]]
                    if len(above):
                        blk = bstore.blocks[j][:, :store.diag[j]]
                        if x.ndim == 2:
                            x[:, above] -= np.matmul(
                                blk, x[:, s:e, None])[..., 0]
                        else:
                            x[:, above] -= np.matmul(blk, x[:, s:e])
    return x


def solve_factored_batch(bnum: BatchedNumericResult,
                         b: np.ndarray) -> np.ndarray:
    """x_i = U_i^{-1} L_i^{-1} b_i on the batched packed factors (no
    refinement)."""
    return backward_substitute_batch(bnum.store,
                                     forward_substitute_batch(bnum.store, b))


@dataclasses.dataclass
class BatchedSolveResult:
    """Solutions + per-system convergence histories of one ``solve_batch``.

    ``x`` is (B, n) or (B, n, k); ``residuals[i]`` is system i's accepted
    worst-column relative-residual history (same per-system lengths and
    floats a loop of sequential ``solve`` calls would record);
    ``refine_accepted`` the (B,) accepted-correction counts.
    """

    x: np.ndarray
    residuals: List[List[float]]
    num: BatchedNumericResult
    solve_s: float
    refine_accepted: np.ndarray

    @property
    def batch(self) -> int:
        return self.num.batch

    @property
    def residual(self) -> np.ndarray:
        """(B,) final per-system worst-column relative residuals."""
        return np.array([h[-1] for h in self.residuals])

    def system(self, i: int) -> SolveResult:
        """System i repackaged as a sequential ``SolveResult`` (zero-copy
        factor view; ``factor_s``/``solve_s`` are not split per system)."""
        return SolveResult(x=self.x[i], residuals=list(self.residuals[i]),
                           num=self.num.system(i), factor_s=0.0,
                           solve_s=0.0,
                           refine_accepted=int(self.refine_accepted[i]))


def solve_batch(a: CSRMatrix, b: np.ndarray, values_batch: np.ndarray,
                bnum: BatchedNumericResult, *, refine_iters: int = 2,
                refine_tol: Optional[float] = None,
                transform=None) -> BatchedSolveResult:
    """Substitution + iterative refinement across all B factored systems at
    once: ``b`` is (B, n) or (B, n, k), ``values_batch`` the (B, nnz) value
    stack ``bnum`` was factored from (each system refines against its OWN
    matrix).

    Refinement runs the level sweeps over the whole batch each iteration
    and masks per system: a system leaves the active set exactly when the
    sequential loop would break (all columns at/below ``refine_tol``, or no
    column improving), corrections are accepted per (system, column) only
    when improving, and stopped systems' solutions are never touched — so
    every system's x, residual history, and accepted count are
    bitwise-identical to a loop of ``solve(..., num=num_i)`` calls.

    ``transform`` (a ``repro.robust.RobustPlan``) applies the
    static-pivoting permutation/scalings around the batched factored
    solves, exactly as in sequential ``solve``; ``a``/``values_batch``/``b``
    stay the original systems the refinement iterates against.
    """
    t0 = time.perf_counter()
    bsz = bnum.batch
    b = np.asarray(b, dtype=np.float64)
    n = bnum.n
    if (b.ndim not in (2, 3) or b.shape[0] != bsz or b.shape[1] != n
            or (b.ndim == 3 and b.shape[2] == 0)):
        raise ValueError(f"b must be ({bsz}, {n}) or ({bsz}, {n}, k>=1), "
                         f"got {b.shape}")
    values_batch = np.asarray(values_batch, dtype=np.float64)
    if values_batch.ndim != 2 or values_batch.shape[0] != bsz:
        raise ValueError(f"values_batch must be ({bsz}, nnz), got "
                         f"{values_batch.shape}")
    if refine_tol is None:
        refine_tol = 1e-14

    if transform is None:
        def fsolve(rhs):
            return solve_factored_batch(bnum, rhs)
    else:
        def fsolve(rhs):
            return transform.apply_solution_batch(
                solve_factored_batch(bnum, transform.apply_rhs_batch(rhs)))

    def residuals_of(x):
        # per-system _col_residuals (same norm calls as sequential solve)
        return np.stack([
            _col_residuals(lambda v: csr_matvec(a, values_batch[i], v),
                           x[i], b[i], b_norms[i]) for i in range(bsz)])

    b_norms = np.stack([
        np.array([np.linalg.norm(b[i])]) if b.ndim == 2
        else np.linalg.norm(b[i], axis=0) for i in range(bsz)])
    b_norms = np.where(b_norms == 0.0, 1.0, b_norms)

    with _ot.span("solve_batch"):
        x = fsolve(b)
        res_cols = residuals_of(x)                       # (B, kk)
        histories = [[float(res_cols[i].max())] for i in range(bsz)]
        accepted = np.zeros(bsz, dtype=np.int64)
        stopped = np.zeros(bsz, dtype=bool)
        for _ in range(max(0, refine_iters)):
            at_tol = res_cols.max(axis=1) <= refine_tol
            active = ~stopped & ~at_tol
            stopped |= at_tol
            if not active.any():
                break
            with _ot.span("refine"):
                r = np.stack([b[i] - csr_matvec(a, values_batch[i], x[i])
                              for i in range(bsz)])
                x_try = x + fsolve(r)
                res_try = residuals_of(x_try)
                improve = (res_try < res_cols) & active[:, None]
                any_imp = improve.any(axis=1)
                stopped |= active & ~any_imp   # sequential's permanent break
                if b.ndim == 2:     # vector RHS: whole-x accept per system
                    x = np.where(any_imp[:, None], x_try, x)
                else:               # accept only the improving columns
                    x = np.where(improve[:, None, :], x_try, x)
                res_cols = np.where(improve, res_try, res_cols)
                accepted += any_imp
                for i in np.flatnonzero(any_imp):
                    histories[int(i)].append(float(res_cols[i].max()))
    return BatchedSolveResult(x=x, residuals=histories, num=bnum,
                              solve_s=time.perf_counter() - t0,
                              refine_accepted=accepted)
