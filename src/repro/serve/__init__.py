"""Serving front end for the plan/factor session API (DESIGN.md §14).

``SolverEngine`` keeps a fingerprint-keyed LRU cache of ``LUPlan`` analyses
and packs queued (structure, values, rhs) requests into fixed-shape batched
``factorize_batch``/``solve_batch`` dispatches — the continuous-batching
serving loop of ``launch/serve.py`` on sparse LU instead of LM decode::

    from repro.serve import SolverEngine

    eng = SolverEngine(repro.LUOptions(supernode_relax=2), batch_slots=16)
    rids = [eng.submit(a, vals, rhs) for vals, rhs in requests]
    results = eng.flush()          # one batched sweep per pattern chunk

Per-request results are bitwise-identical to the sequential
``analyze``/``factorize``/``solve`` calls.
"""
from repro.serve.cache import PatternKey, PlanCache, pattern_fingerprint
from repro.serve.engine import ServeRequest, ServeResult, SolverEngine

__all__ = [
    "PatternKey", "PlanCache", "pattern_fingerprint",
    "ServeRequest", "ServeResult", "SolverEngine",
]
