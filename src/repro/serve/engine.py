"""Long-lived solver engine: plan cache + fixed-shape batched dispatch
(DESIGN.md §14).

The production shape of a symbolic-factorization-amortizing solver is a
*service*: requests carrying (structure, values, rhs) arrive continuously,
most share one of a handful of sparsity patterns (circuit simulation:
Newton iterations / transient sweeps / Monte Carlo corners over one
netlist), and the engine's job is to (a) never re-analyze a pattern it has
seen, and (b) never pay per-request sweep overhead when requests can share
one batched sweep.

This is the continuous-batching idiom of the LM serving driver
(``launch/serve.py``) transplanted onto the ``LUPlan`` session API:

* **Plan cache** — ``pattern_fingerprint`` content-hashes each request's
  structure; hits reuse the cached ``LUPlan`` (an O(1) dict probe vs a full
  symbolic analysis), misses analyze once and insert with LRU eviction.
* **Fixed-shape slots** — requests sharing (pattern, rhs shape) are packed
  into ``batch_slots``-wide chunks; the final partial chunk is padded by
  repeating its last request, so every dispatch sees the same (B, nnz) /
  (B, n) shapes — the jit signature never changes as requests arrive or
  finish (the LM engine's resident-decode-batch policy; padded slots are
  computed and discarded).
* **Observability** — ``serve.cache.{hit,miss,evict}`` counters,
  ``serve.batch_occupancy`` (real requests / slots per dispatch), and a
  ``serve`` span around every flush, all gated on ``obs`` tracing being
  enabled; ``engine.stats`` keeps always-on Python-level totals.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.api import LUOptions, LUPlan, analyze
from repro.obs import metrics as _om
from repro.obs import trace as _ot
from repro.serve.cache import PatternKey, PlanCache, pattern_fingerprint


@dataclasses.dataclass
class ServeRequest:
    """One queued (structure, values, rhs) solve request."""

    rid: int
    key: PatternKey
    a: object                    # CSRMatrix (first-seen per pattern wins)
    values: np.ndarray           # (nnz,) CSR-aligned
    b: np.ndarray                # (n,) or (n, k)


@dataclasses.dataclass
class ServeResult:
    """Per-request outcome: the solution, its final relative residual,
    whether the plan came from cache, and which batched dispatch (and
    slot) computed it.  ``quality`` (engines built with ``quality=True``)
    carries the factorization's ``repro.robust.QualityReport`` so callers
    can gate on the verdict instead of trusting every answer."""

    rid: int
    x: np.ndarray
    residual: float
    cache_hit: bool
    batch_id: int
    slot: int
    quality: Optional[object] = None


class SolverEngine:
    """Long-lived serving front end over the plan/factor session API.

    >>> eng = SolverEngine(LUOptions(supernode_relax=2), capacity=8,
    ...                    batch_slots=16)
    >>> eng.submit(a, values, b)          # -> request id
    >>> results = eng.flush()             # batched factorize + solve
    >>> eng.solve(a, values, b)           # submit + flush one request

    Results are bitwise-identical to calling
    ``analyze(a).factorize(values).solve(b)`` per request — batching and
    slot padding change scheduling only, never a float op (the batched
    tier's conformance contract).
    """

    def __init__(self, options: Optional[LUOptions] = None, *,
                 capacity: int = 8, batch_slots: int = 16,
                 quality: bool = False):
        if batch_slots <= 0:
            raise ValueError(
                f"batch_slots must be positive, got {batch_slots}")
        self.options = options if options is not None else LUOptions()
        self.cache = PlanCache(capacity)
        self.batch_slots = batch_slots
        # quality=True attaches a per-request QualityReport (growth /
        # condition / verdict, DESIGN.md §15) to every ServeResult — a few
        # extra triangular solves per dispatched slot
        self.quality = quality
        self._queue: List[ServeRequest] = []
        self._hit_rids: set = set()
        self._next_rid = 0
        self._next_batch = 0
        self.stats: Dict[str, float] = {
            "requests": 0, "cache_hits": 0, "cache_misses": 0,
            "cache_evictions": 0, "batches": 0, "padded_slots": 0,
            "quality_rejects": 0,
            "analyze_s": 0.0, "factor_s": 0.0, "solve_s": 0.0,
        }

    # -- plan cache ---------------------------------------------------------
    def plan_for(self, a) -> LUPlan:
        """The plan for ``a``'s pattern: cache hit (O(1) content-hash
        probe) or a full ``analyze`` inserted with LRU eviction."""
        return self._plan_for(a, pattern_fingerprint(a))[0]

    def _plan_for(self, a, key: PatternKey, values=None):
        plan = self.cache.get(key)
        if plan is not None:
            self.stats["cache_hits"] += 1
            if _ot.ENABLED:
                _om.registry().count("serve.cache.hit")
            return plan, True
        self.stats["cache_misses"] += 1
        if _ot.ENABLED:
            _om.registry().count("serve.cache.miss")
        t0 = time.perf_counter()
        # under static pivoting the first-seen request's values seed the
        # transversal (first-seen per pattern wins, like the structure) —
        # later value sets replay the same plan transform
        plan = analyze(a, self.options, values=values)
        self.stats["analyze_s"] += time.perf_counter() - t0
        if self.cache.put(key, plan) is not None:
            self.stats["cache_evictions"] += 1
            if _ot.ENABLED:
                _om.registry().count("serve.cache.evict")
        return plan, False

    # -- request queue ------------------------------------------------------
    def submit(self, a, values: np.ndarray, b: np.ndarray) -> int:
        """Queue one solve of ``values`` (CSR-aligned (nnz,)) / rhs ``b``
        ((n,) or (n, k)) on ``a``'s structure; returns the request id used
        to match ``flush`` results."""
        values = np.asarray(values, dtype=np.float64)
        b = np.asarray(b, dtype=np.float64)
        if values.shape != (a.nnz,):
            raise ValueError(f"values must be CSR-aligned ({a.nnz},), got "
                             f"{values.shape}")
        if b.ndim not in (1, 2) or b.shape[0] != a.n:
            raise ValueError(f"b must be ({a.n},) or ({a.n}, k), got "
                             f"{b.shape}")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(ServeRequest(rid=rid, key=pattern_fingerprint(a),
                                        a=a, values=values, b=b))
        self.stats["requests"] += 1
        return rid

    @property
    def pending(self) -> int:
        return len(self._queue)

    def flush(self) -> List[ServeResult]:
        """Run every queued request through batched dispatches and return
        results in submission order.

        Requests are grouped by (pattern key, rhs shape); each group is cut
        into ``batch_slots``-wide chunks, the last chunk padded by
        repeating its final request (fixed-shape policy — padded slots are
        real solves whose outputs are dropped).  Each chunk is ONE
        ``factorize_batch`` + ``solve_batch`` pair.
        """
        queue, self._queue = self._queue, []
        if not queue:
            return []
        results: Dict[int, ServeResult] = {}
        groups: "Dict[tuple, List[ServeRequest]]" = {}
        for req in queue:
            groups.setdefault((req.key, req.b.shape), []).append(req)
        with _ot.span("serve"):
            for (key, _shape), reqs in groups.items():
                plan, hit = self._plan_for(reqs[0].a, key,
                                           values=reqs[0].values)
                for lo in range(0, len(reqs), self.batch_slots):
                    chunk = reqs[lo:lo + self.batch_slots]
                    self._dispatch(plan, key, chunk, hit, results)
        return [results[req.rid] for req in queue]

    def _dispatch(self, plan: LUPlan, key: PatternKey,
                  chunk: List[ServeRequest], cache_hit: bool,
                  results: Dict[int, ServeResult]) -> None:
        pad = self.batch_slots - len(chunk)
        padded = chunk + [chunk[-1]] * pad
        values = np.stack([r.values for r in padded])
        b = np.stack([r.b for r in padded])
        batch_id = self._next_batch
        self._next_batch += 1
        self.stats["batches"] += 1
        self.stats["padded_slots"] += pad
        if _ot.ENABLED:
            _om.registry().observe("serve.batch_occupancy",
                                   len(chunk) / self.batch_slots)
        t0 = time.perf_counter()
        factor = plan.factorize_batch(values)
        t1 = time.perf_counter()
        solved = factor.solve_batch(b)
        self.stats["factor_s"] += t1 - t0
        self.stats["solve_s"] += time.perf_counter() - t1
        for slot, req in enumerate(chunk):
            quality = None
            if self.quality:
                quality = factor.system(slot).quality()
                if quality.verdict == "reject":
                    self.stats["quality_rejects"] += 1
            results[req.rid] = ServeResult(
                rid=req.rid, x=np.asarray(solved.x[slot]),
                residual=float(solved.residuals[slot][-1]),
                cache_hit=cache_hit, batch_id=batch_id, slot=slot,
                quality=quality)

    # -- one-shot convenience ----------------------------------------------
    def solve(self, a, values: np.ndarray, b: np.ndarray) -> ServeResult:
        """Submit one request and flush immediately (occupancy 1/slots —
        batch real workloads via ``submit`` + ``flush``)."""
        rid = self.submit(a, values, b)
        return next(r for r in self.flush() if r.rid == rid)
