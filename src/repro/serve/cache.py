"""Fingerprint-keyed LRU plan cache (DESIGN.md §14).

A solver service amortizes symbolic analysis across every request that
shares a sparsity pattern, so the cache key must be a *content* hash of the
structure — never object identity (requests arrive as fresh ``CSRMatrix``
objects, often deserialized).  ``pattern_fingerprint`` reuses the supernode
detector's two independent 32-bit row hashes (``supernodes/fingerprint.py``:
Knuth-multiplicative ``mix1`` summed mod 2^32, murmur3-fmix32 ``mix2``
xor-folded) over the linearized (row, col) structural keys, alongside the
exact (n, nnz) — the same collision contract the detector documents:
two distinct patterns colliding is a < 2^-64-ish event.

The key is a plain frozen dataclass of Python ints, so it is stable across
pickle round-trips, processes, and sessions — a plan analyzed yesterday in
another process hits today.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.supernodes.fingerprint import mix1, mix2


@dataclasses.dataclass(frozen=True)
class PatternKey:
    """Content hash of one CSR sparsity pattern: exact (n, nnz) + two
    independent 32-bit structure hashes.  Hashable / comparable /
    picklable — the plan-cache key."""

    n: int
    nnz: int
    h1: int          # sum of mix1(row*n + col) mod 2^32
    h2: int          # xor of mix2(row*n + col)


def pattern_fingerprint(a) -> PatternKey:
    """Content-hash ``a``'s structure (values are irrelevant — one plan
    serves every value set on the pattern).

    The linear key ``row * n + col`` of every structural entry feeds both
    row-hash families; sum and xor are associative/commutative reductions,
    so the fingerprint is independent of entry order within the CSR arrays.
    """
    rows = np.repeat(np.arange(a.n, dtype=np.int64), np.diff(a.indptr))
    lin = rows * np.int64(a.n) + a.indices.astype(np.int64)
    h1 = int(np.sum(mix1(lin), dtype=np.uint64) & np.uint64(0xFFFFFFFF))
    h2 = int(np.bitwise_xor.reduce(mix2(lin))) if lin.size else 0
    return PatternKey(n=int(a.n), nnz=int(a.nnz), h1=h1, h2=h2)


class PlanCache:
    """LRU cache of ``LUPlan`` objects keyed by ``PatternKey``.

    ``get`` refreshes recency; ``put`` evicts the least-recently-used
    entry beyond ``capacity``.  Pure container — hit/miss/evict counters
    live on the ``SolverEngine`` so the cache stays trivially testable.

    Thread-safe: a serving engine naturally sees concurrent
    ``submit``/``flush`` from request threads, and the recency bookkeeping
    is a read-modify-write on the underlying ``OrderedDict`` (``get`` moves
    the key, ``put`` may pop an LRU victim) — unlocked interleavings can
    double-evict or corrupt the recency order.  Every public method holds
    one internal lock; the lock never wraps plan construction, only the
    O(1) dict transitions, so analyze-scale work stays outside it.
    """

    def __init__(self, capacity: int = 8):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self._entries: "OrderedDict[PatternKey, object]" = OrderedDict()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: PatternKey) -> bool:
        with self._lock:
            return key in self._entries

    def keys(self) -> Tuple[PatternKey, ...]:
        """Keys in eviction order (least recently used first)."""
        with self._lock:
            return tuple(self._entries.keys())

    def get(self, key: PatternKey) -> Optional[object]:
        """The cached plan for ``key`` (refreshing its recency), or None."""
        with self._lock:
            plan = self._entries.get(key)
            if plan is not None:
                self._entries.move_to_end(key)
            return plan

    def put(self, key: PatternKey, plan) -> Optional[PatternKey]:
        """Insert/refresh ``key``; returns the evicted key if the insert
        pushed an LRU entry out, else None."""
        with self._lock:
            self._entries[key] = plan
            self._entries.move_to_end(key)
            if len(self._entries) > self.capacity:
                evicted, _ = self._entries.popitem(last=False)
                return evicted
            return None
