"""Structure-aware irregular blocking: post-detection supernode merging.

T2/T3 detection only fuses columns with (near-)identical structure, so
sparse factors still emit thousands of narrow panels (bbd-20k: 9372
supernodes at n=20000) and the one-GEMM-per-panel sweep pays a dispatch
overhead per panel that dwarfs the math.  Following "A Structure-Aware
Irregular Blocking Method for Sparse LU Factorization" (PAPERS.md), this
pass greedily coalesces *adjacent* supernodes whose row structures nearly
overlap into one padded dense block whenever the roofline cost model says
the flop/byte gain (one bigger GEMM at higher arithmetic intensity, one
dispatch instead of two) beats the explicit-zero padding cost.

Correctness rides on the existing packed-panel contract: ``PanelStore``
builds each panel over the *union* of its columns' row patterns with an
``in_pattern`` mask that keeps out-of-pattern slots exactly zero
(``zero_padding`` after every panel, escape-checked against
``pattern_tol``), and ``build_schedule`` accepts any contiguous partition —
so a merged partition is numerically valid by construction, exactly like
T3 relaxed merges, just driven by a cost model instead of a subdiagonal
coupling test.  Merging changes the float-op grouping (one wide diagonal
LU / trailing GEMM instead of several), so blocked factors get
dense-oracle parity, while the default (``blocking=False``) path never
runs this code and stays bitwise-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.obs import metrics as _om
from repro.obs import trace as _ot


@dataclasses.dataclass(frozen=True)
class BlockingStats:
    """What the merge pass did, for ``plan.stats`` / bench reporting."""

    n_before: int
    n_after: int
    merges: int
    pad_entries_before: int
    pad_entries_after: int
    modeled_before_s: float
    modeled_after_s: float

    @property
    def modeled_gain_s(self) -> float:
        return self.modeled_before_s - self.modeled_after_s


def _panel_rows(pattern, s: int, e: int) -> np.ndarray:
    """Sorted union row set of columns ``[s, e)`` incl. the diagonal block
    rows — the exact set ``PanelStore`` packs for this panel."""
    seg = pattern.rowind[pattern.indptr[s]:pattern.indptr[e]]
    return np.unique(np.concatenate([seg, np.arange(s, e, dtype=seg.dtype)]))


def _panel_shape(rows: np.ndarray, s: int, e: int) -> Tuple[int, int, int]:
    """(m, k, w) of the panel over ``rows``: ``m`` rows at/below the
    diagonal, ``k`` ancestor rows above it, ``w`` columns."""
    k = int(np.searchsorted(rows, s))
    return len(rows) - k, k, e - s


def partition_stats(pattern, supernodes) -> dict:
    """Per-panel shape arrays for a contiguous partition.

    Returns ``{"m", "k", "w", "entries", "pad_entries"}`` numpy arrays (one
    element per panel) where ``entries`` is the packed block size
    ``n_rows * w`` and ``pad_entries`` the explicit zeros it carries beyond
    the column patterns.  Feeds ``RooflineCostModel.partition_time`` and the
    autotune sweep.
    """
    sup = np.asarray(supernodes)
    n_panels = len(sup)
    m = np.zeros(n_panels, dtype=np.int64)
    k = np.zeros(n_panels, dtype=np.int64)
    w = np.zeros(n_panels, dtype=np.int64)
    entries = np.zeros(n_panels, dtype=np.int64)
    pad = np.zeros(n_panels, dtype=np.int64)
    indptr = pattern.indptr
    for i, (s, e) in enumerate(sup):
        rows = _panel_rows(pattern, int(s), int(e))
        m[i], k[i], w[i] = _panel_shape(rows, int(s), int(e))
        entries[i] = len(rows) * (int(e) - int(s))
        pad[i] = entries[i] - int(indptr[int(e)] - indptr[int(s)])
    pad = np.maximum(pad, 0)
    return {"m": m, "k": k, "w": w, "entries": entries, "pad_entries": pad}


def merge_supernodes(pattern, supernodes, model, *, threshold: float = 1.0,
                     max_width: int = 256,
                     ) -> Tuple[np.ndarray, BlockingStats]:
    """Greedy left-to-right merge of adjacent supernodes under ``model``.

    Walks the detected partition keeping a current group; the next panel is
    absorbed when the merged block stays within ``max_width`` columns and
    the modeled time of the merged panel is at most ``threshold`` times the
    sum of the two separate panels (``threshold=1.0`` accepts exactly the
    merges the roofline model predicts as wins; ``>1`` trades modeled time
    for fewer panels, ``<1`` demands a strict margin).  Returns the merged
    ``(n_panels, 2)`` contiguous ranges plus a :class:`BlockingStats`.

    Cost per candidate is one sorted-union of row sets, so the whole pass is
    ``O(sum panel entries)`` — cheap enough for the autotune sweep to call
    it once per candidate partition.
    """
    sup = np.asarray(supernodes)
    if max_width < 1:
        raise ValueError(f"max_width must be >= 1, got {max_width}")
    if threshold <= 0:
        raise ValueError(f"threshold must be > 0, got {threshold}")
    with _ot.span("blocking_merge"):
        before = partition_stats(pattern, sup)
        modeled_before = model.partition_time(before["m"], before["k"],
                                              before["w"])
        merged: list[tuple[int, int]] = []
        merges = 0
        if len(sup):
            cur_s, cur_e = int(sup[0][0]), int(sup[0][1])
            cur_rows = _panel_rows(pattern, cur_s, cur_e)
            cur_t = model.panel_time(*_panel_shape(cur_rows, cur_s, cur_e))
            for s2, e2 in sup[1:]:
                s2, e2 = int(s2), int(e2)
                if (e2 - cur_s) <= max_width:
                    nxt_rows = _panel_rows(pattern, s2, e2)
                    nxt_t = model.panel_time(*_panel_shape(nxt_rows, s2, e2))
                    uni = np.union1d(cur_rows, nxt_rows)
                    uni_t = model.panel_time(*_panel_shape(uni, cur_s, e2))
                    if uni_t <= threshold * (cur_t + nxt_t):
                        cur_e, cur_rows, cur_t = e2, uni, uni_t
                        merges += 1
                        continue
                merged.append((cur_s, cur_e))
                cur_s, cur_e = s2, e2
                cur_rows = _panel_rows(pattern, cur_s, cur_e)
                cur_t = model.panel_time(*_panel_shape(cur_rows, cur_s,
                                                       cur_e))
            merged.append((cur_s, cur_e))
        out = np.asarray(merged, dtype=np.int64).reshape(-1, 2)
        after = partition_stats(pattern, out)
        modeled_after = model.partition_time(after["m"], after["k"],
                                             after["w"])
        stats = BlockingStats(
            n_before=int(len(sup)),
            n_after=int(len(out)),
            merges=merges,
            pad_entries_before=int(before["pad_entries"].sum()),
            pad_entries_after=int(after["pad_entries"].sum()),
            modeled_before_s=float(modeled_before),
            modeled_after_s=float(modeled_after),
        )
        if _ot.ENABLED:
            reg = _om.registry()
            reg.count("blocking.merges", merges)
            reg.gauge("blocking.panels_before", stats.n_before)
            reg.gauge("blocking.panels_after", stats.n_after)
            reg.gauge("blocking.pad_entries", stats.pad_entries_after)
            reg.gauge("blocking.modeled_gain_s", stats.modeled_gain_s)
    return out, stats
