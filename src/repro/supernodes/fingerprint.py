"""Streaming per-column structure fingerprints for supernode detection.

The serial post-pass (core/symbolic.detect_supernodes) compares whole columns
of the *gathered dense* filled pattern — O(n^2) memory and a serial scan.
This module replaces the gather: because row ``i`` of the filled pattern is
exactly the converged label row of source ``i``, the below-diagonal structure
of every column of L can be summarized *incrementally* as the multi-source
driver streams per-chunk converged ``maxId`` matrices (DESIGN.md §3).  Per
column ``j`` we keep three O(n) accumulators:

    counts[j] = |{ i > j : filled(i, j) }|         (below-diagonal nnz)
    hsum[j]   = sum_{i in that set} mix1(i)        (mod 2^32)
    hxor[j]   = xor_{i in that set} mix2(i)

plus ``subdiag[j] = filled(j, j-1)`` (the L(j, j-1) != 0 half of the T2
test).  All three column reductions are associative and commutative, so
chunks can arrive in any order, with any width (bubble-removal chunks are
narrower than n — they simply touch fewer columns), under any label-window
offset, and partial accumulators from disjoint source shards merge exactly
(multi-device detection composes with core/distributed.py source sharding).

Two independent 32-bit row hashes + the exact count make a fingerprint
collision (two different column structures comparing equal) a < 2^-64-ish
event per column pair; detect.py documents the probabilistic contract.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as _om
from repro.obs import trace as _ot

_GOLDEN = np.uint64(2654435761)          # Knuth multiplicative hash
_MASK32 = np.uint64(0xFFFFFFFF)


def mix1(ids: np.ndarray) -> np.ndarray:
    """Multiplicative row hash, uint32 (wrapping)."""
    x = (np.asarray(ids, dtype=np.uint64) + 1) * _GOLDEN
    return (x & _MASK32).astype(np.uint32)


def mix2(ids: np.ndarray) -> np.ndarray:
    """murmur3 fmix32 row hash — independent of mix1."""
    x = (np.asarray(ids, dtype=np.uint64) + 1) & _MASK32
    x ^= x >> 16
    x = (x * np.uint64(0x85EBCA6B)) & _MASK32
    x ^= x >> 13
    x = (x * np.uint64(0xC2B2AE35)) & _MASK32
    x ^= x >> 16
    return x.astype(np.uint32)


@dataclasses.dataclass
class ColumnFingerprints:
    """O(n) fingerprint state, filled row-chunk by row-chunk.

    ``update`` consumes a converged label matrix exactly as multisource emits
    it (possibly width-truncated, offset-encoded, and padded with repeated
    sources); rows already seen are ignored, so re-delivery (chunk padding,
    checkpoint replay) is idempotent.
    """

    n: int
    backend: str = "auto"        # "kernel" (Pallas), "ref" (jnp), "auto"

    def __post_init__(self):
        self.counts = np.zeros(self.n, dtype=np.int64)
        self.hsum = np.zeros(self.n, dtype=np.uint32)
        self.hxor = np.zeros(self.n, dtype=np.uint32)
        self.subdiag = np.zeros(self.n, dtype=bool)
        self.seen = np.zeros(self.n, dtype=bool)

    @property
    def complete(self) -> bool:
        return bool(self.seen.all())

    def update(self, labels: jax.Array, srcs: np.ndarray,
               offset: int = 0) -> int:
        """Accumulate one converged chunk; returns #new rows consumed.

        labels: (G, W) int32 ``offset + maxId`` label matrix, W <= n
                (bubble-removal chunks are narrower; a source s < W only ever
                contributes to columns j < s < W, so truncation is lossless).
        srcs:   (G,) source ids of the label rows (repeats allowed — padding).
        """
        if not _ot.ENABLED:
            return self._update(labels, srcs, offset)
        t0 = time.perf_counter()
        with _ot.span("fingerprint_update"):
            consumed = self._update(labels, srcs, offset)
        # analytic traffic of the column reduction: the (consumed, W) int32
        # label block read once + the three W-wide int32 partials written
        reg = _om.registry()
        reg.count("fingerprint.seconds", time.perf_counter() - t0)
        reg.count("fingerprint.bytes",
                  4 * consumed * labels.shape[1] + 12 * labels.shape[1])
        return consumed

    def _update(self, labels: jax.Array, srcs: np.ndarray,
                offset: int = 0) -> int:
        srcs = np.asarray(srcs, dtype=np.int64)
        w = labels.shape[1]
        # first occurrence within the batch, then drop rows seen earlier
        _, first = np.unique(srcs, return_index=True)
        keep = first[~self.seen[srcs[first]]]
        if len(keep) == 0:
            return 0
        kept_srcs = srcs[keep]
        self.seen[kept_srcs] = True

        lab = jnp.asarray(labels)[jnp.asarray(keep, dtype=jnp.int32)]
        off = jnp.int32(offset)
        # offset-free labels: maxId, or w+1 (> any real column) when the
        # label is uninitialized / stale arena garbage
        rel = jnp.where(lab <= off + jnp.int32(w), lab - off, jnp.int32(w) + 1)

        src_j = jnp.asarray(kept_srcs, dtype=jnp.int32)
        m1 = jnp.asarray(mix1(kept_srcs).view(np.int32))
        m2 = jnp.asarray(mix2(kept_srcs).view(np.int32))
        valid = jnp.ones((len(keep),), dtype=jnp.int32)

        from repro.kernels import ops as kops
        if self.backend == "ref":
            part = kops.column_fingerprints_ref(rel, src_j, m1, m2, valid)
        elif self.backend == "kernel":
            part = kops.column_fingerprints(rel, src_j, m1, m2, valid)
        else:  # auto: the Pallas kernel on real TPU, the jnp oracle elsewhere
            if jax.default_backend() == "tpu":
                part = kops.column_fingerprints(rel, src_j, m1, m2, valid)
            else:
                part = kops.column_fingerprints_ref(rel, src_j, m1, m2, valid)
        part = np.asarray(part)
        self.counts[:w] += part[0].astype(np.int64)
        self.hsum[:w] += part[1].view(np.uint32)
        self.hxor[:w] ^= part[2].view(np.uint32)

        # subdiag half of T2: filled(s, s-1) <=> maxId[s-1] < s-1
        has_prev = kept_srcs >= 1
        if np.any(has_prev):
            rows = np.flatnonzero(has_prev)
            cols = kept_srcs[rows] - 1
            vals = np.asarray(rel[jnp.asarray(rows, jnp.int32),
                                  jnp.asarray(cols, jnp.int32)])
            self.subdiag[kept_srcs[rows]] = vals < cols
        return len(keep)

    def merge(self, other: "ColumnFingerprints") -> "ColumnFingerprints":
        """Fold a disjoint shard's partial fingerprints into this one
        (multi-device detection: each shard accumulates its own sources,
        partials merge associatively at the host)."""
        assert self.n == other.n
        overlap = self.seen & other.seen
        if overlap.any():
            raise ValueError(
                f"cannot merge overlapping fingerprint shards: rows "
                f"{np.flatnonzero(overlap)[:8].tolist()}... seen on both sides")
        self.counts += other.counts
        self.hsum += other.hsum
        self.hxor ^= other.hxor
        self.subdiag |= other.subdiag
        self.seen |= other.seen
        return self


def fingerprints_from_graph(graph, *, concurrency: int = 128,
                            backend: str = "ell", bubble: bool = False,
                            use_arena: bool = True,
                            fp_backend: str = "auto") -> ColumnFingerprints:
    """Convenience: run the multi-source fixpoint purely to collect
    fingerprints (symbolic_factorize(detect_supernodes=True) gets them for
    free from the same pass)."""
    from repro.core.multisource import run_multisource

    fp = ColumnFingerprints(n=graph.n, backend=fp_backend)
    run_multisource(graph, concurrency=concurrency, backend=backend,
                    bubble=bubble, use_arena=use_arena, on_chunk=fp.update)
    return fp
