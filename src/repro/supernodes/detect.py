"""Vectorized supernode detection from column fingerprints (DESIGN.md §3).

The serial reference (core/symbolic.detect_supernodes) walks columns left to
right comparing whole dense columns — O(n^2) compares on a gathered pattern.
Here the per-boundary test is a pure vectorized predicate over the O(n)
fingerprint arrays:

**T2 (exact-match) test.**  Columns j-1, j share a supernode iff
``L(j:, j)`` and ``L(j:, j-1)`` have identical structure and L(j, j-1) != 0.
Since L(j, j) is structurally nonzero, that is equivalent to::

    subdiag[j]                                (L(j, j-1) != 0)
    counts[j]  == counts[j-1] - 1             (sets differ exactly by row j)
    hsum[j]    == hsum[j-1] - mix1(j)         (mod 2^32)
    hxor[j]    == hxor[j-1] ^ mix2(j)

The count is exact; the two independent 32-bit row-hash relations make a
false merge a hash-collision event (two distinct equal-size row sets agreeing
under both mix1-sum and mix2-xor), negligible in practice — and the serial
routine is kept as the test oracle precisely to police this contract.

**T3 (relaxed) test.**  With ``relax > 0``, boundary j may also merge when
L(j, j-1) != 0 and the below-diagonal counts of the two columns differ by at
most ``relax`` beyond the mandatory row j (``|counts[j-1] - 1 - counts[j]|
<= relax``).  This is a *count-proximity heuristic*, in the spirit of
SuperLU's structure-oblivious relaxed snodes: it is gated on the
subdiagonal coupling and count closeness only, and does NOT bound the
explicit-zero padding a numeric consumer must add (two size-matched but
disjoint column structures pass it) — fingerprints summarize columns, they
cannot measure set differences.  Consumers that need a padding guarantee
should verify candidate T3 merges against the CSR structure.  ``relax=0``
degenerates to exactly T2.

Boundary flags then become ``(n_supernodes, 2)`` [start, end) ranges — the
same contract the serial routine returns and downstream supernodal numeric
factorization consumes — with maximal merge runs split every ``max_size``
columns, matching the serial size-reset semantics.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.obs import metrics as _om
from repro.obs import trace as _ot
from repro.supernodes.fingerprint import ColumnFingerprints, mix1, mix2


def merge_flags(fp: ColumnFingerprints, *, relax: int = 0) -> np.ndarray:
    """(n,) bool; flags[j] = columns j-1 and j may share a supernode
    (flags[0] is always False: column 0 starts the first supernode)."""
    if not fp.complete:
        missing = np.flatnonzero(~fp.seen)
        raise ValueError(f"fingerprints incomplete: rows {missing[:8].tolist()}"
                         f"... of {fp.n} were never accumulated")
    n = fp.n
    flags = np.zeros(n, dtype=bool)
    if n < 2:
        return flags
    j = np.arange(1, n)
    cnt_ok = fp.counts[1:] == fp.counts[:-1] - 1
    hs_ok = (fp.hsum[:-1] - fp.hsum[1:]) == mix1(j)     # uint32 wraparound
    hx_ok = (fp.hxor[:-1] ^ fp.hxor[1:]) == mix2(j)
    t2 = fp.subdiag[1:] & cnt_ok & hs_ok & hx_ok
    if relax > 0:
        extra = np.abs(fp.counts[:-1] - 1 - fp.counts[1:])
        t2 = t2 | (fp.subdiag[1:] & (extra <= relax))
    flags[1:] = t2
    return flags


def ranges_from_flags(flags: np.ndarray, *, max_size: int = 64) -> np.ndarray:
    """Merge flags -> (n_supernodes, 2) [start, end) ranges, splitting every
    maximal merge run into ``max_size``-column pieces (vectorized; identical
    to the serial scan's size-counter reset)."""
    if max_size < 1:
        raise ValueError(f"max_size must be >= 1, got {max_size}")
    n = len(flags)
    if n == 0:
        return np.zeros((0, 2), dtype=np.int64)
    starts = np.flatnonzero(~flags)            # flags[0] is False -> starts[0]=0
    ends = np.append(starts[1:], n)
    reps = -(-(ends - starts) // max_size)     # pieces per run (ceil)
    total = int(reps.sum())
    # piece index within its run: 0,1,...,reps[r]-1 concatenated
    piece = np.arange(total) - np.repeat(np.cumsum(reps) - reps, reps)
    s = np.repeat(starts, reps) + piece * max_size
    e = np.minimum(s + max_size, np.repeat(ends, reps))
    return np.stack([s, e], axis=1)


def detect_from_fingerprints(fp: ColumnFingerprints, *, relax: int = 0,
                             max_size: int = 64) -> np.ndarray:
    """Full detection: fingerprint state -> (n_supernodes, 2) ranges."""
    with _ot.span("supernode_detect"):
        ranges = ranges_from_flags(merge_flags(fp, relax=relax),
                                   max_size=max_size)
        if _ot.ENABLED:
            reg = _om.registry()
            reg.gauge("supernodes.count", len(ranges))
            for w in (ranges[:, 1] - ranges[:, 0]).tolist():
                reg.observe("supernodes.size", w)
        return ranges


def detect_supernodes_batched(a, *, relax: int = 0, max_size: int = 64,
                              concurrency: int = 128, backend: str = "ell",
                              bubble: bool = False,
                              fp_backend: str = "auto",
                              fp: Optional[ColumnFingerprints] = None
                              ) -> np.ndarray:
    """Batched, accelerator-resident replacement for the serial post-pass:
    CSR in, supernode ranges out, never materializing the dense pattern.

    Pass ``fp`` to reuse fingerprints already accumulated by a symbolic run
    (symbolic_factorize streams them for free); otherwise one multi-source
    fixpoint pass is executed to collect them.
    """
    if fp is None:
        from repro.core.gsofa import prepare_graph
        from repro.supernodes.fingerprint import fingerprints_from_graph

        graph = a if not hasattr(a, "indptr") else prepare_graph(a)
        fp = fingerprints_from_graph(graph, concurrency=concurrency,
                                     backend=backend, bubble=bubble,
                                     fp_backend=fp_backend)
    return detect_from_fingerprints(fp, relax=relax, max_size=max_size)


def supernode_stats(ranges: np.ndarray) -> dict:
    """Summary the pipeline reports (SymbolicResult / bench_supernode)."""
    sizes = ranges[:, 1] - ranges[:, 0]
    return {
        "n_supernodes": int(len(ranges)),
        "mean_size": float(sizes.mean()) if len(sizes) else 0.0,
        "max_size": int(sizes.max()) if len(sizes) else 0,
        "multi_column_fraction": float((sizes > 1).mean()) if len(sizes) else 0.0,
    }
