"""Balanced panel packing of detected supernodes (DESIGN.md §3.4).

Downstream consumers of the supernode partition — supernodal numeric
factorization batching dense panel updates (GLU3.0-style level batching), and
multi-device pipelines assigning panels across the mesh alongside
core/distributed.py's interleaved source sharding — want *near-equal-nnz*
panels, not near-equal column counts: panel cost is dominated by the L-panel
nnz it touches, and supernode sizes after fill are heavily skewed (the dense
trailing block dwarfs early singletons).

Two packers:

* ``lpt``        — longest-processing-time greedy: sort supernodes by weight,
  assign each to the currently-lightest panel.  Classic bound: max load
  <= total/p + max single weight (tests assert it); panels are *sets* of
  supernodes, fine for independent panel updates / device assignment.
* ``contiguous`` — order-preserving prefix splitter for consumers that need
  each panel to be a contiguous column block (e.g. a blocked triangular
  solve); greedy target-crossing split, same worst-case bound.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


@dataclasses.dataclass
class PanelPartition:
    """Assignment of supernodes to near-equal-weight panels."""

    assignment: np.ndarray     # (n_supernodes,) panel id
    loads: np.ndarray          # (n_panels,) packed weight per panel
    n_panels: int

    @property
    def balance_ratio(self) -> float:
        """max / mean panel load (1.0 = perfect)."""
        if self.n_panels == 0 or len(self.loads) == 0 or self.loads.sum() == 0:
            return 1.0      # nothing packed: trivially balanced
        return float(self.loads.max()) / float(self.loads.mean())

    def panels(self) -> list:
        return [np.flatnonzero(self.assignment == p)
                for p in range(self.n_panels)]


def supernode_weights(ranges: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """(k,) estimated L-panel nnz per supernode: each column j carries its
    below-diagonal count plus the diagonal; computed from the O(n) fingerprint
    counts, no pattern needed."""
    per_col = np.concatenate([[0], np.cumsum(counts.astype(np.int64) + 1)])
    return per_col[ranges[:, 1]] - per_col[ranges[:, 0]]


def pack_panels(ranges: np.ndarray, counts: np.ndarray, n_panels: int, *,
                policy: str = "lpt") -> PanelPartition:
    """Bin-pack supernodes into ``n_panels`` near-equal-nnz panels."""
    k = len(ranges)
    if n_panels <= 0 and k > 0:
        # an assignment into an empty partition would silently point every
        # supernode at panel 0 of a zero-length loads array
        raise ValueError(
            f"pack_panels: n_panels must be positive to pack {k} supernodes, "
            f"got {n_panels}")
    weights = supernode_weights(ranges, counts)
    assignment = np.zeros(k, dtype=np.int64)
    loads = np.zeros(max(0, n_panels), dtype=np.int64)
    if k == 0:
        return PanelPartition(assignment=assignment, loads=loads,
                              n_panels=max(0, n_panels))
    if policy == "lpt":
        heap = [(0, p) for p in range(n_panels)]
        heapq.heapify(heap)
        for i in np.argsort(weights)[::-1]:
            load, p = heapq.heappop(heap)
            assignment[i] = p
            load += int(weights[i])
            loads[p] = load
            heapq.heappush(heap, (load, p))
    elif policy == "contiguous":
        target = weights.sum() / n_panels
        p, acc = 0, 0
        for i in range(k):
            # keep panels contiguous; advance when the running load crosses
            # the ideal prefix boundary (never past the last panel)
            if acc >= target * (p + 1) and p < n_panels - 1:
                p += 1
            assignment[i] = p
            acc += int(weights[i])
        for p in range(n_panels):
            loads[p] = int(weights[assignment == p].sum())
    else:
        raise ValueError(f"unknown packing policy: {policy!r}")
    return PanelPartition(assignment=assignment, loads=loads,
                          n_panels=n_panels)
