"""SIMT-friendly supernode detection (paper §"supernode detection").

Pipeline: core/multisource.py streams per-chunk converged label matrices ->
fingerprint.py folds them into O(n) per-column fingerprints (Pallas kernel
kernels/supernode_fp.py on TPU) -> detect.py runs the vectorized T2/T3
boundary tests -> balance.py packs the resulting supernodes into
near-equal-nnz panels for numeric consumers and multi-device merge.

The serial dense post-pass (core/symbolic.detect_supernodes) survives as the
test oracle; ``symbolic_factorize(..., detect_supernodes=True)`` is the
integrated entry point.
"""
from repro.supernodes.fingerprint import (
    ColumnFingerprints, fingerprints_from_graph, mix1, mix2,
)
from repro.supernodes.detect import (
    detect_from_fingerprints, detect_supernodes_batched, merge_flags,
    ranges_from_flags, supernode_stats,
)
from repro.supernodes.balance import (
    PanelPartition, pack_panels, supernode_weights,
)
from repro.supernodes.blocking import (
    BlockingStats, merge_supernodes, partition_stats,
)

__all__ = [
    "ColumnFingerprints", "fingerprints_from_graph", "mix1", "mix2",
    "detect_from_fingerprints", "detect_supernodes_batched", "merge_flags",
    "ranges_from_flags", "supernode_stats",
    "PanelPartition", "pack_panels", "supernode_weights",
    "BlockingStats", "merge_supernodes", "partition_stats",
]
