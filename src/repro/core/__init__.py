"""GSoFa core: the paper's contribution as a composable JAX module.

Public API: ``repro.core.symbolic.symbolic_factorize``.
"""
from repro.core.gsofa import (
    SymbolicGraph, prepare_graph, gsofa_batch, fill_masks, row_counts,
    dense_pattern, INF,
)

__all__ = [
    "SymbolicGraph", "prepare_graph", "gsofa_batch", "fill_masks",
    "row_counts", "dense_pattern", "INF",
]
