"""Public API: the paper's technique as a first-class framework feature.

``symbolic_factorize`` is what a solver integration (e.g. the paper's planned
SuperLU_DIST integration) calls: CSR in, L/U structure out, with the paper's
knobs (concurrency, combined traversal, interleaving, memory envelope) and
framework-grade fault tolerance (chunk checkpointing, restart, work stealing
via runtime.scheduler).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Optional

import numpy as np

from repro.core.gsofa import SymbolicGraph, prepare_graph
from repro.core.multisource import MultiSourceResult, run_multisource
from repro.core.spaceopt import aux_memory_report, auto_concurrency
from repro.obs import metrics as _om
from repro.obs import trace as _ot
from repro.sparse.csr import CSRMatrix


@dataclasses.dataclass
class SymbolicResult:
    n: int
    l_counts: np.ndarray          # per-row strictly-lower structural counts
    u_counts: np.ndarray          # per-row strictly-upper structural counts
    fill_ratio: float             # #fill-ins / nnz(A)  (Table I statistic)
    concurrency: int              # effective #C after the memory envelope
    supersteps: int
    reinits: int
    elapsed_s: float
    memory_report: dict
    # supernode partition (detect_supernodes=True; repro.supernodes pipeline)
    supernodes: Optional[np.ndarray] = None   # (n_supernodes, 2) [start, end)
    n_supernodes: int = 0
    mean_supernode_size: float = 0.0
    # sparse L+U pattern streamed from the fixpoint (collect_pattern=True) —
    # a storage.CSCPattern; the large-n path's replacement for dense_pattern
    pattern: Optional[object] = None
    # merged per-column fingerprints (detect_supernodes=True) — a
    # supernodes.ColumnFingerprints, O(n) and picklable.  Retained so
    # autotune/replan can re-detect partitions under different relax /
    # max_size knobs without re-running the fixpoint (DESIGN.md §16).
    fingerprints: Optional[object] = None

    @property
    def lu_nnz(self) -> int:
        return int(self.l_counts.sum() + self.u_counts.sum() + self.n)


class ChunkCheckpointer:
    """Fault tolerance for long symbolic runs: per-chunk durable progress.

    The source space is embarrassingly parallel, so the natural checkpoint
    unit is a completed *source range*; restart resumes whatever sources are
    not covered by any record (a node failure loses at most one in-flight
    chunk).  Coverage is tracked per source, not per chunk-grid start, so a
    restart may use a different ``concurrency`` than the recording run —
    pending work is re-chunked on the new grid.
    """

    def __init__(self, path: str, n: int):
        self.path = path
        self.n = n
        self.records: list[dict] = []
        self.covered = np.zeros(n, dtype=bool)
        self.done: dict[int, dict] = {}    # start -> latest rec (introspection)
        if os.path.exists(path):
            with open(path) as f:
                for line in f:
                    rec = json.loads(line)
                    if rec["n"] == n:
                        self._remember(rec)

    def _remember(self, rec: dict) -> None:
        self.records.append(rec)
        self.covered[np.asarray(rec["srcs"], dtype=np.int64)] = True
        self.done[rec["start"]] = rec

    def pending_sources(self) -> np.ndarray:
        """Sources not covered by any record, ready to be re-chunked on
        whatever concurrency grid the restarting run uses."""
        return np.flatnonzero(~self.covered).astype(np.int64)

    def record(self, start: int, srcs: np.ndarray, l_cnt: np.ndarray,
               u_cnt: np.ndarray) -> None:
        rec = {"n": self.n, "start": int(start), "srcs": srcs.tolist(),
               "l": l_cnt.tolist(), "u": u_cnt.tolist()}
        with open(self.path, "a") as f:
            f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._remember(rec)

    def restore_into(self, l_counts: np.ndarray, u_counts: np.ndarray) -> int:
        for rec in self.records:
            srcs = np.asarray(rec["srcs"], dtype=np.int64)
            l_counts[srcs] = np.asarray(rec["l"], dtype=np.int64)
            u_counts[srcs] = np.asarray(rec["u"], dtype=np.int64)
        return int(self.covered.sum())


def detect_supernodes(pattern: np.ndarray, *, max_size: int = 64) -> np.ndarray:
    """Supernode partition of the filled pattern (paper §V: supported even
    under interleaved source assignment, since it is a post-pass over the
    gathered structure).

    Columns j-1, j share a supernode iff L(j:, j) and L(j:, j-1) have the
    same nonzero structure and L(j, j-1) != 0 (the SuperLU T2 test).
    Returns an (n_supernodes, 2) array of [start, end) column ranges —
    consumed by supernodal numeric factorization to batch dense updates.

    This is the dense *test oracle* for the streamed fingerprint detector
    (repro.supernodes); it is vectorized — one shifted-column structure
    comparison instead of a per-column ``np.array_equal`` loop — but stays
    bitwise-identical to the serial scan (tests hold it to that contract).
    """
    pattern = np.asarray(pattern, dtype=bool)
    n = pattern.shape[0]
    if n == 0:
        return np.zeros((0, 2), dtype=np.int64)
    # mergeable[j] (j >= 1): L(j:, j) == L(j:, j-1) structurally and
    # L(j, j-1) != 0.  The suffix comparison vectorizes as "the last row
    # where adjacent columns disagree sits strictly above row j".
    diff = pattern[:, 1:] != pattern[:, :-1]            # (n, n-1)
    rows = np.arange(n, dtype=np.int64)
    last_mismatch = np.where(diff, rows[:, None], -1).max(axis=0)   # (n-1,)
    flags = np.zeros(n, dtype=bool)
    flags[1:] = pattern[rows[1:], rows[1:] - 1] & (last_mismatch < rows[1:])
    # maximal merge runs, split every max_size columns — identical to the
    # serial scan's size-counter reset
    starts = np.flatnonzero(~flags)
    ends = np.append(starts[1:], n)
    reps = -(-(ends - starts) // max_size)
    piece = np.arange(int(reps.sum())) - np.repeat(np.cumsum(reps) - reps, reps)
    s = np.repeat(starts, reps) + piece * max_size
    e = np.minimum(s + max_size, np.repeat(ends, reps))
    return np.stack([s, e], axis=1)


class PatternCollector:
    """Streams the filled L+U structure out of the fixpoint as sparse rows.

    ``update`` consumes the (G, n) bool fill mask of each converged chunk
    exactly as ``run_multisource(on_mask=...)`` emits it (padded duplicate
    sources allowed; re-delivery is idempotent) and immediately reduces each
    row to its column-index list, so peak host memory is O(nnz(L+U)) + one
    chunk mask — never a dense (n, n) pattern.  ``to_csc`` transposes the
    row lists into the ``storage.CSCPattern`` the packed numeric path
    consumes; this is the large-n replacement for ``core.gsofa
    .dense_pattern`` (ROADMAP follow-up: CSC extraction straight from the
    fixpoint).
    """

    def __init__(self, n: int):
        self.n = n
        self.row_cols: list = [None] * n
        self.seen = np.zeros(n, dtype=bool)

    @property
    def complete(self) -> bool:
        return bool(self.seen.all())

    def update(self, mask, srcs: np.ndarray) -> int:
        """Accumulate one chunk's fill mask; returns #new rows consumed."""
        if not _ot.ENABLED:
            return self._update(mask, srcs)
        with _ot.span("pattern_collect"):
            return self._update(mask, srcs)

    def _update(self, mask, srcs: np.ndarray) -> int:
        srcs = np.asarray(srcs, dtype=np.int64)
        _, first = np.unique(srcs, return_index=True)
        keep = first[~self.seen[srcs[first]]]
        if len(keep) == 0:
            return 0
        mask = np.asarray(mask, dtype=bool)
        for i in keep:
            src = int(srcs[i])
            row = np.flatnonzero(mask[i]).astype(np.int64)
            d = np.searchsorted(row, src)
            if d >= len(row) or row[d] != src:      # diagonal always present
                row = np.insert(row, d, src)
            self.row_cols[src] = row
            self.seen[src] = True
        return len(keep)

    def to_csc(self):
        """CSR row lists -> ``storage.CSCPattern`` (sorted rows per column)."""
        from repro.numeric.storage import CSCPattern

        if not self.complete:
            missing = np.flatnonzero(~self.seen)
            raise ValueError(f"pattern incomplete: rows {missing[:8].tolist()}"
                             f"... of {self.n} were never collected")
        counts = np.array([len(r) for r in self.row_cols], dtype=np.int64)
        rows = np.repeat(np.arange(self.n, dtype=np.int64), counts)
        cols = (np.concatenate(self.row_cols) if self.n
                else np.zeros(0, dtype=np.int64))
        order = np.lexsort((rows, cols))
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(indptr, cols + 1, 1)
        return CSCPattern(n=self.n, indptr=np.cumsum(indptr),
                          rowind=rows[order])


def _symbolic_factorize_distributed(a: CSRMatrix, graph: SymbolicGraph,
                                    mesh, *, concurrency: int, backend: str,
                                    budget_bytes: Optional[int],
                                    detect_supernodes: bool,
                                    supernode_relax: int,
                                    supernode_max_size: int,
                                    collect_pattern: bool,
                                    t0: float,
                                    on_progress=None) -> SymbolicResult:
    """Mesh-sharded symbolic pass (DESIGN.md §11): the multi-source fixpoint
    runs inside ``core.distributed``'s shard_map chunk step; per-shard
    supernode fingerprints accumulate from the streamed label matrices and
    merge through ``runtime.collectives.merge_fingerprint_shards``; the
    sparse CSC pattern streams through the same ``PatternCollector`` hook
    as the single-device path.  Per-source fixpoints are unique and
    chunking-independent, so every output (counts, supernodes, pattern) is
    bitwise-identical to the single-device result at any device count —
    the `tests/test_distributed_plan.py` conformance contract.
    """
    from repro.core.distributed import distributed_multisource
    from repro.core.spaceopt import aux_memory_report
    from repro.runtime.collectives import merge_fingerprint_shards

    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[ax] for ax in axes]))

    fp_shards = None
    on_shard_chunk = None
    if detect_supernodes:
        from repro.supernodes import ColumnFingerprints

        fp_shards = [ColumnFingerprints(n=a.n) for _ in range(n_shards)]

        def on_shard_chunk(d, labels, srcs):
            fp_shards[d].update(labels, srcs)

    collector = PatternCollector(n=a.n) if collect_pattern else None
    on_shard_mask = None
    if collector is not None:
        def on_shard_mask(d, mask, srcs):
            collector.update(mask, srcs)

    eff_c = auto_concurrency(graph, budget_bytes, concurrency, backend)
    with _ot.span("fixpoint"):
        ms = distributed_multisource(
            graph, mesh, concurrency=eff_c, backend=backend,
            on_shard_chunk=on_shard_chunk, on_shard_mask=on_shard_mask,
            on_progress=on_progress)

    sn_ranges = None
    sn_count = 0
    sn_mean = 0.0
    fp = None
    if fp_shards is not None:
        from repro.supernodes import detect_from_fingerprints, supernode_stats

        with _ot.span("fingerprint_merge"):
            if len(axes) == 1:
                # device-side merge: one ring collective per accumulator
                fp = merge_fingerprint_shards(mesh, axes[0], fp_shards)
            else:
                # multi-axis production meshes fold on the host (same result:
                # the merge is associative/commutative either way)
                fp = fp_shards[0]
                for shard in fp_shards[1:]:
                    fp.merge(shard)
        sn_ranges = detect_from_fingerprints(
            fp, relax=supernode_relax, max_size=supernode_max_size)
        stats = supernode_stats(sn_ranges)
        sn_count = stats["n_supernodes"]
        sn_mean = stats["mean_size"]

    row_ids = np.repeat(np.arange(a.n, dtype=np.int64), np.diff(a.indptr))
    nnz_offdiag = int(a.nnz) - int(np.count_nonzero(a.indices == row_ids))
    fills = int(ms.l_counts.sum() + ms.u_counts.sum()) - nnz_offdiag
    res = SymbolicResult(
        n=a.n, l_counts=ms.l_counts, u_counts=ms.u_counts,
        fill_ratio=fills / max(1, a.nnz),
        concurrency=ms.concurrency, supersteps=ms.supersteps,
        reinits=ms.reinits, elapsed_s=time.perf_counter() - t0,
        memory_report=aux_memory_report(graph, ms.concurrency, backend),
        supernodes=sn_ranges, n_supernodes=sn_count,
        mean_supernode_size=sn_mean,
        pattern=collector.to_csc() if collector is not None else None,
        fingerprints=fp,
    )
    res.dist = getattr(ms, "dist", None)       # type: ignore[attr-defined]
    _record_fill_metrics(res, a)
    return res


def _record_fill_metrics(res: SymbolicResult, a: CSRMatrix) -> None:
    """Device-count-invariant fill gauges (obs registry, DESIGN.md §12)."""
    if not _ot.ENABLED:
        return
    reg = _om.registry()
    reg.gauge("fill.lu_nnz", res.lu_nnz)
    reg.gauge("fill.input_nnz", int(a.nnz))


def symbolic_factorize(a: CSRMatrix, *, concurrency: int = 128,
                       backend: str = "ell", combined: bool = True,
                       bubble: bool = False, use_arena: bool = True,
                       budget_bytes: Optional[int] = None,
                       checkpoint_path: Optional[str] = None,
                       graph: Optional[SymbolicGraph] = None,
                       detect_supernodes: bool = False,
                       supernode_relax: int = 0,
                       supernode_max_size: int = 64,
                       collect_pattern: bool = False,
                       mesh=None, runtime: str = "static",
                       on_progress=None) -> SymbolicResult:
    """Compute the L/U nonzero structure of ``a``.

    With ``detect_supernodes=True`` the supernode partition rides along for
    free: per-chunk converged label matrices are folded into O(n) column
    fingerprints as they stream out of the fixpoint (repro.supernodes,
    DESIGN.md §3) — no dense pattern is ever gathered — and the result gains
    ``supernodes`` / ``n_supernodes`` / ``mean_supernode_size``.
    ``supernode_relax`` is the T3 merge tolerance (0 = exact T2);
    ``supernode_max_size`` caps panel width like the serial post-pass.

    With ``collect_pattern=True`` the sparse L+U structure streams out of
    the same fixpoint chunks (``PatternCollector``): the result gains
    ``pattern``, a ``storage.CSCPattern`` in O(nnz(L+U)) host memory —
    what ``repro.analyze`` feeds the packed numeric path at any n, with no
    dense (n, n) gather anywhere (DESIGN.md §10).

    With ``mesh`` (a ``jax.sharding.Mesh``; build one with
    ``launch.mesh.make_flat_mesh``) the fixpoint shards its sources over
    the mesh devices inside shard_map (DESIGN.md §11): fingerprints
    accumulate per shard and merge through device collectives, the
    pattern streams exactly as on one device, and every output is
    bitwise-identical to the mesh-less path.  The distributed path always
    runs combined chunks; ``bubble`` and ``checkpoint_path`` are
    single-device refinements and raise here, while ``use_arena`` is
    simply ignored (no label-arena windows inside shard_map).

    ``runtime="dynamic"`` routes the fixpoint through the work-stealing
    ``runtime.scheduler.DynamicScheduler`` instead of the static chunk
    loop (DESIGN.md §13): every visible device pulls chunks from a shared
    queue, stragglers are speculatively re-issued, and devices may
    join/leave mid-run — while the converged label matrices and fill
    masks stream into the *same* fingerprint/pattern collectors, so every
    output stays bitwise-identical to the static drivers.
    ``checkpoint_path`` composes with it (the scheduler skips covered
    chunks on restart); ``mesh`` and ``bubble`` do not (the scheduler
    *is* the distribution — one host driving the device pool).
    """
    t0 = time.perf_counter()
    if runtime not in ("static", "dynamic"):
        raise ValueError(f"unknown runtime {runtime!r}; pick from "
                         f"('static', 'dynamic')")
    if graph is None:
        dense_block = 128 if backend in ("dense", "kernel") else None
        graph = prepare_graph(a, dense_block=dense_block)
    if mesh is not None:
        if runtime == "dynamic":
            raise ValueError(
                "runtime='dynamic' is the host-driven scheduler over the "
                "visible devices and cannot be combined with a shard_map "
                "mesh — drop one of the two")
        if checkpoint_path is not None:
            raise ValueError(
                "checkpoint_path is a single-device refinement; the "
                "distributed path re-runs lost shards instead (drop the "
                "mesh or the checkpoint)")
        if bubble:
            raise ValueError("bubble removal is not supported on the "
                             "distributed path (chunks are full-width)")
        return _symbolic_factorize_distributed(
            a, graph, mesh, concurrency=concurrency, backend=backend,
            budget_bytes=budget_bytes,
            detect_supernodes=detect_supernodes,
            supernode_relax=supernode_relax,
            supernode_max_size=supernode_max_size,
            collect_pattern=collect_pattern, t0=t0,
            on_progress=on_progress)
    eff_c = auto_concurrency(graph, budget_bytes, concurrency, backend)

    fp = None
    on_chunk = None
    if detect_supernodes:
        from repro.supernodes import ColumnFingerprints

        fp = ColumnFingerprints(n=a.n)
        on_chunk = fp.update
    collector = PatternCollector(n=a.n) if collect_pattern else None
    on_mask = collector.update if collector is not None else None

    ckpt = ChunkCheckpointer(checkpoint_path, a.n) if checkpoint_path else None
    runtime_stats = None
    if runtime == "dynamic":
        if bubble:
            raise ValueError("bubble removal is not supported on the "
                             "dynamic runtime (chunks are full-width)")
        from repro.runtime.scheduler import DynamicScheduler

        sched = DynamicScheduler(graph, concurrency=eff_c, backend=backend,
                                 checkpointer=ckpt, on_chunk=on_chunk,
                                 on_mask=on_mask)
        with _ot.span("fixpoint"):
            out = sched.run()
        ms = MultiSourceResult(
            l_counts=out["l_counts"], u_counts=out["u_counts"],
            edge_checks=out["edge_checks"],
            conv_iters=np.zeros(a.n, np.int64),
            supersteps=out["supersteps"], n_chunks=out["completed"],
            concurrency=eff_c, reinits=out["completed"],
            windows=out["completed"])
        runtime_stats = {
            "n_devices": len(sched.devices),
            "chunks": out["chunks"], "completed": out["completed"],
            "steals": out["steals"], "reissues": out["reissues"],
            "retired": out["retired"],
        }
    elif ckpt is not None and ckpt.covered.any():
        # restart path: only run the uncovered sources, re-chunked on THIS
        # run's grid (the recording run may have used a different concurrency)
        l_counts = np.zeros(a.n, dtype=np.int64)
        u_counts = np.zeros(a.n, dtype=np.int64)
        ckpt.restore_into(l_counts, u_counts)
        pending = ckpt.pending_sources()
        supersteps = reinits = n_chunks = 0
        with _ot.span("fixpoint"):
            for start in range(0, len(pending), eff_c):
                srcs = pending[start:start + eff_c].astype(np.int32)
                res = run_multisource(graph, concurrency=eff_c,
                                      backend=backend, combined=combined,
                                      bubble=bubble, use_arena=use_arena,
                                      sources=srcs, on_chunk=on_chunk,
                                      on_mask=on_mask)
                l_counts[srcs] = res.l_counts[srcs]
                u_counts[srcs] = res.u_counts[srcs]
                supersteps += res.supersteps
                reinits += res.reinits
                n_chunks += 1
                ckpt.record(int(srcs[0]), srcs, res.l_counts[srcs],
                            res.u_counts[srcs])
        ms = MultiSourceResult(
            l_counts=l_counts, u_counts=u_counts,
            edge_checks=np.zeros(a.n, np.int64), conv_iters=np.zeros(a.n, np.int64),
            supersteps=supersteps, n_chunks=n_chunks, concurrency=eff_c,
            reinits=reinits, windows=0)
    else:
        with _ot.span("fixpoint"):
            ms = run_multisource(graph, concurrency=eff_c, backend=backend,
                                 combined=combined, bubble=bubble,
                                 use_arena=use_arena,
                                 budget_bytes=budget_bytes,
                                 on_chunk=on_chunk, on_mask=on_mask,
                                 on_progress=on_progress)
        if ckpt is not None:
            for start in range(0, a.n, eff_c):
                srcs = np.arange(start, min(start + eff_c, a.n), dtype=np.int64)
                ckpt.record(start, srcs, ms.l_counts[srcs], ms.u_counts[srcs])

    # checkpoint restart restored some chunks' counts without their label
    # matrices; re-run those sources once for whichever collectors miss them
    # (update() is idempotent, so one shared re-run feeds both)
    missing = np.zeros(a.n, dtype=bool)
    if fp is not None and not fp.complete:
        missing |= ~fp.seen
    if collector is not None and not collector.complete:
        missing |= ~collector.seen
    if missing.any():
        run_multisource(graph, concurrency=eff_c, backend=backend,
                        combined=combined, bubble=bubble,
                        use_arena=use_arena,
                        sources=np.flatnonzero(missing).astype(np.int32),
                        on_chunk=on_chunk, on_mask=on_mask)

    sn_ranges = None
    sn_count = 0
    sn_mean = 0.0
    if fp is not None:
        from repro.supernodes import detect_from_fingerprints, supernode_stats

        sn_ranges = detect_from_fingerprints(
            fp, relax=supernode_relax, max_size=supernode_max_size)
        stats = supernode_stats(sn_ranges)
        sn_count = stats["n_supernodes"]
        sn_mean = stats["mean_size"]

    row_ids = np.repeat(np.arange(a.n, dtype=np.int64), np.diff(a.indptr))
    nnz_offdiag = int(a.nnz) - int(np.count_nonzero(a.indices == row_ids))
    lu_offdiag = int(ms.l_counts.sum() + ms.u_counts.sum())
    fills = lu_offdiag - nnz_offdiag
    out = SymbolicResult(
        n=a.n, l_counts=ms.l_counts, u_counts=ms.u_counts,
        fill_ratio=fills / max(1, a.nnz),
        concurrency=ms.concurrency, supersteps=ms.supersteps, reinits=ms.reinits,
        elapsed_s=time.perf_counter() - t0,
        memory_report=aux_memory_report(graph, ms.concurrency, backend),
        supernodes=sn_ranges, n_supernodes=sn_count,
        mean_supernode_size=sn_mean,
        pattern=collector.to_csc() if collector is not None else None,
        fingerprints=fp,
    )
    if runtime_stats is not None:
        out.runtime = runtime_stats            # type: ignore[attr-defined]
    _record_fill_metrics(out, a)
    return out
