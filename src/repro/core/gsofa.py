"""GSoFa: fine-grained parallel symbolic factorization as a batched JAX fixpoint.

The paper's algorithm (Fig 4b) relaxes fill2's serial threshold order: all
frontiers expand in parallel, guarded by the monotone label

    maxId[v] = min over discovered paths src -> v of (max intermediate vertex id)

updated with atomicMin and re-visitation until convergence.  On TPU there are
no queues/atomics, so we adapt (DESIGN.md §2): one *superstep* relaxes every
vertex synchronously (Jacobi); the atomicMin race becomes a min-reduction; the
paper's re-visitation is the fixpoint iteration itself.  The label lattice and
the fixpoint are identical, so the converged structure matches fill2 exactly
(tests prove it).

Key algebraic facts used:

* direct edges carry label -1 (no intermediates), so the converged filled
  structure of row ``src`` is simply ``{v != src : maxId[v] < v}`` — original
  entries and fill-ins need no separate bookkeeping (the paper's fill[] array
  folds away; it only de-duplicated queue insertions, which dense masks make
  free).
* only vertices ``u < src`` may expand (paper lines 6/15), which makes every
  discovered path's intermediates < src; hence for v > src the Theorem-1 test
  collapses to reachability, and for v < src it is ``maxId[v] < v``.
* the paper's "line 9.5" optimization — never lower the label of a detected
  fill — is the clamp ``prop(u) = max(u, maxId[u])``: once ``maxId[u] < u``,
  further lowering cannot change what u propagates.  The Jacobi step applies
  the clamp inherently, so the optimization is structural here.

Three relaxation backends share this module's driver:
  * ``ell``    — padded-ELL gather (irregular-friendly, default on CPU),
  * ``dense``  — dense-tile min-max semiring product (jnp oracle of the kernel),
  * ``kernel`` — the Pallas TPU kernel (kernels/gsofa_relax.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.csr import CSRMatrix, csr_to_ell, dense_block_adjacency, transpose_csr

# label "uninitialized / unreachable / masked"
INF = jnp.int32(jnp.iinfo(jnp.int32).max)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SymbolicGraph:
    """Device-ready graph data for the fixpoint."""

    n: int
    in_ell: jax.Array      # (V, K_in) int32 in-neighbors, padded with V
    out_ell: jax.Array     # (V, K_out) int32 out-neighbors, padded with V
    out_deg: jax.Array     # (V,) int32 true out-degrees (edge-check metric)
    adj_dense: Optional[jax.Array] = None  # (Vp, Vp) uint8, u->v rows, for dense/kernel

    def tree_flatten(self):
        return (self.in_ell, self.out_ell, self.out_deg, self.adj_dense), self.n

    @classmethod
    def tree_unflatten(cls, n, children):
        in_ell, out_ell, out_deg, adj_dense = children
        return cls(n=n, in_ell=in_ell, out_ell=out_ell, out_deg=out_deg,
                   adj_dense=adj_dense)


def prepare_graph(a: CSRMatrix, *, dense_block: Optional[int] = None) -> SymbolicGraph:
    at = transpose_csr(a)
    in_ell, _ = csr_to_ell(at, pad_value=a.n, drop_diagonal=True)
    out_ell, _ = csr_to_ell(a, pad_value=a.n, drop_diagonal=True)
    deg = np.array([int(np.sum(a.row(i) != i)) for i in range(a.n)], dtype=np.int32)
    adj = None
    if dense_block is not None:
        adj = jnp.asarray(dense_block_adjacency(a, dense_block))
    return SymbolicGraph(
        n=a.n,
        in_ell=jnp.asarray(in_ell),
        out_ell=jnp.asarray(out_ell),
        out_deg=jnp.asarray(deg),
        adj_dense=adj,
    )


# ---------------------------------------------------------------------------
# label initialization & relaxation supersteps
# ---------------------------------------------------------------------------

def init_labels(graph: SymbolicGraph, srcs: jax.Array, *,
                offset: jax.Array | int = 0,
                stale_buf: Optional[jax.Array] = None,
                nbrs: Optional[jax.Array] = None) -> jax.Array:
    """(S, V) labels encoded as ``offset + maxId``: out-neighbors of each source
    get ``offset - 1`` (direct edge, no intermediates); everything else is left
    "uninitialized" — either explicit INF, or, when ``stale_buf`` is given, the
    stale contents of an earlier label window (spaceopt.LabelArena), which by
    construction are > offset + n and therefore read as uninitialized."""
    v = graph.n
    offset = jnp.asarray(offset, jnp.int32)
    if nbrs is None:
        nbrs = graph.out_ell[srcs]                      # (S, K_out), pad >= V

    def one(nb, row):
        lab = jnp.concatenate([row, jnp.full((1,), INF, jnp.int32)])
        lab = lab.at[jnp.minimum(nb, jnp.int32(v))].set(offset - 1)
        return lab[:v]

    if stale_buf is None:
        stale_buf = jnp.full((srcs.shape[0], v), INF, dtype=jnp.int32)
    return jax.vmap(one)(nbrs, stale_buf)


def compute_prop(labels: jax.Array, srcs: jax.Array, n: int,
                 offset: jax.Array | int = 0) -> jax.Array:
    """Clamped propagation values, (S, V), in the offset encoding:
    ``max(offset + u, labels[u])`` for expandable u (u < src, label valid in the
    current window), else INF.  Clamping stale/uninitialized labels to INF stops
    values from dead windows from propagating (they stay put as inert storage)."""
    offset = jnp.asarray(offset, jnp.int32)
    u_ids = jnp.arange(n, dtype=jnp.int32)
    valid = labels <= offset + jnp.int32(n)
    prop = jnp.maximum(offset + u_ids[None, :], labels)
    ok = valid & (u_ids[None, :] < srcs[:, None])
    return jnp.where(ok, prop, INF)


def relax_ell(prop: jax.Array, graph: SymbolicGraph) -> jax.Array:
    """Candidate labels via ELL gather: cand[s, v] = min_{u in in-nbr(v)} prop[s, u]."""
    prop_pad = jnp.concatenate(
        [prop, jnp.full((prop.shape[0], 1), INF, dtype=jnp.int32)], axis=1)
    # (S, V, K_in); pad idx V -> INF
    gathered = jnp.take(prop_pad, graph.in_ell, axis=1)
    return jnp.min(gathered, axis=2)


def relax_dense(prop: jax.Array, graph: SymbolicGraph) -> jax.Array:
    """Candidates as a (min, max)-semiring product against the dense adjacency.

    Pure-jnp oracle of the Pallas kernel: cand[s, v] = min_u (adj[u, v] ?
    prop[s, u] : INF).  ``prop`` already encodes the u < src mask and the
    max(u, label) clamp, so the kernel is a pure masked-min contraction.
    """
    vp = graph.adj_dense.shape[0]
    n = graph.n
    if vp > n:
        prop = jnp.pad(prop, ((0, 0), (0, vp - n)), constant_values=INF)
    masked = jnp.where(graph.adj_dense[None, :, :] != 0, prop[:, :, None], INF)
    return jnp.min(masked, axis=1)[:, :n]


def relax_kernel(prop: jax.Array, graph: SymbolicGraph) -> jax.Array:
    """Candidates via the Pallas TPU kernel (interpret-mode on CPU)."""
    from repro.kernels import ops as kops

    vp = graph.adj_dense.shape[0]
    n = graph.n
    if vp > n:
        prop = jnp.pad(prop, ((0, 0), (0, vp - n)), constant_values=INF)
    return kops.minmax_relax(prop, graph.adj_dense)[:, :n]


_BACKENDS = {"ell": relax_ell, "dense": relax_dense, "kernel": relax_kernel}


# ---------------------------------------------------------------------------
# fixpoint driver
# ---------------------------------------------------------------------------

class FixpointResult(NamedTuple):
    labels: jax.Array       # (S, V) converged maxId
    iters: jax.Array        # () total supersteps for the batch
    conv_iter: jax.Array    # (S,) last superstep at which each source was active
    edge_checks: jax.Array  # (S,) paper's workload counter (frontier out-degrees)


def fixpoint_impl(graph: SymbolicGraph, srcs: jax.Array, labels0: jax.Array,
                  offset: jax.Array, backend: str, max_iters: int) -> FixpointResult:
    """Un-jitted fixpoint body — callable from inside shard_map/jit contexts."""
    relax = _BACKENDS[backend]
    n = graph.n

    def cond(state):
        _, prev_prop, any_frontier, it, _, _ = state
        return jnp.logical_and(any_frontier, it < max_iters)

    def body(state):
        labels, prev_prop, _, it, conv, edges = state
        cur_prop = compute_prop(labels, srcs, n, offset)
        # frontier = vertices whose propagation value changed since the last
        # superstep (includes the initial source-adjacency frontier at it=0,
        # because prev_prop starts all-INF).  Paper's edge-check workload
        # metric = sum of frontier out-degrees (Figs 7/8).
        frontier = cur_prop != prev_prop
        row_active = jnp.any(frontier, axis=1)
        edges = edges + jnp.sum(
            jnp.where(frontier, graph.out_deg[None, :], 0), axis=1, dtype=jnp.int32)
        conv = jnp.where(row_active, it + 1, conv)
        cand = relax(cur_prop, graph)
        new = jnp.minimum(labels, cand)
        return new, cur_prop, jnp.any(row_active), it + 1, conv, edges

    s = srcs.shape[0]
    state0 = (labels0, jnp.full((s, n), INF, dtype=jnp.int32), jnp.bool_(True),
              jnp.int32(0), jnp.zeros((s,), jnp.int32), jnp.zeros((s,), jnp.int32))
    labels, _, _, iters, conv, edges = jax.lax.while_loop(cond, body, state0)
    # the final superstep only *verifies* the fixpoint; don't count it as work
    return FixpointResult(labels=labels, iters=jnp.maximum(iters - 1, 0),
                          conv_iter=jnp.maximum(conv - 1, 0), edge_checks=edges)


_fixpoint = functools.partial(jax.jit, static_argnames=("backend", "max_iters"),
                              donate_argnames=("labels0",))(fixpoint_impl)


def gsofa_batch(graph: SymbolicGraph, srcs: jax.Array, *, backend: str = "ell",
                max_iters: Optional[int] = None, labels0: Optional[jax.Array] = None,
                offset: jax.Array | int = 0) -> FixpointResult:
    """Run the fine-grained parallel fixpoint for a batch of sources ("combined
    traversal": one shared computation over the whole batch, DESIGN.md §2)."""
    srcs = jnp.asarray(srcs, dtype=jnp.int32)
    if max_iters is None:
        max_iters = graph.n + 2
    if labels0 is None:
        labels0 = init_labels(graph, srcs, offset=offset)
    return _fixpoint(graph, srcs, labels0, jnp.asarray(offset, jnp.int32),
                     backend, int(max_iters))


# ---------------------------------------------------------------------------
# structure extraction
# ---------------------------------------------------------------------------

def fill_masks(labels: jax.Array, srcs: jax.Array,
               offset: jax.Array | int = 0) -> jax.Array:
    """(S, V) bool: filled structure of each row (originals + fill-ins, no diag)."""
    n = labels.shape[1]
    offset = jnp.asarray(offset, jnp.int32)
    v_ids = jnp.arange(n, dtype=jnp.int32)
    mask = labels < offset + v_ids[None, :]
    return mask & (v_ids[None, :] != srcs[:, None])


def row_counts(labels: jax.Array, srcs: jax.Array,
               offset: jax.Array | int = 0) -> Tuple[jax.Array, jax.Array]:
    """Per-row L-part / U-part structural counts (columns < src / > src)."""
    n = labels.shape[1]
    v_ids = jnp.arange(n, dtype=jnp.int32)
    mask = fill_masks(labels, srcs, offset)
    l_cnt = jnp.sum(mask & (v_ids[None, :] < srcs[:, None]), axis=1)
    u_cnt = jnp.sum(mask & (v_ids[None, :] > srcs[:, None]), axis=1)
    return l_cnt, u_cnt


def dense_pattern(graph: SymbolicGraph, *, backend: str = "ell", batch: int = 64
                  ) -> np.ndarray:
    """Full L+U boolean pattern (diag True) — convenience for tests/benchmarks."""
    n = graph.n
    out = np.zeros((n, n), dtype=bool)
    for start in range(0, n, batch):
        srcs = np.arange(start, min(start + batch, n), dtype=np.int32)
        res = gsofa_batch(graph, srcs, backend=backend)
        out[srcs] = np.asarray(fill_masks(res.labels, jnp.asarray(srcs)))
    np.fill_diagonal(out, True)
    return out
