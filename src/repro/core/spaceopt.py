"""Space-complexity management (paper §VI), adapted to TPU/JAX (DESIGN.md §2).

Three prongs, mirroring the paper:

1. **LabelArena / window trick** — the paper slides the "valid value range" of
   maxId[] down the int32 range so the array is re-initialized only once every
   ``maxVal/|V|`` sources (re-init cost on PR drops 22% -> 0.08%).  This is an
   algebraic trick and transfers verbatim: labels are stored as
   ``offset_k + maxId`` with ``offset_k = top - k*(n+2)``; anything above
   ``offset_k + n`` reads as uninitialized, so the previous chunk's garbage is
   inert and the buffer is reused (donated) without clearing.

2. **Bubble removal** — a source v never touches label entries > v.  Exact
   removal is ragged; we recover it at *chunk* granularity: sources are chunked
   in ascending order and each chunk's label matrix is allocated at width
   ``round_up(max_src_in_chunk + 1)`` instead of |V| (see multisource.plan_chunks).

3. **Memory envelope / auto-#C** — one arena budget covers labels + prop +
   gather scratch; if the configured budget cannot host the requested
   concurrency, #C is reduced (the paper's final fallback, §VI "space
   configurability").  ``bytes_per_source`` accounts for the real resident set
   of the chosen backend.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.gsofa import INF, SymbolicGraph

_I32_TOP = np.int32(np.iinfo(np.int32).max - 4)


@dataclasses.dataclass
class LabelArena:
    """Reusable (C, V) label buffer with sliding-window re-initialization."""

    capacity: int            # max concurrent sources (#C)
    n: int                   # label width (graph order, or chunk bubble width)
    reinits: int = 0         # how many real re-initializations happened
    windows: int = 0         # how many windows were consumed

    def __post_init__(self):
        self._range = self.n + 2
        # leave headroom so offset + n never overflows int32
        self._top = int(_I32_TOP) - self._range
        self._floor = self._range + 1
        self._offset = None   # set on first window
        self.buf = jnp.full((self.capacity, self.n), INF, dtype=jnp.int32)
        self.reinits = 1      # the initial fill is a real initialization

    def next_window(self) -> int:
        """Advance to a fresh value window; re-initialize only on wraparound."""
        if self._offset is None:
            self._offset = self._top
        else:
            self._offset -= self._range
            if self._offset < self._floor:
                # wraparound: one real re-init every ~2^31/|V| windows
                self.buf = jnp.full((self.capacity, self.n), INF, dtype=jnp.int32)
                self.reinits += 1
                self._offset = self._top
        self.windows += 1
        return self._offset

    @property
    def offset(self) -> int:
        assert self._offset is not None, "call next_window() first"
        return self._offset


def bytes_per_source(graph: SymbolicGraph, backend: str = "ell",
                     label_width: Optional[int] = None) -> int:
    """Resident bytes one concurrent source costs during the fixpoint.

    Paper Table II counts 6 structures x |V| entries (two queues, two trackers,
    maxId, fill).  In the dense adaptation the queues/trackers fold into the
    batch dimension; the real per-source residents are: labels (V), prev_prop
    (V), cur_prop (V), and the relaxation scratch — (V * K_in) for the ELL
    gather or the (V) accumulator for the blocked kernel.
    """
    v = label_width if label_width is not None else graph.n
    base = 3 * v * 4
    if backend == "ell":
        k = int(graph.in_ell.shape[1])
        return base + v * k * 4
    return base + v * 4


def auto_concurrency(graph: SymbolicGraph, budget_bytes: Optional[int],
                     requested: int, backend: str = "ell",
                     label_width: Optional[int] = None) -> int:
    """Paper §VI fallback: shrink #C until the resident set fits the envelope."""
    if budget_bytes is None:
        return requested
    per_src = bytes_per_source(graph, backend, label_width)
    fixed = graph.in_ell.size * 4 + graph.out_ell.size * 4 + graph.out_deg.size * 4
    if graph.adj_dense is not None:
        fixed += graph.adj_dense.size
    avail = budget_bytes - fixed
    if avail <= 0:
        return 1
    return max(1, min(requested, avail // per_src))


def aux_memory_report(graph: SymbolicGraph, concurrency: int,
                      backend: str = "ell") -> dict:
    """Fig 16 analogue: auxiliary-structure bytes vs matrix bytes."""
    matrix_bytes = graph.in_ell.size * 4 + graph.out_ell.size * 4
    aux = bytes_per_source(graph, backend) * concurrency
    return {
        "matrix_bytes": int(matrix_bytes),
        "aux_bytes": int(aux),
        "ratio": float(aux) / max(1, matrix_bytes),
        "concurrency": concurrency,
    }
