"""Ground-truth oracles for symbolic LU fill (Theorem 1, Rose & Tarjan).

Two independent oracles (dense, O(n^3), small-n test use only):

1. ``elimination_fill`` — simulate symbolic Gaussian elimination directly
   (the *definition* of fill).
2. ``minimax_fill`` — Floyd-Warshall in the (min, max) "bottleneck path"
   semiring; fill at (i, j) iff the minimal-over-paths maximum intermediate
   vertex on an i->j path is < min(i, j).  This is Theorem 1 verbatim and is
   also the fixpoint the GSoFa label array converges to (DESIGN.md §2).

Agreement of the two (tests/test_gsofa_correctness.py) validates the
bottleneck-semiring reading of Theorem 1 that the Pallas kernel relies on.
"""
from __future__ import annotations

import numpy as np

from repro.sparse.csr import CSRMatrix

INF = np.int64(1 << 40)


def elimination_fill(a: CSRMatrix) -> np.ndarray:
    """Dense boolean L+U pattern by symbolic right-looking elimination."""
    s = a.to_dense().copy()
    np.fill_diagonal(s, True)
    n = a.n
    for k in range(n):
        rows = np.nonzero(s[k + 1:, k])[0] + k + 1
        if len(rows):
            s[np.ix_(rows, np.arange(k + 1, n))] |= s[k, k + 1:]
    return s


def minimax_closure(a: CSRMatrix) -> np.ndarray:
    """B[i, j] = min over directed paths i->j of (max intermediate vertex id),
    with -1 for a direct edge and INF when unreachable.  Floyd-Warshall in the
    (min, max) semiring, k ascending."""
    n = a.n
    b = np.full((n, n), INF, dtype=np.int64)
    for i in range(n):
        cols = a.row(i)
        b[i, cols[cols != i]] = -1
    for k in range(n):
        via = np.maximum.outer(b[:, k], b[k, :])
        via = np.maximum(via, k)
        via[b[:, k] >= INF] = INF
        via[:, b[k, :] >= INF] = INF
        b = np.minimum(b, via)
    return b


def minimax_fill(a: CSRMatrix) -> np.ndarray:
    """Dense boolean L+U pattern via Theorem 1 on the minimax closure."""
    b = minimax_closure(a)
    n = a.n
    i = np.arange(n)
    thresh = np.minimum.outer(i, i)
    filled = b < thresh
    np.fill_diagonal(filled, True)
    return filled


def fill_ratio(a: CSRMatrix, filled: np.ndarray) -> float:
    """#fill-ins / nnz(A) — the Table I '#Fill-in/nnz(A)' statistic."""
    orig = a.to_dense()
    np.fill_diagonal(orig, True)
    new = filled & ~orig
    return float(new.sum()) / max(1, int(orig.sum()))
