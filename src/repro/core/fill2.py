"""Sequential fill2 (Rose & Tarjan 1978), per the paper's Figure 4(a).

This is the CPU baseline GSoFa compares against (SuperLU_DIST's parallel
symbolic factorization is a distributed fill2-family algorithm).  It is also
the second correctness reference for the parallel fixpoint.

The threshold loop ascends and every vertex is visited at most once per
source — the serialization the paper's Challenge #1 identifies.
"""
from __future__ import annotations

from collections import deque
from typing import List, Tuple

import numpy as np

from repro.sparse.csr import CSRMatrix


def fill2_row(a: CSRMatrix, src: int, fill: np.ndarray, *, count_edges: bool = False
              ) -> Tuple[np.ndarray, int]:
    """Filled-structure column ids of row ``src`` (originals + fill-ins, no diagonal).

    ``fill`` is the reusable |V| visitation array (init to -1); entry == src
    marks "visited for this source" (paper lines 3-5, with -1 instead of 0 so
    source 0 needs no special case).
    Returns (sorted column ids, #edge checks) — the edge-check counter is the
    workload metric used in the paper's Figs 7/8.
    """
    edge_checks = 0
    fill[src] = src
    out: List[int] = []
    adj0 = a.row(src)
    for v in adj0:
        if v != src:
            fill[v] = src
            out.append(int(v))
    # Threshold loop: strictly ascending, dynamically gated on fill[t] == src.
    for threshold in range(src):
        if fill[threshold] != src:
            continue
        queue: deque[int] = deque([threshold])
        while queue:
            frontier = queue.popleft()
            row = a.row(frontier)
            edge_checks += len(row)
            for nbr in row:
                nbr = int(nbr)
                if nbr == src or fill[nbr] == src:
                    continue
                fill[nbr] = src
                if nbr > threshold:
                    out.append(nbr)       # fill-in (src, nbr): Theorem 1 holds
                else:
                    queue.append(nbr)     # keep expanding below the threshold
    return np.array(sorted(out), dtype=np.int64), edge_checks


def fill2_all(a: CSRMatrix, sources: np.ndarray | None = None,
              *, count_edges: bool = False):
    """Run fill2 for every source row. Returns (list of row structures, edge counts)."""
    if sources is None:
        sources = np.arange(a.n)
    fill = np.full(a.n, -1, dtype=np.int64)
    rows = []
    edges = np.zeros(len(sources), dtype=np.int64)
    for i, src in enumerate(sources):
        r, ec = fill2_row(a, int(src), fill)
        rows.append(r)
        edges[i] = ec
    return rows, edges


def fill2_dense(a: CSRMatrix) -> np.ndarray:
    """Dense L+U boolean pattern from fill2 (diagonal set True)."""
    rows, _ = fill2_all(a)
    out = np.zeros((a.n, a.n), dtype=bool)
    for i, r in enumerate(rows):
        out[i, r] = True
    np.fill_diagonal(out, True)
    return out
