"""Multi-source concurrent symbolic factorization (paper §V).

* **Combined traversal** — a chunk of #C sources runs as ONE batched fixpoint:
  every vector lane works on whatever (source, vertex) tile is active,
  irrespective of the source — the dense-batch equivalent of the paper's shared
  frontier queue + tracker[] (the tracker is the batch index, free).
  ``combined=False`` runs the same chunk one source at a time (the paper's
  "#C = 1" baseline in Fig 12).

* **Chunk planning with bubble removal** — sources are processed in ascending
  chunks; since a source ``src`` never *expands* vertices >= src, the label
  matrix of a chunk only needs width ``max(src in chunk) + 1`` (rounded for
  retrace stability).  U-part fills beyond the window are pure reachability
  (any discovered path has intermediates < src < v, so Theorem 1 collapses —
  paper §VI "bubble removal", which keeps fill[] full-width but shrinks
  maxId[]); we recover them with one full-width relaxation pass at convergence.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gsofa
from repro.core.gsofa import (
    INF, SymbolicGraph, compute_prop, fill_masks, init_labels, relax_ell,
    row_counts,
)
from repro.core.spaceopt import LabelArena, auto_concurrency
from repro.obs import metrics as _om
from repro.obs import trace as _ot


@dataclasses.dataclass(frozen=True)
class Chunk:
    srcs: np.ndarray     # (S,) int32, padded to full concurrency with repeats
    n_real: int          # how many leading entries are real sources
    width: int           # label width (bubble removal), <= graph.n


def plan_chunks(n: int, concurrency: int, *, bubble: bool = False,
                round_to: int = 256) -> List[Chunk]:
    """Ascending source chunks.  Padding repeats the last source (idempotent —
    duplicate sources converge to identical labels; the extras are sliced off)."""
    chunks: List[Chunk] = []
    for start in range(0, n, concurrency):
        srcs = np.arange(start, min(start + concurrency, n), dtype=np.int32)
        n_real = len(srcs)
        if n_real < concurrency:
            srcs = np.concatenate(
                [srcs, np.full(concurrency - n_real, srcs[-1], dtype=np.int32)])
        if bubble:
            width = min(n, math.ceil((int(srcs[:n_real].max()) + 1)
                                     / round_to) * round_to)
        else:
            width = n
        chunks.append(Chunk(srcs=srcs, n_real=n_real, width=width))
    return chunks


def _chunk_view(graph: SymbolicGraph, width: int) -> SymbolicGraph:
    """Truncated view for bubble-removal chunks: only vertices < width can be
    relaxed/expanded; in-neighbor ids >= width are clipped to the INF pad slot."""
    if width >= graph.n:
        return graph
    return SymbolicGraph(
        n=width,
        in_ell=jnp.minimum(graph.in_ell[:width], jnp.int32(width)),
        out_ell=graph.out_ell,  # unused by the fixpoint (init passes nbrs)
        out_deg=graph.out_deg[:width],
        adj_dense=None,
    )


def _finalize_bubble(graph: SymbolicGraph, labels_w: jax.Array, srcs: jax.Array,
                     offset, width: int) -> jax.Array:
    """Full-width fill mask from a truncated-label fixpoint.

    v < width: Theorem-1 test on the converged labels.  v >= width (> src):
    reachability — one extra full-width relaxation of the converged props,
    plus the direct edges of each source.
    """
    n = graph.n
    prop = compute_prop(labels_w, srcs, width, offset)
    prop_full = jnp.pad(prop, ((0, 0), (0, n - width)), constant_values=INF)
    cand_full = relax_ell(prop_full, graph)                 # (S, n)
    v_ids = jnp.arange(n, dtype=jnp.int32)
    low = fill_masks(labels_w, srcs, offset)                # (S, width)
    direct = init_labels(graph, srcs) < INF                 # (S, n) original edges
    high = (cand_full < INF) | direct
    mask = jnp.concatenate(
        [low, high[:, width:]], axis=1) if width < n else low
    return mask & (v_ids[None, :] != srcs[:, None])


@dataclasses.dataclass
class MultiSourceResult:
    l_counts: np.ndarray        # (n,) structural L counts per row (no diag)
    u_counts: np.ndarray        # (n,)
    edge_checks: np.ndarray     # (n,) paper workload metric per source
    conv_iters: np.ndarray      # (n,) supersteps each source stayed active
    supersteps: int             # total supersteps across chunks
    n_chunks: int
    concurrency: int
    reinits: int                # real label re-initializations (window trick)
    windows: int

    @property
    def total_nnz(self) -> int:
        return int(self.l_counts.sum() + self.u_counts.sum() + len(self.l_counts))


def run_multisource(graph: SymbolicGraph, *, concurrency: int = 64,
                    backend: str = "ell", combined: bool = True,
                    bubble: bool = False, use_arena: bool = True,
                    budget_bytes: Optional[int] = None,
                    sources: Optional[np.ndarray] = None,
                    collect_masks: bool = False,
                    on_chunk: Optional[Callable] = None,
                    on_mask: Optional[Callable] = None,
                    on_progress: Optional[Callable] = None
                    ) -> MultiSourceResult:
    """Single-device multi-source driver: plan chunks, run fixpoints, aggregate.

    ``on_chunk(labels, srcs, offset)`` is invoked with every converged label
    matrix before it is recycled — labels is the (G, W) device array (W < n
    for bubble chunks), srcs the matching source ids (repeats possible from
    padding), offset the label-window base.  This is how supernode
    fingerprinting (repro.supernodes) overlaps detection with the symbolic
    chunks instead of gathering the dense pattern afterwards.

    ``on_mask(mask, srcs)`` receives the *full-width* (G, n) bool fill mask
    of each converged chunk (bubble chunks are finalized to full width
    first) — this is how the sparse CSC pattern streams out of the fixpoint
    (core.symbolic.PatternCollector) without ever gathering a dense (n, n)
    pattern on the host: each delivery is O(concurrency * n) and is reduced
    to per-row index lists before the next chunk arrives.

    ``on_progress(done, total, eta_s)`` fires once per completed chunk with
    a rolling-rate ETA (``repro.obs.ProgressMeter``) — the opt-in progress
    surface for long analyzes (bbd-20k runs ~88 s otherwise silent).
    """
    n = graph.n
    concurrency = auto_concurrency(graph, budget_bytes, concurrency, backend)
    if not combined:
        concurrency = max(1, concurrency)
    chunks = plan_chunks(n, concurrency, bubble=bubble)
    if sources is not None:
        # explicit source set (distributed callers slice their shard)
        chunks = []
        for start in range(0, len(sources), concurrency):
            srcs = np.asarray(sources[start:start + concurrency], dtype=np.int32)
            n_real = len(srcs)
            if n_real < concurrency:
                srcs = np.concatenate(
                    [srcs, np.full(concurrency - n_real, srcs[-1], np.int32)])
            chunks.append(Chunk(srcs=srcs, n_real=n_real, width=n))

    arena = None
    if use_arena and not bubble:
        arena = LabelArena(capacity=concurrency, n=n)

    l_counts = np.zeros(n, dtype=np.int64)
    u_counts = np.zeros(n, dtype=np.int64)
    edge_checks = np.zeros(n, dtype=np.int64)
    conv_iters = np.zeros(n, dtype=np.int64)
    masks = np.zeros((n, n), dtype=bool) if collect_masks else None
    supersteps = 0

    meter = _om.ProgressMeter(on_progress) if on_progress is not None else None
    for ci, chunk in enumerate(chunks):
        srcs = jnp.asarray(chunk.srcs)
        if combined:
            groups = [np.arange(len(chunk.srcs))]
        else:
            groups = [np.array([i]) for i in range(chunk.n_real)]
        for g in groups:
            with _ot.span("fixpoint_chunk"):
                gs = srcs[jnp.asarray(g)]
                if bubble and chunk.width < n:
                    offset = 0
                    view = _chunk_view(graph, chunk.width)
                    nbrs = graph.out_ell[gs]
                    labels0 = init_labels(view, gs, nbrs=nbrs)
                    res = gsofa.gsofa_batch(view, gs, backend="ell",
                                            labels0=labels0,
                                            max_iters=chunk.width + 2)
                    mask = _finalize_bubble(graph, res.labels, gs, 0,
                                            chunk.width)
                    v_ids = jnp.arange(n, dtype=jnp.int32)
                    l_cnt = jnp.sum(mask & (v_ids[None, :] < gs[:, None]),
                                    axis=1)
                    u_cnt = jnp.sum(mask & (v_ids[None, :] > gs[:, None]),
                                    axis=1)
                else:
                    offset = 0
                    labels0 = None
                    if arena is not None and combined:
                        offset = arena.next_window()
                        labels0 = init_labels(graph, gs, offset=offset,
                                              stale_buf=arena.buf)
                    res = gsofa.gsofa_batch(graph, gs, backend=backend,
                                            labels0=labels0, offset=offset)
                    if arena is not None and combined:
                        arena.buf = res.labels
                    mask = None
                    if collect_masks or on_mask is not None:
                        mask = fill_masks(res.labels, gs, offset)
                    l_cnt, u_cnt = row_counts(res.labels, gs, offset)

                if on_chunk is not None:
                    on_chunk(res.labels, chunk.srcs[np.asarray(g)], offset)
                if on_mask is not None:
                    on_mask(mask, chunk.srcs[np.asarray(g)])
                real = np.asarray(g) < chunk.n_real
                real_idx = chunk.srcs[np.asarray(g)[real]]
                l_counts[real_idx] = np.asarray(l_cnt)[real]
                u_counts[real_idx] = np.asarray(u_cnt)[real]
                edge_checks[real_idx] = np.asarray(res.edge_checks)[real]
                conv_iters[real_idx] = np.asarray(res.conv_iter)[real]
                supersteps += int(res.iters)
                if collect_masks and mask is not None:
                    masks[real_idx] = np.asarray(mask)[real]
                if _ot.ENABLED:
                    _om.registry().observe("fixpoint.iterations",
                                           int(res.iters))
                    _om.registry().count("fixpoint.chunks")
        if meter is not None:
            meter.update(ci + 1, len(chunks))

    result = MultiSourceResult(
        l_counts=l_counts, u_counts=u_counts, edge_checks=edge_checks,
        conv_iters=conv_iters, supersteps=supersteps, n_chunks=len(chunks),
        concurrency=concurrency,
        reinits=arena.reinits if arena else len(chunks),
        windows=arena.windows if arena else len(chunks),
    )
    if collect_masks:
        result.masks = masks  # type: ignore[attr-defined]
    return result
