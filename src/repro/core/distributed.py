"""Distributed GSoFa: sources sharded across the device mesh via shard_map.

The paper scales to 1,000 GPUs because sources are *independent* once
per-source work is balanced; scaling is then purely a scheduling question:

* **interleaved (round-robin) source assignment** (paper §V, Fig 8): workload
  grows with the source id (Theorem 1 admits more intermediates), so a
  contiguous split loads late devices ~10x heavier; strided assignment
  ``src[d, i] = d + i * D`` flattens it to ~1.0x.
* each device runs the *combined traversal* over its local batch — exactly the
  single-device fixpoint; no collectives inside the loop (each device's
  while_loop trip count is its own), one all-gather of the per-source counts
  at the end (implicit in the shard_map output spec).

``make_distributed_counts`` returns the jitted shard_map step used both for
real execution (tests run it on 8 host devices) and for the 512-device
production-mesh dry-run (launch/dryrun.py lowers it with ShapeDtypeStructs).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import SHARD_MAP_NOCHECK_KW, shard_map
from repro.core.gsofa import (
    SymbolicGraph, fill_masks, fixpoint_impl, init_labels, row_counts,
)


def assign_sources(n: int, n_shards: int, *, policy: str = "interleave") -> np.ndarray:
    """(n_shards, ceil(n / n_shards)) source matrix; short rows padded by
    repeating the row's last source (idempotent duplicates, sliced on return).

    interleave: src[d, i] = d + i * D   (paper's round-robin, Fig 8 'after')
    contiguous: src[d, i] = d * C + i   (the imbalanced baseline, Fig 8 'before')
    """
    per = -(-n // n_shards)
    total = per * n_shards
    ids = np.arange(total, dtype=np.int32)
    if policy == "interleave":
        mat = ids.reshape(per, n_shards).T
    elif policy == "contiguous":
        mat = ids.reshape(n_shards, per)
    else:
        raise ValueError(policy)
    mat = np.where(mat < n, mat, np.int32(n - 1))
    return np.ascontiguousarray(mat)


def _local_body(srcs_local: jax.Array, graph: SymbolicGraph, max_iters: int,
                backend: str) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-device work: batched fixpoint over the local source rows."""
    srcs = srcs_local.reshape(-1)
    labels0 = init_labels(graph, srcs)
    res = fixpoint_impl(graph, srcs, labels0, jnp.int32(0), backend, max_iters)
    l_cnt, u_cnt = row_counts(res.labels, srcs)
    shape = srcs_local.shape
    return (l_cnt.reshape(shape), u_cnt.reshape(shape),
            res.edge_checks.reshape(shape),
            jnp.broadcast_to(res.iters, (shape[0],)))


def make_distributed_counts(mesh: Mesh, graph_n: int, *, backend: str = "ell",
                            max_iters: Optional[int] = None,
                            axes: Optional[tuple] = None):
    """Build the jitted distributed step.

    The source matrix's leading axis is sharded over ``axes`` (default: every
    mesh axis, i.e. the fully-flattened device space — this is what scales the
    paper to 1,000 GPUs; for the LM production mesh it is ('pod','data','model')).
    The graph is replicated: symbolic factorization reads A everywhere but
    writes only its own rows, so the only communication is the final gather.
    """
    if axes is None:
        axes = tuple(mesh.axis_names)
    if max_iters is None:
        max_iters = graph_n + 2
    spec_src = P(axes, None)
    spec_rep = P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_src, spec_rep),
        out_specs=(spec_src, spec_src, spec_src, P(axes)),
        # the while_loop carry mixes device-varying labels with replicated
        # scalars (trip counts differ per device by design) — disable the
        # varying-manual-axes (check_rep on older jax) check rather than
        # pcast every carry leaf
        **SHARD_MAP_NOCHECK_KW,
    )
    def body(srcs_mat, graph):
        return _local_body(srcs_mat, graph, max_iters, backend)

    in_shardings = (NamedSharding(mesh, spec_src), NamedSharding(mesh, spec_rep))
    out_shardings = (NamedSharding(mesh, spec_src), NamedSharding(mesh, spec_src),
                     NamedSharding(mesh, spec_src), NamedSharding(mesh, P(axes)))
    return jax.jit(body, in_shardings=in_shardings, out_shardings=out_shardings)


def distributed_symbolic(graph: SymbolicGraph, mesh: Mesh, *,
                         policy: str = "interleave", backend: str = "ell",
                         axes: Optional[tuple] = None) -> dict:
    """Run distributed symbolic factorization; returns counts + balance metrics."""
    if axes is None:
        axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    srcs = assign_sources(graph.n, n_shards, policy=policy)
    step = make_distributed_counts(mesh, graph.n, backend=backend, axes=axes)
    l_cnt, u_cnt, edges, iters = step(jnp.asarray(srcs), graph)
    l_cnt, u_cnt = np.asarray(l_cnt), np.asarray(u_cnt)
    edges = np.asarray(edges)
    # fold the (shard, slot) matrix back to per-source vectors, dropping pads
    l_out = np.zeros(graph.n, dtype=np.int64)
    u_out = np.zeros(graph.n, dtype=np.int64)
    seen = np.zeros(graph.n, dtype=bool)
    per_dev_edges = np.zeros(n_shards, dtype=np.int64)
    for d in range(n_shards):
        for i, s in enumerate(srcs[d]):
            if not seen[s]:
                l_out[s], u_out[s] = l_cnt[d, i], u_cnt[d, i]
                seen[s] = True
                per_dev_edges[d] += edges[d, i]
    balance = float(per_dev_edges.max()) / max(1.0, float(per_dev_edges.min()))
    return {
        "l_counts": l_out,
        "u_counts": u_out,
        "per_device_edge_checks": per_dev_edges,
        "balance_ratio": balance,
        "iters": np.asarray(iters),
        "n_shards": n_shards,
        "policy": policy,
    }
