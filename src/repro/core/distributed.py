"""Distributed GSoFa: sources sharded across the device mesh via shard_map.

The paper scales to 1,000 GPUs because sources are *independent* once
per-source work is balanced; scaling is then purely a scheduling question:

* **interleaved (round-robin) source assignment** (paper §V, Fig 8): workload
  grows with the source id (Theorem 1 admits more intermediates), so a
  contiguous split loads late devices ~10x heavier; strided assignment
  ``src[d, i] = d + i * D`` flattens it to ~1.0x.
* each device runs the *combined traversal* over its local batch — exactly the
  single-device fixpoint; no collectives inside the loop (each device's
  while_loop trip count is its own), one all-gather of the per-source counts
  at the end (implicit in the shard_map output spec).

``make_distributed_counts`` returns the jitted shard_map step used both for
real execution (tests run it on 8 host devices) and for the 512-device
production-mesh dry-run (launch/dryrun.py lowers it with ShapeDtypeStructs).

``distributed_multisource`` is the *analyze* driver (DESIGN.md §11): the
same per-shard fixpoint, but streaming each converged chunk's label matrix
and fill mask back to the host so supernode fingerprints
(supernodes/fingerprint.py) accumulate per shard — merged afterwards
through ``runtime/collectives.merge_fingerprint_shards`` — and the sparse
``CSCPattern`` streams through the ``PatternCollector`` hook.  No dense
(n, n) pattern ever exists on any shard or on the host: each chunk step
moves O(n_shards * concurrency * n) labels, reduced to O(nnz) state before
the next step.  ``core.symbolic.symbolic_factorize(mesh=...)`` routes
through this driver, which is how ``repro.analyze`` distributes.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import SHARD_MAP_NOCHECK_KW, shard_map
from repro.core.gsofa import (
    SymbolicGraph, fill_masks, fixpoint_impl, init_labels, row_counts,
)
from repro.obs import metrics as _om
from repro.obs import trace as _ot


def assign_sources(n: int, n_shards: int, *, policy: str = "interleave") -> np.ndarray:
    """(n_shards, ceil(n / n_shards)) source matrix; short rows padded by
    repeating the row's last source (idempotent duplicates, sliced on return).

    interleave: src[d, i] = d + i * D   (paper's round-robin, Fig 8 'after')
    contiguous: src[d, i] = d * C + i   (the imbalanced baseline, Fig 8 'before')
    """
    per = -(-n // n_shards)
    total = per * n_shards
    ids = np.arange(total, dtype=np.int32)
    if policy == "interleave":
        mat = ids.reshape(per, n_shards).T
    elif policy == "contiguous":
        mat = ids.reshape(n_shards, per)
    else:
        raise ValueError(policy)
    mat = np.where(mat < n, mat, np.int32(n - 1))
    return np.ascontiguousarray(mat)


def _local_body(srcs_local: jax.Array, graph: SymbolicGraph, max_iters: int,
                backend: str) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-device work: batched fixpoint over the local source rows."""
    srcs = srcs_local.reshape(-1)
    labels0 = init_labels(graph, srcs)
    res = fixpoint_impl(graph, srcs, labels0, jnp.int32(0), backend, max_iters)
    l_cnt, u_cnt = row_counts(res.labels, srcs)
    shape = srcs_local.shape
    return (l_cnt.reshape(shape), u_cnt.reshape(shape),
            res.edge_checks.reshape(shape),
            jnp.broadcast_to(res.iters, (shape[0],)))


def make_distributed_counts(mesh: Mesh, graph_n: int, *, backend: str = "ell",
                            max_iters: Optional[int] = None,
                            axes: Optional[tuple] = None):
    """Build the jitted distributed step.

    The source matrix's leading axis is sharded over ``axes`` (default: every
    mesh axis, i.e. the fully-flattened device space — this is what scales the
    paper to 1,000 GPUs; for the LM production mesh it is ('pod','data','model')).
    The graph is replicated: symbolic factorization reads A everywhere but
    writes only its own rows, so the only communication is the final gather.
    """
    if axes is None:
        axes = tuple(mesh.axis_names)
    if max_iters is None:
        max_iters = graph_n + 2
    spec_src = P(axes, None)
    spec_rep = P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_src, spec_rep),
        out_specs=(spec_src, spec_src, spec_src, P(axes)),
        # the while_loop carry mixes device-varying labels with replicated
        # scalars (trip counts differ per device by design) — disable the
        # varying-manual-axes (check_rep on older jax) check rather than
        # pcast every carry leaf
        **SHARD_MAP_NOCHECK_KW,
    )
    def body(srcs_mat, graph):
        return _local_body(srcs_mat, graph, max_iters, backend)

    in_shardings = (NamedSharding(mesh, spec_src), NamedSharding(mesh, spec_rep))
    out_shardings = (NamedSharding(mesh, spec_src), NamedSharding(mesh, spec_src),
                     NamedSharding(mesh, spec_src), NamedSharding(mesh, P(axes)))
    return jax.jit(body, in_shardings=in_shardings, out_shardings=out_shardings)


def distributed_symbolic(graph: SymbolicGraph, mesh: Mesh, *,
                         policy: str = "interleave", backend: str = "ell",
                         axes: Optional[tuple] = None) -> dict:
    """Run distributed symbolic factorization; returns counts + balance metrics."""
    if axes is None:
        axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    srcs = assign_sources(graph.n, n_shards, policy=policy)
    step = make_distributed_counts(mesh, graph.n, backend=backend, axes=axes)
    l_cnt, u_cnt, edges, iters = step(jnp.asarray(srcs), graph)
    l_cnt, u_cnt = np.asarray(l_cnt), np.asarray(u_cnt)
    edges = np.asarray(edges)
    # fold the (shard, slot) matrix back to per-source vectors, dropping pads
    l_out = np.zeros(graph.n, dtype=np.int64)
    u_out = np.zeros(graph.n, dtype=np.int64)
    seen = np.zeros(graph.n, dtype=bool)
    per_dev_edges = np.zeros(n_shards, dtype=np.int64)
    for d in range(n_shards):
        for i, s in enumerate(srcs[d]):
            if not seen[s]:
                l_out[s], u_out[s] = l_cnt[d, i], u_cnt[d, i]
                seen[s] = True
                per_dev_edges[d] += edges[d, i]
    balance = float(per_dev_edges.max()) / max(1.0, float(per_dev_edges.min()))
    return {
        "l_counts": l_out,
        "u_counts": u_out,
        "per_device_edge_checks": per_dev_edges,
        "balance_ratio": balance,
        "iters": np.asarray(iters),
        "n_shards": n_shards,
        "policy": policy,
    }


# ---------------------------------------------------------------------------
# distributed analyze: the fixpoint chunk step that streams labels + masks
# ---------------------------------------------------------------------------

def ownership_mask(srcs_mat: np.ndarray) -> np.ndarray:
    """(D, S) bool: True at the globally-first occurrence of each source.

    ``assign_sources`` pads short rows by clipping ids to ``n - 1``, so the
    last source can appear on several shards; exactly one shard must *own*
    each source or per-shard fingerprint partials would double-count on
    merge (``ColumnFingerprints.merge`` rejects overlapping shards for the
    same reason).
    """
    flat = srcs_mat.reshape(-1)
    owned = np.zeros(flat.shape, dtype=bool)
    _, first = np.unique(flat, return_index=True)
    owned[first] = True
    return owned.reshape(srcs_mat.shape)


def make_distributed_chunk_step(mesh: Mesh, graph_n: int, *,
                                backend: str = "ell",
                                max_iters: Optional[int] = None,
                                axes: Optional[tuple] = None):
    """Jitted shard_map step for ONE source chunk per device.

    In: (D, C) source matrix sharded over ``axes``; replicated graph.
    Out (all sharded on the leading axis): converged (D, C, n) label
    matrices, (D, C, n) bool fill masks, (D, C) l/u counts and edge
    checks, (D,) per-shard superstep counts.  The labels/masks leave the
    step so the host can feed the streaming supernode-fingerprint and
    pattern collectors — O(D * C * n) per step, never (n, n) anywhere.
    """
    if axes is None:
        axes = tuple(mesh.axis_names)
    if max_iters is None:
        max_iters = graph_n + 2
    spec_src = P(axes, None)
    spec_mat = P(axes, None, None)
    spec_rep = P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_src, spec_rep),
        out_specs=(spec_mat, spec_mat, spec_src, spec_src, spec_src, P(axes)),
        **SHARD_MAP_NOCHECK_KW,     # per-device while_loop trip counts differ
    )
    def body(srcs_mat, graph):
        srcs = srcs_mat.reshape(-1)                       # (C,) local chunk
        labels0 = init_labels(graph, srcs)
        res = fixpoint_impl(graph, srcs, labels0, jnp.int32(0), backend,
                            max_iters)
        mask = fill_masks(res.labels, srcs)
        l_cnt, u_cnt = row_counts(res.labels, srcs)
        lead = srcs_mat.shape                             # (1, C) local
        return (res.labels.reshape(lead + (graph.n,)),
                mask.reshape(lead + (graph.n,)),
                l_cnt.reshape(lead), u_cnt.reshape(lead),
                res.edge_checks.reshape(lead),
                jnp.broadcast_to(res.iters, (lead[0],)))

    shardings = {spec_src: NamedSharding(mesh, spec_src),
                 spec_mat: NamedSharding(mesh, spec_mat)}
    return jax.jit(
        body,
        in_shardings=(shardings[spec_src], NamedSharding(mesh, spec_rep)),
        out_shardings=(shardings[spec_mat], shardings[spec_mat],
                       shardings[spec_src], shardings[spec_src],
                       shardings[spec_src], NamedSharding(mesh, P(axes))))


def make_chunk_step(graph_n: int, *, backend: str = "ell",
                    max_iters: Optional[int] = None):
    """Jitted *single-device* chunk step — the mesh-less sibling of
    ``make_distributed_chunk_step``, and the closure the dynamic
    work-stealing scheduler (``runtime.scheduler``) launches per device.

    In: (C,) int32 sources + the (replicated) graph.  Out: converged
    (C, n) labels, (C, n) bool fill masks, (C,) l/u counts and edge
    checks, and the chunk's superstep count — exactly the streams the
    fingerprint/pattern collectors consume, so a host-driven scheduler
    can feed ``repro.analyze`` the same data the static drivers do.

    Dispatch is async: the returned callable hands back device arrays
    immediately; poll ``.is_ready()`` (or block via ``np.asarray``) on
    the outputs.  Per-source fixpoints are unique and chunking- and
    device-independent, so results are bitwise-identical no matter which
    device runs which chunk, in what order, or how many times.
    """
    if max_iters is None:
        max_iters = graph_n + 2

    @jax.jit
    def step(srcs, graph):
        labels0 = init_labels(graph, srcs)
        res = fixpoint_impl(graph, srcs, labels0, jnp.int32(0), backend,
                            max_iters)
        mask = fill_masks(res.labels, srcs)
        l_cnt, u_cnt = row_counts(res.labels, srcs)
        return res.labels, mask, l_cnt, u_cnt, res.edge_checks, res.iters

    return step


def distributed_multisource(graph: SymbolicGraph, mesh: Mesh, *,
                            concurrency: int = 128, backend: str = "ell",
                            policy: str = "interleave",
                            axes: Optional[tuple] = None,
                            on_shard_chunk: Optional[Callable] = None,
                            on_shard_mask: Optional[Callable] = None,
                            on_progress: Optional[Callable] = None):
    """Multi-source symbolic fixpoint sharded over the mesh, streaming each
    shard's converged chunks back to the host.

    ``on_shard_chunk(d, labels, srcs)`` receives shard ``d``'s converged
    (G, n) label matrix restricted to the rows that shard *owns* (see
    ``ownership_mask``) — this is where per-shard ``ColumnFingerprints``
    accumulate.  ``on_shard_mask(d, mask, srcs)`` receives the matching
    bool fill masks (all rows — ``PatternCollector.update`` is idempotent)
    for streaming the sparse CSC pattern.  Every per-source fixpoint is
    *identical* to the single-device driver's (the fixpoint is unique and
    chunking-independent), so counts, fingerprints, and patterns are
    bitwise-equal to ``run_multisource`` at any device count.

    ``on_progress(done, total, eta_s)`` (optional) fires after every
    sharded chunk step with a rolling-rate ETA — the same callback shape
    ``run_multisource`` takes, surfaced as ``analyze(on_progress=...)``.

    The loop is **double-buffered**: step k+1 is dispatched (JAX dispatch
    is async) before chunk k's host reduction runs, so fingerprint/pattern
    accumulation hides behind the next device step.  Chunks are reduced
    strictly in submission order, so delivery — and therefore every output
    — is bitwise-identical to the synchronous loop; the hidden reduction
    wall-time is reported as ``result.dist["overlap_hidden_s"]`` and the
    ``overlap.hidden_s`` counter (an ``overlap`` span wraps each hidden
    reduction when tracing).

    Returns a ``core.multisource.MultiSourceResult`` plus a ``stats`` dict
    (per-device edge checks, balance ratio) attached as ``result.dist``.
    """
    from repro.core.multisource import MultiSourceResult

    if axes is None:
        axes = tuple(mesh.axis_names)
    n = graph.n
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    srcs_mat = assign_sources(n, n_shards, policy=policy)   # (D, per)
    owned = ownership_mask(srcs_mat)
    per = srcs_mat.shape[1]
    concurrency = max(1, min(concurrency, per))
    step = make_distributed_chunk_step(mesh, n, backend=backend, axes=axes)

    l_counts = np.zeros(n, dtype=np.int64)
    u_counts = np.zeros(n, dtype=np.int64)
    edge_checks = np.zeros(n, dtype=np.int64)
    conv_iters = np.zeros(n, dtype=np.int64)
    per_dev_edges = np.zeros(n_shards, dtype=np.int64)
    supersteps = 0
    n_chunks = 0

    total_steps = -(-per // concurrency)
    meter = _om.ProgressMeter(on_progress) if on_progress is not None else None

    def _inputs(start):
        cols = srcs_mat[:, start:start + concurrency]
        own = owned[:, start:start + concurrency]
        if cols.shape[1] < concurrency:
            # fixed step shape: pad by repeating each shard's last column
            # (duplicate sources are idempotent and never owned twice)
            short = concurrency - cols.shape[1]
            cols = np.concatenate(
                [cols, np.repeat(cols[:, -1:], short, axis=1)], axis=1)
            own = np.concatenate(
                [own, np.zeros((n_shards, short), dtype=bool)], axis=1)
        return cols, own

    def _reduce(cols, own, outs):
        nonlocal supersteps, n_chunks
        labels, mask, l_cnt, u_cnt, edges, iters = outs
        labels = np.asarray(labels)
        mask = np.asarray(mask)
        l_cnt, u_cnt = np.asarray(l_cnt), np.asarray(u_cnt)
        edges = np.asarray(edges)
        with _ot.span("host_reduce"):
            for d in range(n_shards):
                keep = own[d]
                srcs_d = cols[d][keep]
                l_counts[srcs_d] = l_cnt[d][keep]
                u_counts[srcs_d] = u_cnt[d][keep]
                edge_checks[srcs_d] = edges[d][keep]
                per_dev_edges[d] += int(edges[d][keep].sum())
                if on_shard_chunk is not None and keep.any():
                    on_shard_chunk(d, labels[d][keep], srcs_d)
                if on_shard_mask is not None:
                    on_shard_mask(d, mask[d], cols[d])
        # per-shard while_loop trip counts differ by design; the step's
        # wall-clock is the slowest shard's count
        supersteps += int(np.asarray(iters).max())
        n_chunks += 1
        if _ot.ENABLED:
            _om.registry().observe("fixpoint.iterations",
                                   int(np.asarray(iters).max()))
            _om.registry().count("fixpoint.chunks")
        if meter is not None:
            meter.update(n_chunks, total_steps)

    # double-buffered fixpoint: dispatch step k+1 (async JAX dispatch keeps
    # the devices busy) *before* consuming step k's outputs, so the host-side
    # fingerprint/pattern reduction of chunk k overlaps the device compute of
    # chunk k+1.  Chunks are still reduced strictly in order, so every
    # collector sees the exact same delivery sequence as the synchronous loop
    # — the bitwise conformance contract is untouched.
    pending = None
    overlap_hidden = 0.0
    for start in range(0, per, concurrency):
        with _ot.span("fixpoint_chunk"):
            cols, own = _inputs(start)
            outs = step(jnp.asarray(cols), graph)
        if pending is not None:
            t0 = time.perf_counter()
            with _ot.span("overlap"):
                _reduce(*pending)
            overlap_hidden += time.perf_counter() - t0
        pending = (cols, own, outs)
    if pending is not None:
        _reduce(*pending)       # the last chunk has nothing left to hide it
    if _ot.ENABLED:
        _om.registry().count("overlap.hidden_s", overlap_hidden)

    result = MultiSourceResult(
        l_counts=l_counts, u_counts=u_counts, edge_checks=edge_checks,
        conv_iters=conv_iters, supersteps=supersteps, n_chunks=n_chunks,
        concurrency=concurrency, reinits=n_chunks, windows=n_chunks)
    balance = (float(per_dev_edges.max()) / max(1.0, float(per_dev_edges.min()))
               if n_shards > 1 else 1.0)
    result.dist = {                                 # type: ignore[attr-defined]
        "n_shards": n_shards,
        "per_device_edge_checks": per_dev_edges,
        "balance_ratio": balance,
        "policy": policy,
        "overlap_hidden_s": overlap_hidden,
    }
    return result
