"""Numerical robustness tier (DESIGN.md §15): static pivoting, tiny-pivot
perturbation support, and factorization-quality certificates.

The pipeline's contract elsewhere is *pivot-free* numeric sweeps on a
pattern fixed at analyze time.  This package supplies everything that
makes that contract survive indefinite / non-diagonally-dominant systems:

* ``build_robust_prepass`` / ``RobustPlan`` — the analyze-time
  maximum-product transversal + Ruiz equilibration producing the
  ``A_f = Dr·P·A·Dc`` transform stored on the plan
  (``LUOptions(pivot="static")``).
* ``QualityReport`` / ``estimate_quality`` — element growth + Hager 1-norm
  condition estimate + trust verdict on a completed factorization
  (``LUFactorization.quality()``).

Tiny-pivot perturbation itself lives with the pivot kernels
(``repro.sparse.numeric.PerturbState``, ``LUOptions(perturb=True)``); its
counts surface here through the quality report.
"""
from repro.robust.condition import (
    QualityReport, condest_1, element_growth, estimate_quality,
)
from repro.robust.transversal import (
    RobustPlan, StructurallySingularError, build_robust_prepass,
    equilibrate, max_product_transversal,
)

__all__ = [
    "QualityReport",
    "RobustPlan",
    "StructurallySingularError",
    "build_robust_prepass",
    "condest_1",
    "element_growth",
    "equilibrate",
    "estimate_quality",
    "max_product_transversal",
]
