"""Static-pivoting pre-pass: maximum-product transversal + equilibration
(DESIGN.md §15).

GSoFa-style symbolic factorization is only useful when the numeric sweep it
feeds can run *without* pivoting — row exchanges at factor time would
invalidate the predicted pattern.  The SuperLU_DIST / HYLU / GLU3.0 answer
is to spend the pivoting budget **once, at analyze time**: pick a row
permutation that puts the largest attainable entries on the diagonal
(a maximum-weight transversal of the bipartite value graph, MC64 job=5
style), equilibrate rows and columns so every scaled entry is O(1), and
factorize the permuted, scaled matrix ``A_f = Dr·P·A·Dc`` with no pivoting
at all.  The permutation and scalings are *plan properties*: refactorizing
with new values replays a precomputed O(nnz) index gather + elementwise
scale (``RobustPlan.transform_values``) — no symbolic work, no matching
rerun — so the analyze-once/refactorize-many contract survives intact.

The matching maximizes the product of |A[perm[j], j]| over the chosen
transversal (equivalently minimizes sum of ``log(colmax_j) - log|a_ij|``,
the classic MC64 objective) via scipy's sparse LAPJVsp; entries with zero
*value* carry no weight information and are excluded, with a structural
fallback so a pattern-nonsingular matrix whose value support happens to be
deficient still gets a valid transversal.  Scaling is Ruiz equilibration
(alternating row/column sup-norm square-root scaling, a fixed iteration
count so results are deterministic), which converges to max|row| =
max|col| = 1 — the same fixed point MC64's duals produce.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.sparse.csr import CSRMatrix


class StructurallySingularError(ValueError):
    """The pattern admits no complete transversal: some set of k rows
    touches fewer than k columns (Hall violation), so *no* row permutation
    can produce a zero-free diagonal — the matrix is singular for every
    value assignment and static pivoting cannot help."""


def _entry_triplets(a: CSRMatrix, values: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(rows, cols, |values|) of every stored entry, CSR order."""
    values = np.asarray(values, dtype=np.float64)
    rows = np.repeat(np.arange(a.n, dtype=np.int64), np.diff(a.indptr))
    cols = a.indices.astype(np.int64)
    if values.ndim == 2:                 # dense (n, n) convenience form
        absv = np.abs(values[rows, cols])
    else:
        if values.shape != (a.nnz,):
            raise ValueError(f"values must be CSR-aligned ({a.nnz},) or "
                             f"dense ({a.n}, {a.n}), got {values.shape}")
        absv = np.abs(values)
    return rows, cols, absv


def _matching(n: int, rows: np.ndarray, cols: np.ndarray,
              weights: np.ndarray) -> np.ndarray:
    """perm with ``perm[j]`` = the row matched to column j, maximizing the
    product of ``weights`` over the transversal.  Raises ``ValueError``
    (from scipy) when no complete matching exists on these edges."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import min_weight_full_bipartite_matching

    # max prod w_ij == min sum (log colmax_j - log w_ij); the +1 shift keeps
    # every stored cost strictly positive (scipy treats stored zeros as
    # absent edges)
    colmax = np.zeros(n, dtype=np.float64)
    np.maximum.at(colmax, cols, weights)
    cost = np.log(colmax[cols]) - np.log(weights) + 1.0
    graph = sp.csr_matrix((cost, (rows, cols)), shape=(n, n))
    row_ind, col_ind = min_weight_full_bipartite_matching(graph)
    perm = np.empty(n, dtype=np.int64)
    perm[col_ind] = row_ind
    return perm


def max_product_transversal(a: CSRMatrix, values: np.ndarray) -> np.ndarray:
    """Row permutation ``perm`` with factored row j = original row
    ``perm[j]``, chosen to maximize ``prod_j |A[perm[j], j]|``.

    Zero-valued stored entries are excluded from the weighted matching
    (log-weight undefined; a zero on the diagonal is exactly what we are
    permuting *away* from).  If the nonzero-value support has no complete
    matching, falls back to a structural matching over the full pattern
    (unit weights); only a pattern-level Hall violation raises
    ``StructurallySingularError``.
    """
    rows, cols, absv = _entry_triplets(a, values)
    live = absv > 0.0
    if live.any():
        try:
            return _matching(a.n, rows[live], cols[live], absv[live])
        except ValueError:
            pass                    # value support deficient — go structural
    try:
        return _matching(a.n, rows, cols, np.ones(len(rows)))
    except ValueError:
        raise StructurallySingularError(
            f"pattern has no complete transversal at n={a.n} — the matrix "
            f"is structurally singular; no static pivoting can repair it"
        ) from None


def equilibrate(n: int, rows: np.ndarray, cols: np.ndarray,
                absv: np.ndarray, *, iters: int = 8
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Ruiz row/column equilibration of the |A| triple: returns positive
    ``(r, c)`` with ``r[rows] * absv * c[cols]`` having row and column
    sup-norms approaching 1.  A fixed iteration count (convergence is
    quadratic; 8 is ample) keeps results deterministic and refactorization
    value-only.  All-zero rows/columns keep scale 1.0."""
    r = np.ones(n, dtype=np.float64)
    c = np.ones(n, dtype=np.float64)
    for _ in range(max(1, iters)):
        s = absv * r[rows] * c[cols]
        rmax = np.zeros(n, dtype=np.float64)
        np.maximum.at(rmax, rows, s)
        r /= np.sqrt(np.where(rmax > 0.0, rmax, 1.0))
        s = absv * r[rows] * c[cols]
        cmax = np.zeros(n, dtype=np.float64)
        np.maximum.at(cmax, cols, s)
        c /= np.sqrt(np.where(cmax > 0.0, cmax, 1.0))
    return r, c


@dataclasses.dataclass(frozen=True)
class RobustPlan:
    """The value-independent static-pivoting state stored on an ``LUPlan``
    (plain numpy arrays only — plans keep pickling).

    The factored system is ``A_f = Dr · P · A · Dc``: factored row j is
    original row ``perm[j]`` scaled by ``row_scale[j]``; column j is scaled
    by ``col_scale[j]``.  ``A x = b`` becomes ``A_f y = apply_rhs(b)`` with
    ``x = apply_solution(y)``.  ``value_map``/``value_scale`` replay the
    whole transform on a CSR value vector in O(nnz):
    ``A_f values[p] = values[value_map[p]] * value_scale[p]``.
    """

    perm: np.ndarray          # (n,) factored row j <- original row perm[j]
    row_scale: np.ndarray     # (n,) Dr, indexed by *factored* row
    col_scale: np.ndarray     # (n,) Dc, indexed by column
    value_map: np.ndarray     # (nnz,) factored CSR slot -> original CSR slot
    value_scale: np.ndarray   # (nnz,) Dr·Dc factor per factored slot

    @property
    def n(self) -> int:
        return len(self.perm)

    # -- value transform (the per-refactorization O(nnz) work) --------------
    def transform_values(self, values: np.ndarray) -> np.ndarray:
        """CSR values of A -> CSR values of A_f; ``values`` is (nnz,) or a
        batched (B, nnz) stack (the gather/scale broadcasts)."""
        values = np.asarray(values, dtype=np.float64)
        return values[..., self.value_map] * self.value_scale

    def transform_dense(self, dense: np.ndarray) -> np.ndarray:
        """Dense (n, n) values of A -> dense values of A_f."""
        dense = np.asarray(dense, dtype=np.float64)
        return (dense[self.perm] * self.row_scale[:, None]
                * self.col_scale[None, :])

    # -- solve-side transforms ----------------------------------------------
    def apply_rhs(self, b: np.ndarray) -> np.ndarray:
        """b of ``A x = b`` -> rhs of the factored system: Dr·P·b
        ((n,) or multi-RHS (n, k))."""
        b = np.asarray(b, dtype=np.float64)
        pb = b[self.perm]
        return (self.row_scale * pb if b.ndim == 1
                else self.row_scale[:, None] * pb)

    def apply_solution(self, y: np.ndarray) -> np.ndarray:
        """Solution y of the factored system -> x of ``A x = b``: Dc·y."""
        y = np.asarray(y, dtype=np.float64)
        return (self.col_scale * y if y.ndim == 1
                else self.col_scale[:, None] * y)

    def apply_rhs_batch(self, b: np.ndarray) -> np.ndarray:
        """``apply_rhs`` over a leading system axis: (B, n) or (B, n, k)."""
        b = np.asarray(b, dtype=np.float64)
        pb = b[:, self.perm]
        return (self.row_scale * pb if b.ndim == 2
                else self.row_scale[None, :, None] * pb)

    def apply_solution_batch(self, y: np.ndarray) -> np.ndarray:
        y = np.asarray(y, dtype=np.float64)
        return (self.col_scale * y if y.ndim == 2
                else self.col_scale[None, :, None] * y)


def build_robust_prepass(a: CSRMatrix, values: np.ndarray, *,
                         scale_iters: int = 8
                         ) -> Tuple[CSRMatrix, RobustPlan]:
    """The analyze-time static-pivoting pre-pass: returns the permuted
    structural matrix ``a_f`` (whose pattern the symbolic fixpoint runs on)
    and the ``RobustPlan`` that replays the transform per value set.

    ``values`` is the *representative* value set the permutation is chosen
    from — static pivoting's wager (HYLU, SuperLU_DIST) is that one
    matching serves a whole refactorization stream whose values drift but
    whose magnitude structure persists (Newton iterations, transient
    sweeps).  Tiny-pivot perturbation + iterative refinement absorb the
    drift; a fresh ``analyze`` re-picks the transversal when it does not.
    """
    rows, cols, absv = _entry_triplets(a, values)
    perm = max_product_transversal(a, values)
    inv = np.empty(a.n, dtype=np.int64)
    inv[perm] = np.arange(a.n, dtype=np.int64)
    new_rows = inv[rows]
    order = np.lexsort((cols, new_rows))
    indptr = np.zeros(a.n + 1, dtype=np.int64)
    np.add.at(indptr, new_rows + 1, 1)
    a_f = CSRMatrix(n=a.n, indptr=np.cumsum(indptr),
                    indices=cols[order].astype(np.int32))
    fr, fc = new_rows[order], cols[order]
    r, c = equilibrate(a.n, fr, fc, absv[order], iters=scale_iters)
    robust = RobustPlan(perm=perm, row_scale=r, col_scale=c,
                        value_map=order, value_scale=r[fr] * c[fc])
    return a_f, robust
