"""Cheap factorization-quality estimates: element growth, Hager 1-norm
condition, and a trust verdict (DESIGN.md §15).

A no-pivot (statically pivoted, possibly perturbed) factorization can
*complete* and still be garbage — the whole point of static pivoting is
trading the per-column pivot search for a post-hoc certificate.  This
module computes that certificate from quantities the packed factors
already hold:

* **Element growth** ``max|L\\U| / max|A_f|`` — the classic stability
  proxy (Wilkinson): large growth means elimination amplified roundoff and
  the backward error bound is weak.
* **Hager/Higham 1-norm condition estimate** — ``cond_1(A_f) ~
  ‖A_f‖₁ · est(‖A_f^{-1}‖₁)`` where the inverse norm comes from a few
  forward/transpose solves on the existing packed factors (each iterate is
  one ``solve_factored`` + one ``solve_factored_transposed``; never a
  dense inverse).  This is the LAPACK ``gecon`` algorithm, O(nnz) per
  iterate.
* **Verdict** — "ok" / "suspect" / "reject" from fixed thresholds, so
  serving-path callers (``repro.serve``) can gate answers without
  interpreting raw numbers.  The estimates describe the FACTORED system
  ``A_f = Dr·P·A·Dc`` — after equilibration that is exactly the system
  whose conditioning decides how much accuracy refinement can recover.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.numeric.solve import solve_factored, solve_factored_transposed
from repro.obs import metrics as _om
from repro.obs import trace as _ot

#: Verdict thresholds.  cond_1 beyond ~1e10 leaves <6 float64 digits for
#: refinement to work with ("suspect"); beyond ~1e14 essentially none
#: ("reject").  Growth mirrors the same margins on the Wilkinson proxy.
COND_SUSPECT = 1e10
COND_REJECT = 1e14
GROWTH_SUSPECT = 1e6
GROWTH_REJECT = 1e10


@dataclasses.dataclass(frozen=True)
class QualityReport:
    """Trust certificate of one factorization (``LUFactorization.quality()``).

    ``verdict`` is "ok", "suspect" (perturbed pivots or moderate
    growth/conditioning — check the achieved residual before trusting), or
    "reject" (non-finite or hopeless conditioning — the solve should not be
    trusted even if it returns numbers).
    """

    growth: float              # max|L\U| / max|A_f| element growth
    cond_1_est: float          # Hager estimate of cond_1(A_f)
    norm1_a: float             # ‖A_f‖₁ (exact, from the factored values)
    perturbed_pivots: int      # tiny pivots bumped during the sweep
    verdict: str               # "ok" | "suspect" | "reject"

    @property
    def ok(self) -> bool:
        return self.verdict == "ok"


def _verdict(growth: float, cond: float, perturbed: int) -> str:
    if (not np.isfinite(growth) or not np.isfinite(cond)
            or cond > COND_REJECT or growth > GROWTH_REJECT):
        return "reject"
    if perturbed > 0 or cond > COND_SUSPECT or growth > GROWTH_SUSPECT:
        return "suspect"
    return "ok"


def condest_1(num, norm1_a: float, *, itmax: int = 5) -> float:
    """Hager/Higham estimate of ``cond_1`` of the factored matrix:
    ``norm1_a * est(‖A_f^{-1}‖₁)`` via at most ``itmax`` rounds of one
    factored solve + one transposed solve each (the gecon iteration).
    The estimate is a lower bound, in practice within a small factor of
    the true norm."""
    n = num.n
    if n == 0:
        return 0.0
    x = np.full(n, 1.0 / n)
    est = 0.0
    last_j = -1
    for _ in range(max(1, itmax)):
        y = solve_factored(num, x, batched=False)
        est = float(np.abs(y).sum())
        if not np.isfinite(est):
            return np.inf
        xi = np.where(y >= 0.0, 1.0, -1.0)
        z = solve_factored_transposed(num, xi)
        j = int(np.argmax(np.abs(z)))
        if float(np.abs(z[j])) <= float(z @ x) or j == last_j:
            break
        x = np.zeros(n)
        x[j] = 1.0
        last_j = j
    return est * norm1_a


def element_growth(num, factored_scale: float) -> float:
    """``max|L\\U| / max|A_f|`` over the packed blocks (padding is zeroed
    by the sweep, so the block max IS the factor max)."""
    gmax = 0.0
    for blk in num.store.blocks:
        if blk.size:
            m = float(np.abs(blk).max())
            if not np.isfinite(m):
                return np.inf
            gmax = max(gmax, m)
    return gmax / factored_scale if factored_scale > 0.0 else 0.0


def norm1_csr(a, factored_values: np.ndarray) -> float:
    """Exact ‖A_f‖₁ (max column abs-sum) from CSR-aligned values, O(nnz)."""
    sums = np.zeros(a.n, dtype=np.float64)
    np.add.at(sums, a.indices.astype(np.int64), np.abs(factored_values))
    return float(sums.max()) if a.n else 0.0


def estimate_quality(num, a_f, factored_values: np.ndarray, *,
                     perturbed_pivots: int = 0,
                     itmax: int = 5) -> QualityReport:
    """Compute the full certificate for one factorization.

    ``num``: the ``NumericResult`` holding the packed factors;
    ``a_f``/``factored_values``: the structural matrix and CSR-aligned
    values that were factored (the transformed system when static pivoting
    is on, the original otherwise).
    """
    with _ot.span("robust_quality"):
        values = np.asarray(factored_values, dtype=np.float64)
        if values.ndim == 2:
            norm1 = float(np.abs(values).sum(axis=0).max()) if values.size \
                else 0.0
            scale = float(np.abs(values).max()) if values.size else 0.0
        else:
            norm1 = norm1_csr(a_f, values)
            scale = float(np.abs(values).max()) if values.size else 0.0
        growth = element_growth(num, scale)
        cond = condest_1(num, norm1, itmax=itmax)
        report = QualityReport(growth=growth, cond_1_est=cond, norm1_a=norm1,
                               perturbed_pivots=int(perturbed_pivots),
                               verdict=_verdict(growth, cond,
                                                int(perturbed_pivots)))
        if _ot.ENABLED:
            reg = _om.registry()
            reg.gauge("robust.growth", growth if np.isfinite(growth) else -1.0)
            reg.gauge("robust.cond_estimate",
                      cond if np.isfinite(cond) else -1.0)
    return report
