"""Mixture-of-Experts FFN: top-k router + capacity-based sort dispatch.

TPU-native expert parallelism: tokens are dispatched into a dense
(E, C, d) buffer (C = capacity per expert) via a sort-based position
assignment, the expert SwiGLUs run as one batched einsum with the expert
axis sharded over the ``model`` mesh axis (EP), and results are combined
with the router weights.  Overflowed tokens (position >= C) are dropped —
the GShard/Switch convention; the drop fraction is returned as a metric.

Shared (always-on) experts are fused into a single dense SwiGLU of width
``n_shared * d_expert`` — numerically identical to summing the shared
experts and one matmul instead of n_shared.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, init_mlp, mlp
from repro.train.sharding import constrain


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s_in, s_out = d ** -0.5, m.d_expert ** -0.5
    p = {
        "router": _normal(k1, (d, m.n_experts), s_in, jnp.float32),
        "w_gate": _normal(k2, (m.n_experts, d, m.d_expert), s_in, dtype),
        "w_up": _normal(k3, (m.n_experts, d, m.d_expert), s_in, dtype),
        "w_down": _normal(k4, (m.n_experts, m.d_expert, d), s_out, dtype),
    }
    if m.n_shared:
        p["shared"] = init_mlp(k5, d, m.n_shared * m.d_expert, dtype)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * n_tokens * m.top_k / m.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 (sublane alignment)


def _dispatch_row(xf: jax.Array, logits: jax.Array, cap: int, m) -> Tuple:
    """Per-row dispatch: (S, d) tokens into an (E, C, d) capacity buffer.

    Position-in-expert is each (token, slot) pair's rank among same-expert
    pairs, from one stable argsort over the row's assignments — the TPU
    analogue of the atomic queue append a GPU implementation would use.
    Row-local dispatch (vs a global sort) is what keeps every tensor here
    batch-sharded: a global sort would force XLA to all-gather the token
    activations of the whole batch onto every device.
    """
    s, d = xf.shape
    k = m.top_k
    gate_logits, expert_idx = jax.lax.top_k(logits, k)          # (S, k)
    gates = jax.nn.softmax(gate_logits, axis=-1).astype(xf.dtype)

    flat_e = expert_idx.reshape(-1)                             # (S*k,)
    sort_i = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_i]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(m.n_experts, dtype=flat_e.dtype))
    pos_sorted = (jnp.arange(s * k, dtype=jnp.int32)
                  - seg_start[sorted_e].astype(jnp.int32))
    pos = jnp.zeros((s * k,), jnp.int32).at[sort_i].set(pos_sorted)

    keep = pos < cap
    # dropped pairs go to a dump expert row E (sliced off before compute)
    e_safe = jnp.where(keep, flat_e, m.n_experts).astype(jnp.int32)
    p_safe = jnp.where(keep, pos, 0)
    tok_of_pair = jnp.arange(s * k, dtype=jnp.int32) // k

    disp = jnp.zeros((m.n_experts + 1, cap, d), xf.dtype)
    disp = disp.at[e_safe, p_safe].set(xf[tok_of_pair])
    return disp[: m.n_experts], (e_safe, p_safe, gates, keep)


def _combine_row(h_out: jax.Array, meta, k: int) -> jax.Array:
    e_safe, p_safe, gates, keep = meta
    cap, d = h_out.shape[1], h_out.shape[2]
    h_pad = jnp.concatenate([h_out, jnp.zeros((1, cap, d), h_out.dtype)], axis=0)
    per_pair = h_pad[e_safe, p_safe]                             # (S*k, d)
    w = (gates.reshape(-1) * keep.astype(h_out.dtype))[:, None]
    return jnp.sum((per_pair * w).reshape(-1, k, d), axis=1)     # (S, d)


def moe_forward(params: Dict, x: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, Dict]:
    """x: (B, S, d) -> (B, S, d), metrics.

    Dispatch is row-local (capacity budgeted per sequence), so the dispatch
    buffer is (B, E, C, d) with B sharded over the batch axes and E over
    'model' (EP); the expert einsum is then collective-free — the router
    never moves activations across data shards.
    """
    m = cfg.moe
    b, s, d = x.shape
    cap = _capacity(s, cfg)

    logits = x.astype(jnp.float32) @ params["router"]            # (B, S, E)
    disp, meta = jax.vmap(
        lambda xr, lr: _dispatch_row(xr, lr, cap, m))(x, logits)
    disp = constrain(disp, ("batch", "model", None, None))       # (B, E, C, d)

    # --- expert SwiGLU, expert axis sharded over 'model' (EP) ---
    h_gate = jax.nn.silu(jnp.einsum("becd,edf->becf", disp, params["w_gate"]))
    h_up = jnp.einsum("becd,edf->becf", disp, params["w_up"])
    h_out = jnp.einsum("becf,efd->becd", h_gate * h_up, params["w_down"])
    h_out = constrain(h_out, ("batch", "model", None, None))

    y = jax.vmap(lambda h, mt: _combine_row(h, mt, m.top_k))(h_out, meta)

    if m.n_shared:
        y = y + mlp(params["shared"], x.reshape(b * s, d)).reshape(b, s, d)

    # load-balance metrics (Switch aux loss + drop fraction)
    _, expert_idx = jax.lax.top_k(logits, m.top_k)
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx, m.n_experts, dtype=jnp.float32).sum(2),
        axis=(0, 1)) / m.top_k
    frac_probs = jnp.mean(probs, axis=(0, 1))
    keep_frac = jnp.mean(meta[3].astype(jnp.float32))
    metrics = {
        "moe_aux_loss": m.n_experts * jnp.sum(frac_tokens * frac_probs),
        "moe_drop_frac": 1.0 - keep_frac,
    }
    return y, metrics
