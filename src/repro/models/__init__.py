"""LM substrate: composable model definitions for the ten assigned families."""
