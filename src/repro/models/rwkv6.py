"""RWKV6 ("Finch") time-mix: linear attention with data-dependent decay.

Per head h with head size K: state S in R^{K x K} evolves per token

    S_t = diag(w_t) S_t-1 + k_t^T v_t          (w_t in (0,1)^K, data-dependent)
    o_t = r_t (diag(u) k_t^T v_t + S_t-1)      (u = per-head "bonus" on the
                                                current token)

All projections (r, k, v, g, the decay LoRA and the output) are computed for
the whole sequence as batched matmuls — the dominant FLOPs stay on the MXU —
and only the elementwise state recurrence runs under ``lax.scan``.  On real
TPU the recurrence is the memory-latency hot spot; ``kernels/ssm_scan.py``
holds the VMEM-resident Pallas kernel for it (the model uses the jnp scan,
which is also the kernel's oracle).

Deviations noted in DESIGN.md §8: the channel-mix FFN is the framework's
SwiGLU (same FLOP structure), and the per-head GroupNorm on the output is an
RMSNorm per head.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, init_rmsnorm, rmsnorm

_DECAY_LORA = 64


def init_rwkv6(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    hs = cfg.ssm.head_size
    n_heads = d // hs
    keys = jax.random.split(key, 9)
    s = d ** -0.5
    return {
        "wr": _normal(keys[0], (d, d), s, dtype),
        "wk": _normal(keys[1], (d, d), s, dtype),
        "wv": _normal(keys[2], (d, d), s, dtype),
        "wg": _normal(keys[3], (d, d), s, dtype),
        "wo": _normal(keys[4], (d, d), s, dtype),
        # data-dependent decay: w_t = exp(-exp(base + lora(x_t)))
        "w_base": jnp.zeros((d,), jnp.float32) - 0.6,
        "w_lora_a": _normal(keys[5], (d, _DECAY_LORA), s, dtype),
        "w_lora_b": _normal(keys[6], (_DECAY_LORA, d), _DECAY_LORA ** -0.5, dtype),
        "u": _normal(keys[7], (n_heads, hs), 0.5, jnp.float32),
        # token-shift mixing coefficients for (r, k, v, g, w)
        "mix": _normal(keys[8], (5, d), 0.1, jnp.float32),
        "o_norm": init_rmsnorm(hs, dtype),
    }


def _projections(params: Dict, x: jax.Array, x_prev: jax.Array, cfg: ModelConfig):
    """Token-shifted projections for the whole sequence (batched matmuls).

    x: (B, L, d); x_prev: (B, d) = last hidden of the previous segment
    (zeros at sequence start).  Returns per-head r, k, v, g (B, L, H, K) and
    decay w (B, L, H, K) in (0, 1).
    """
    b, l, d = x.shape
    hs = cfg.ssm.head_size
    h = d // hs
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    mix = params["mix"].astype(x.dtype)                          # (5, d)
    xs = x[None] * (1 - mix[:, None, None, :]) + shifted[None] * mix[:, None, None, :]
    xr, xk, xv, xg, xw = xs                                      # each (B, L, d)
    r = (xr @ params["wr"]).reshape(b, l, h, hs)
    k = (xk @ params["wk"]).reshape(b, l, h, hs)
    v = (xv @ params["wv"]).reshape(b, l, h, hs)
    g = (xg @ params["wg"]).reshape(b, l, h, hs)
    w_log = params["w_base"].astype(jnp.float32) + (
        (xw @ params["w_lora_a"]) @ params["w_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(b, l, h, hs)            # (0, 1)
    return r, k, v, g, w


def _recurrence(r, k, v, w, u, state):
    """lax.scan over time of the elementwise state update.

    r/k/v/w: (B, L, H, K); u: (H, K); state: (B, H, K, K) keyed [key, value].
    Returns o: (B, L, H, K) and the final state.
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                                 # (B, H, K)
        kv = k_t[..., :, None] * v_t[..., None, :]               # (B, H, K, K)
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = s * w_t[..., :, None] + kv
        return s, o_t

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))     # (L, B, H, K)
    state, o = jax.lax.scan(step, state, seq)
    return jnp.moveaxis(o, 0, 1), state


def init_rwkv6_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    hs = cfg.ssm.head_size
    return {
        "s": jnp.zeros((batch, d // hs, hs, hs), jnp.float32),
        "x_prev": jnp.zeros((batch, d), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def rwkv6_forward(params: Dict, x: jax.Array, cfg: ModelConfig,
                  state: Dict | None = None) -> Tuple[jax.Array, Dict]:
    """Full-sequence (train / prefill) time-mix. Returns (out, final_state)."""
    b, l, d = x.shape
    if state is None:
        state = init_rwkv6_state(cfg, b, x.dtype)
    r, k, v, g, w = _projections(params, x, state["x_prev"], cfg)
    u = params["u"].astype(jnp.float32)
    o, s_new = _recurrence(r.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), w, u, state["s"])
    o = rmsnorm(params["o_norm"], o.astype(x.dtype), cfg.norm_eps)
    o = (o * jax.nn.silu(g)).reshape(b, l, d)
    new_state = {"s": s_new, "x_prev": x[:, -1, :], "idx": state["idx"] + l}
    return o @ params["wo"], new_state


def rwkv6_decode(params: Dict, x: jax.Array, state: Dict, cfg: ModelConfig
                 ) -> Tuple[jax.Array, Dict]:
    """One-token decode: identical math at L=1 (O(1) state — no KV cache)."""
    return rwkv6_forward(params, x, cfg, state)
