"""Mamba (S6) selective state-space mixer, as used by Jamba's SSM layers.

    h_t = exp(dt_t A) .  h_t-1 + (dt_t x_t) outer B_t
    y_t = h_t . C_t + D x_t

with A (di, N) diagonal-negative, dt/B/C data-dependent.  As in rwkv6.py, all
projections and the depthwise conv run as full-sequence batched ops (MXU
work); only the elementwise recurrence runs under ``lax.scan`` (the Pallas
kernel in kernels/ssm_scan.py is the TPU-resident version; the scan here is
its oracle).  The depthwise causal conv (d_conv taps) is computed as a sum of
shifted scaled copies — exact and layout-friendly.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _normal

_DT_RANK_DIV = 16   # dt_rank = d_model / 16 (mamba default ~ d/16)


def _dims(cfg: ModelConfig):
    di = cfg.ssm.expand * cfg.d_model
    dt_rank = max(1, cfg.d_model // _DT_RANK_DIV)
    return di, dt_rank, cfg.ssm.d_state, cfg.ssm.d_conv


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    di, dt_rank, n, d_conv = _dims(cfg)
    keys = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_in": _normal(keys[0], (d, 2 * di), s, dtype),            # x, z
        "conv_w": _normal(keys[1], (d_conv, di), 0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_xproj": _normal(keys[2], (di, dt_rank + 2 * n), di ** -0.5, dtype),
        "w_dt": _normal(keys[3], (dt_rank, di), dt_rank ** -0.5, dtype),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),              # softplus ~ 0.01
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, n)).copy()),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": _normal(keys[4], (di, d), di ** -0.5, dtype),
    }


def _conv_causal(x: jax.Array, conv_state: jax.Array, w: jax.Array,
                 b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time as shifted adds.

    x: (B, L, di); conv_state: (B, d_conv-1, di) = trailing inputs of the
    previous segment.  Returns (y, new_conv_state).
    """
    d_conv = w.shape[0]
    ext = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # (B, L+dc-1, di)
    l = x.shape[1]
    y = jnp.zeros_like(x)
    for i in range(d_conv):
        # tap i multiplies input at offset (t - (d_conv-1-i))
        y = y + ext[:, i:i + l, :] * w[i][None, None, :]
    new_state = ext[:, -(d_conv - 1):, :] if d_conv > 1 else conv_state
    return y + b[None, None, :], new_state


def _selective_scan(xc, dt, b_t, c_t, a, d_skip, h0):
    """The S6 recurrence under lax.scan.

    xc/dt: (B, L, di); b_t/c_t: (B, L, N); a: (di, N); h0: (B, di, N).
    Returns y (B, L, di), h_final.
    """
    def step(h, inp):
        x_t, dt_t, bb, cc = inp                  # (B, di), (B, di), (B, N), (B, N)
        decay = jnp.exp(dt_t[..., None] * a[None])           # (B, di, N)
        h = h * decay + (dt_t * x_t)[..., None] * bb[:, None, :]
        y_t = jnp.einsum("bdn,bn->bd", h, cc) + d_skip[None] * x_t
        return h, y_t

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in (xc, dt, b_t, c_t))
    h, y = jax.lax.scan(step, h0, seq)
    return jnp.moveaxis(y, 0, 1), h


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    di, _, n, d_conv = _dims(cfg)
    return {
        "h": jnp.zeros((batch, di, n), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, di), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def mamba_forward(params: Dict, x: jax.Array, cfg: ModelConfig,
                  state: Dict | None = None) -> Tuple[jax.Array, Dict]:
    """x: (B, L, d) -> (B, L, d). Works for train (L=seq), prefill, decode (L=1)."""
    b, l, d = x.shape
    di, dt_rank, n, _ = _dims(cfg)
    if state is None:
        state = init_mamba_state(cfg, b, x.dtype)

    xz = x @ params["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                            # (B, L, di) each
    xc, conv_new = _conv_causal(xi, state["conv"], params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)

    proj = xc @ params["w_xproj"]                                # (B, L, r+2N)
    dt_raw, b_t, c_t = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_raw @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])                                # (di, N), negative

    y, h_new = _selective_scan(
        xc.astype(jnp.float32), dt, b_t.astype(jnp.float32),
        c_t.astype(jnp.float32), a, params["d_skip"], state["h"])
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["w_out"]
    new_state = {"h": h_new, "conv": conv_new, "idx": state["idx"] + l}
    return y, new_state


def mamba_decode(params: Dict, x: jax.Array, state: Dict, cfg: ModelConfig
                 ) -> Tuple[jax.Array, Dict]:
    return mamba_forward(params, x, cfg, state)
