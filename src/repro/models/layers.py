"""Primitive layers: RMSNorm, RoPE, SwiGLU MLP, embeddings.

Pure-functional: params are nested dicts, init_* build them, apply functions
are free of global state.  Compute runs in the activation dtype; norms
accumulate in float32.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, D) with even D; positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, d_ff ** -0.5
    return {
        "w_gate": _normal(k1, (d, d_ff), s_in, dtype),
        "w_up": _normal(k2, (d, d_ff), s_in, dtype),
        "w_down": _normal(k3, (d_ff, d), s_out, dtype),
    }


def mlp(params: Dict, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(x @ params["w_gate"])
    return (gate * (x @ params["w_up"])) @ params["w_down"]


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Dict:
    return {"table": _normal(key, (vocab, d), d ** -0.5, dtype)}


def embed(params: Dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: Dict, x: jax.Array) -> jax.Array:
    """Logits in float32 (loss stability)."""
    return x.astype(jnp.float32) @ params["table"].astype(jnp.float32).T
