"""Attention mixers: GQA (global / sliding-window / cross) and DeepSeek MLA,
with KV caches for decode.

Cache contract (decode): every mixer owns a dict of fixed-shape arrays plus an
``idx`` scalar; ``*_decode`` writes the new token at ``idx`` and attends over
the valid prefix.  Sliding-window layers keep a ring buffer of ``window``
entries with explicit positions (so long_500k only caches 1k per local layer).
MLA caches the *compressed* latent (kv_lora + rope dims), which is the whole
point of MLA at 32k+ contexts.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _normal, apply_rope, init_rmsnorm, rmsnorm
from repro.train.sharding import constrain

NEG = -1e30

# decode-time cache layout: batch first, then give the sequence dim whatever
# axes remain (matches train/sharding.cache_pspec) — attention then computes
# T-locally (partial softmax + tiny all-reduces) instead of resharding the
# cache to a head-sharded layout every token
_CACHE_KV_PREFS = ("batch", None, [("data", "model"), ("data",), ("model",)],
                   None)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, hd = cfg.d_model, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    wq = _normal(k1, (d, cfg.n_heads * hd), s, dtype)
    wo = _normal(k4, (cfg.n_heads * hd, d), (cfg.n_heads * hd) ** -0.5, dtype)
    if cfg.hp != cfg.n_heads:
        # TP-friendly head padding: zero column/row blocks for the padded
        # heads — their output contribution is exactly zero, but every
        # (B, H, S, hd) tensor becomes divisible by the model axis
        pad = (cfg.hp - cfg.n_heads) * hd
        wq = jnp.pad(wq, ((0, 0), (0, pad)))
        wo = jnp.pad(wo, ((0, pad), (0, 0)))
    p = {
        "wq": wq,
        "wk": _normal(k2, (d, cfg.n_kv_heads * hd), s, dtype),
        "wv": _normal(k3, (d, cfg.n_kv_heads * hd), s, dtype),
        "wo": wo,
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _pad_heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Pad repeated K/V (B, n_heads, S, hd) up to (B, hp, S, hd) with zeros."""
    if cfg.hp == cfg.n_heads:
        return x
    return jnp.pad(x, ((0, 0), (0, cfg.hp - cfg.n_heads), (0, 0), (0, 0)))


def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)   # (B, H, S, hd)


def _merge_heads(x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def _repeat_kv(x, n_rep):
    if n_rep == 1:
        return x
    return jnp.repeat(x, n_rep, axis=1)


def _sdpa(q, k, v, mask, scale) -> jax.Array:
    """(B,H,S,hd) x (B,H,T,hd) -> (B,H,S,hd); float32 softmax."""
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask, logits, NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v)


def _causal_mask(s: int, t: int, window: Optional[int] = None) -> jax.Array:
    q_ids = jnp.arange(s)[:, None] + (t - s)
    k_ids = jnp.arange(t)[None, :]
    mask = k_ids <= q_ids
    if window is not None:
        mask &= k_ids > q_ids - window
    return mask[None, None]


def _sdpa_chunked(q, k, v, scale, *, window: Optional[int] = None,
                  q_chunk: Optional[int] = None, unroll: bool = False,
                  causal: bool = True, seq_shard: bool = False) -> jax.Array:
    """Query-chunked SDPA: bounds the logits working set to (B, H, Cq, T).

    This is the jnp analogue of the flash kernel's outer loop (the kernel in
    kernels/flash_attention.py additionally streams K/V tiles through VMEM);
    at 32k+ sequer lengths the full (S, T) score matrix cannot be
    materialized.  ``unroll`` is used by the dry-run cost extraction so every
    chunk's FLOPs are visible to cost_analysis (scan bodies count once).
    """
    b, h, s, hd = q.shape
    t = k.shape[2]
    if q_chunk is None or s <= q_chunk or s % q_chunk:
        # no chunking (or non-divisible length, e.g. whisper's 1500-frame
        # encoder): one-shot SDPA
        mask = _causal_mask(s, t, window) if causal else jnp.ones((1, 1, s, t), bool)
        return _sdpa(q, k, v, mask, scale)
    k_ids = jnp.arange(t)[None, :]

    def body(_, i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=2)
        if seq_shard:
            # shard the query-chunk rows over 'model': the score/value
            # matmuls then split 16-way even when n_heads % tp != 0
            # (K/V stay as-is; only this chunk's rows partition)
            qs = constrain(qs, ("batch", None, ("model",), None))
        q_ids = i * q_chunk + jnp.arange(q_chunk)[:, None] + (t - s)
        mask = (k_ids <= q_ids) if causal else jnp.ones((q_chunk, t), bool)
        if causal and window is not None:
            mask &= k_ids > q_ids - window
        return None, _sdpa(qs, k, v, mask[None, None], scale)

    # checkpoint per chunk: without it, scan's backward stacks every chunk's
    # (B, H, Cq, T) probs — the full S x T score matrix we chunked to avoid.
    _, outs = jax.lax.scan(jax.checkpoint(body), None,
                           jnp.arange(s // q_chunk, dtype=jnp.int32),
                           unroll=unroll)
    return jnp.moveaxis(outs, 0, 2).reshape(b, h, s, hd)


def gqa_forward(params: Dict, x: jax.Array, cfg: ModelConfig, *,
                window: Optional[int] = None,
                positions: Optional[jax.Array] = None,
                q_chunk: Optional[int] = None, unroll: bool = False,
                causal: bool = True, return_kv: bool = False):
    """Full-sequence (train / prefill) GQA with optional sliding window.

    ``return_kv`` additionally returns the (pre-repeat) rotated K and V for
    prefill cache construction.
    """
    b, s, _ = x.shape
    hd = cfg.hd
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q = _split_heads(x @ params["wq"], cfg.hp, hd)
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "model", None, None))
    kr = _pad_heads(_repeat_kv(k, cfg.n_heads // cfg.n_kv_heads), cfg)
    vr = _pad_heads(_repeat_kv(v, cfg.n_heads // cfg.n_kv_heads), cfg)
    kr = constrain(kr, ("batch", "model", None, None))
    vr = constrain(vr, ("batch", "model", None, None))
    out = _sdpa_chunked(q, kr, vr, hd ** -0.5, window=window,
                        q_chunk=q_chunk, unroll=unroll, causal=causal,
                        seq_shard=cfg.seq_shard_attention)
    y = _merge_heads(out) @ params["wo"]
    if return_kv:
        return y, (k, v)
    return y


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                   window: Optional[int] = None, dtype=jnp.float32) -> Dict:
    t = min(max_len, window) if window else max_len
    shape = (batch, cfg.n_kv_heads, t, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.full((batch, t), -1, jnp.int32),   # absolute position per slot
        "idx": jnp.zeros((), jnp.int32),
    }


def gqa_decode(params: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig, *,
               window: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    """One-token decode. x: (B, 1, d).  Ring-buffered when ``window`` is set."""
    b = x.shape[0]
    hd = cfg.hd
    idx = cache["idx"]                                # tokens generated so far
    pos = jnp.broadcast_to(idx[None, None], (b, 1)).astype(jnp.int32)
    q = _split_heads(x @ params["wq"], cfg.hp, hd)
    k = _split_heads(x @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(x @ params["wv"], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    t = cache["k"].shape[2]
    slot = idx % t if window else idx                 # ring buffer for local layers
    if t >= 65536:
        # long caches are sequence-sharded (train/sharding.py); a
        # dynamic-update-slice on the sharded dim makes GSPMD all-gather the
        # whole cache per token — the one-hot masked update is elementwise
        # and sharding-preserving (EXPERIMENTS.md §Perf, hillclimb #6)
        hit = jnp.arange(t, dtype=jnp.int32) == slot                # (t,)
        k_all = jnp.where(hit[None, None, :, None], k[:, :, 0][:, :, None],
                          cache["k"])
        v_all = jnp.where(hit[None, None, :, None], v[:, :, 0][:, :, None],
                          cache["v"])
        pos_all = jnp.where(hit[None, :], pos[:, 0][:, None], cache["pos"])
    else:
        k_all = cache["k"].at[:, :, slot].set(k[:, :, 0])
        v_all = cache["v"].at[:, :, slot].set(v[:, :, 0])
        pos_all = cache["pos"].at[:, slot].set(pos[:, 0])

    k_all = constrain(k_all, _CACHE_KV_PREFS)
    v_all = constrain(v_all, _CACHE_KV_PREFS)
    kr = _pad_heads(_repeat_kv(k_all, cfg.n_heads // cfg.n_kv_heads), cfg)
    vr = _pad_heads(_repeat_kv(v_all, cfg.n_heads // cfg.n_kv_heads), cfg)
    kr = constrain(kr, _CACHE_KV_PREFS)
    vr = constrain(vr, _CACHE_KV_PREFS)
    valid = (pos_all >= 0) & (pos_all <= idx)
    if window:
        valid &= pos_all > idx - window
    mask = valid[:, None, None, :]                    # (B,1,1,T)
    out = _sdpa(q, kr, vr, mask, hd ** -0.5)
    new_cache = {"k": k_all, "v": v_all, "pos": pos_all, "idx": idx + 1}
    return _merge_heads(out) @ params["wo"], new_cache


def fill_gqa_cache(cache: Dict, k: jax.Array, v: jax.Array,
                   window: Optional[int] = None) -> Dict:
    """Write a prefill segment (rotated K/V, (B, Hkv, S, hd)) into a fresh
    cache.  Sliding-window caches keep the last ``t`` positions in ring
    layout (slot = pos % t), matching gqa_decode's write pattern."""
    b, hkv, s, hd = k.shape
    t = cache["k"].shape[2]
    if s >= t:
        pos = jnp.arange(s - t, s, dtype=jnp.int32)
        k, v = k[:, :, -t:], v[:, :, -t:]
    else:
        pos = jnp.arange(s, dtype=jnp.int32)
    slots = pos % t if window else pos
    k_all = cache["k"].at[:, :, slots].set(k)
    v_all = cache["v"].at[:, :, slots].set(v)
    pos_all = cache["pos"].at[:, slots].set(jnp.broadcast_to(pos, (b, pos.shape[0])))
    return {"k": k_all, "v": v_all, "pos": pos_all, "idx": jnp.int32(s)}


def fill_mla_cache(cache: Dict, c_kv: jax.Array, k_rope: jax.Array) -> Dict:
    """c_kv: (B, S, r); k_rope: (B, 1, S, rd)."""
    s = c_kv.shape[1]
    return {
        "ckv": jax.lax.dynamic_update_slice_in_dim(cache["ckv"], c_kv, 0, axis=1),
        "krope": jax.lax.dynamic_update_slice_in_dim(cache["krope"], k_rope, 0, axis=2),
        "idx": jnp.int32(s),
    }


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def init_cross(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    return init_gqa(key, cfg, dtype)


def make_cross_cache(params: Dict, enc: jax.Array, cfg: ModelConfig) -> Dict:
    """Precompute encoder K/V once per request (reused every decode step)."""
    k = _split_heads(enc @ params["wk"], cfg.n_kv_heads, cfg.hd)
    v = _split_heads(enc @ params["wv"], cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    return {"k": k, "v": v}


def cross_decode(params: Dict, x: jax.Array, cross_cache: Dict,
                 cfg: ModelConfig) -> jax.Array:
    """x: (B, 1, d) decoder state; attends over the cached encoder K/V."""
    hd = cfg.hd
    q = _split_heads(x @ params["wq"], cfg.hp, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
    k = _pad_heads(_repeat_kv(cross_cache["k"], cfg.n_heads // cfg.n_kv_heads), cfg)
    v = _pad_heads(_repeat_kv(cross_cache["v"], cfg.n_heads // cfg.n_kv_heads), cfg)
    mask = jnp.ones((1, 1, 1, k.shape[2]), bool)
    out = _sdpa(q, k, v, mask, hd ** -0.5)
    return _merge_heads(out) @ params["wo"]


def cross_forward(params: Dict, x: jax.Array, enc: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d) decoder states; enc: (B, T, d) encoder output (no mask)."""
    hd = cfg.hd
    q = _split_heads(x @ params["wq"], cfg.hp, hd)
    k = _split_heads(enc @ params["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(enc @ params["wv"], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    k = _pad_heads(_repeat_kv(k, cfg.n_heads // cfg.n_kv_heads), cfg)
    v = _pad_heads(_repeat_kv(v, cfg.n_heads // cfg.n_kv_heads), cfg)
    mask = jnp.ones((1, 1, x.shape[1], enc.shape[1]), bool)
    out = _sdpa(q, k, v, mask, hd ** -0.5)
    return _merge_heads(out) @ params["wo"]


# ---------------------------------------------------------------------------
# DeepSeek MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    keys = jax.random.split(key, 6)
    s = d ** -0.5
    qd = m.nope_head_dim + m.rope_head_dim
    return {
        "wq_a": _normal(keys[0], (d, m.q_lora_rank), s, dtype),
        "q_norm": init_rmsnorm(m.q_lora_rank, dtype),
        "wq_b": _normal(keys[1], (m.q_lora_rank, h * qd), m.q_lora_rank ** -0.5, dtype),
        "wkv_a": _normal(keys[2], (d, m.kv_lora_rank + m.rope_head_dim), s, dtype),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dtype),
        "wkv_b": _normal(keys[3],
                         (m.kv_lora_rank, h * (m.nope_head_dim + m.v_head_dim)),
                         m.kv_lora_rank ** -0.5, dtype),
        "wo": _normal(keys[4], (h * m.v_head_dim, d),
                      (h * m.v_head_dim) ** -0.5, dtype),
    }


def _mla_qkv(params, x, cfg, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q = rmsnorm(params["q_norm"], x @ params["wq_a"], cfg.norm_eps) @ params["wq_b"]
    q = q.reshape(b, s, h, m.nope_head_dim + m.rope_head_dim).transpose(0, 2, 1, 3)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ params["wkv_a"]
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)  # (B,1,S,rd)
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(params, q_nope, q_rope, c_kv, k_rope, cfg, mask):
    m = cfg.mla
    h = cfg.n_heads
    b, t = c_kv.shape[0], c_kv.shape[1]
    kvb = params["wkv_b"].reshape(m.kv_lora_rank, h, m.nope_head_dim + m.v_head_dim)
    k_nope_w = kvb[:, :, : m.nope_head_dim]            # (r, h, nope)
    v_w = kvb[:, :, m.nope_head_dim:]                  # (r, h, vdim)
    # absorb k projection into q (the MLA trick: attend in latent space)
    q_lat = jnp.einsum("bhsn,rhn->bhsr", q_nope, k_nope_w)
    logits = jnp.einsum("bhsr,btr->bhst", q_lat, c_kv,
                        preferred_element_type=jnp.float32)
    logits += jnp.einsum("bhsd,bhtd->bhst", q_rope,
                         jnp.broadcast_to(k_rope, (b, 1, t, m.rope_head_dim)),
                         preferred_element_type=jnp.float32)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    logits = jnp.where(mask, logits * scale, NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(c_kv.dtype)
    out_lat = jnp.einsum("bhst,btr->bhsr", probs, c_kv)
    out = jnp.einsum("bhsr,rhv->bhsv", out_lat, v_w)
    return _merge_heads(out) @ params["wo"]


def mla_forward(params: Dict, x: jax.Array, cfg: ModelConfig, *,
                positions: Optional[jax.Array] = None,
                q_chunk: Optional[int] = None, unroll: bool = False,
                return_latent: bool = False):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, cfg, positions)
    if q_chunk is None or s <= q_chunk:
        out = _mla_attend(params, q_nope, q_rope, c_kv, k_rope, cfg,
                          _causal_mask(s, s))
    else:
        assert s % q_chunk == 0, (s, q_chunk)
        k_ids = jnp.arange(s)[None, :]

        def body(_, i):
            qn = jax.lax.dynamic_slice_in_dim(q_nope, i * q_chunk, q_chunk, axis=2)
            qr = jax.lax.dynamic_slice_in_dim(q_rope, i * q_chunk, q_chunk, axis=2)
            q_ids = i * q_chunk + jnp.arange(q_chunk)[:, None]
            mask = (k_ids <= q_ids)[None, None]
            return None, _mla_attend(params, qn, qr, c_kv, k_rope, cfg, mask)

        _, outs = jax.lax.scan(jax.checkpoint(body), None,
                               jnp.arange(s // q_chunk, dtype=jnp.int32),
                               unroll=unroll)
        # outs: (nc, B, S_c, d) -> (B, S, d)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, -1)
    if return_latent:
        return out, (c_kv, k_rope)
    return out


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.float32) -> Dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, 1, max_len, m.rope_head_dim), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def mla_decode(params: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig
               ) -> Tuple[jax.Array, Dict]:
    b = x.shape[0]
    idx = cache["idx"]
    pos = jnp.broadcast_to(idx[None, None], (b, 1)).astype(jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, cfg, pos)
    t = cache["ckv"].shape[1]
    if t >= 65536:
        hit = jnp.arange(t, dtype=jnp.int32) == idx
        ckv_all = jnp.where(hit[None, :, None], c_kv, cache["ckv"])
        krope_all = jnp.where(hit[None, None, :, None], k_rope, cache["krope"])
    else:
        ckv_all = cache["ckv"].at[:, idx].set(c_kv[:, 0])
        krope_all = cache["krope"].at[:, :, idx].set(k_rope[:, :, 0])
    ckv_all = constrain(ckv_all,
                        ("batch", [("data", "model"), ("data",), ("model",)],
                         None))
    mask = (jnp.arange(t) <= idx)[None, None, None, :]
    out = _mla_attend(params, q_nope, q_rope, ckv_all, krope_all, cfg, mask)
    return out, {"ckv": ckv_all, "krope": krope_all, "idx": idx + 1}
