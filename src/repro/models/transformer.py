"""Composable transformer assembly for the ten assigned families.

A model is a sequence of *layer groups*; one group is the config's layer
``pattern`` (e.g. jamba's ``[attn, 7 x mamba]`` block, gemma3's 17-layer
local/global period, or a single layer for uniform stacks).  Groups are
homogeneous, so the stack runs as ``lax.scan`` over stacked group params —
which keeps the HLO one-group-sized for the 512-device dry-run — with
``jax.checkpoint`` per group for training remat.  ``scan=False`` unrolls the
python loop (used by smoke tests and by the dry-run *cost extraction*, since
XLA's cost_analysis counts a while-loop body once; see EXPERIMENTS.md
§Methodology).

Modes:
  * ``train``   — full sequence, no caches.
  * ``prefill`` — full sequence, returns per-layer caches (KV / latent / SSM
                  state) for subsequent decode.
  * ``decode``  — one token against the caches.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.train.sharding import constrain

ATTN_KINDS = ("attn", "local")

_ACT_PREFS = {
    "rep": ("batch", None, None),
    "seq": ("batch", ("model",), None),
    "d": ("batch", None, ("model",)),
}


def _act_constrain(x, cfg):
    if cfg.act_shard == "off":
        return x
    return constrain(x, _ACT_PREFS[cfg.act_shard])


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, mixer: str, ffn: str, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict = {"norm1": L.init_rmsnorm(cfg.d_model, dtype)}
    if mixer in ATTN_KINDS:
        p["mixer"] = attn.init_gqa(k1, cfg, dtype)
        if cfg.encdec is not None:
            p["cross"] = attn.init_cross(k3, cfg, dtype)
            p["norm_cross"] = L.init_rmsnorm(cfg.d_model, dtype)
    elif mixer == "mla":
        p["mixer"] = attn.init_mla(k1, cfg, dtype)
    elif mixer == "rwkv6":
        p["mixer"] = rwkv_mod.init_rwkv6(k1, cfg, dtype)
    elif mixer == "mamba":
        p["mixer"] = mamba_mod.init_mamba(k1, cfg, dtype)
    else:
        raise ValueError(f"unknown mixer kind {mixer!r}")
    if ffn == "mlp":
        p["ffn"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
        p["norm2"] = L.init_rmsnorm(cfg.d_model, dtype)
    elif ffn == "moe":
        p["ffn"] = moe_mod.init_moe(k2, cfg, dtype)
        p["norm2"] = L.init_rmsnorm(cfg.d_model, dtype)
    elif ffn != "none":
        raise ValueError(f"unknown ffn kind {ffn!r}")
    return p


def init_group(key, cfg: ModelConfig, dtype) -> Dict:
    keys = jax.random.split(key, len(cfg.pattern))
    return {f"l{i}": init_layer(keys[i], cfg, mixer, ffn, dtype)
            for i, (mixer, ffn) in enumerate(cfg.pattern)}


def _init_enc_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.init_rmsnorm(cfg.d_model, dtype),
        "mixer": attn.init_gqa(k1, cfg, dtype),
        "norm2": L.init_rmsnorm(cfg.d_model, dtype),
        "ffn": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    k_e, k_g, k_h, k_enc = jax.random.split(key, 4)
    group_keys = jax.random.split(k_g, cfg.n_groups)
    groups = jax.vmap(lambda k: init_group(k, cfg, dtype))(group_keys)
    params: Dict = {
        "embed": L.init_embedding(k_e, cfg.vocab, cfg.d_model, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "groups": groups,
    }
    if not cfg.tie_embeddings:
        params["head"] = {"table": L._normal(k_h, (cfg.vocab, cfg.d_model),
                                             cfg.d_model ** -0.5, dtype)}
    if cfg.encdec is not None:
        enc_keys = jax.random.split(k_enc, cfg.encdec.n_enc_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(enc_keys),
            "norm": L.init_rmsnorm(cfg.d_model, dtype),
        }
    return params


def n_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, mixer: str, batch: int, cache_len: int,
                 dtype) -> Dict:
    c: Dict = {}
    if mixer in ATTN_KINDS:
        window = cfg.sliding_window if mixer == "local" else None
        c["self"] = attn.init_gqa_cache(cfg, batch, cache_len, window=window,
                                        dtype=dtype)
        if cfg.encdec is not None:
            t = cfg.encdec.enc_len
            c["cross"] = {
                "k": jnp.zeros((batch, cfg.n_kv_heads, t, cfg.hd), dtype),
                "v": jnp.zeros((batch, cfg.n_kv_heads, t, cfg.hd), dtype),
            }
    elif mixer == "mla":
        c["self"] = attn.init_mla_cache(cfg, batch, cache_len, dtype)
    elif mixer == "rwkv6":
        c["state"] = rwkv_mod.init_rwkv6_state(cfg, batch, dtype)
    elif mixer == "mamba":
        c["state"] = mamba_mod.init_mamba_state(cfg, batch, dtype)
    return c


def init_caches(cfg: ModelConfig, batch: int, cache_len: int,
                dtype=jnp.float32) -> Dict:
    """Stacked (n_groups-leading) cache pytree for all layers."""
    one = {f"l{i}": _layer_cache(cfg, mixer, batch, cache_len, dtype)
           for i, (mixer, _) in enumerate(cfg.pattern)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_groups,) + x.shape), one)


# ---------------------------------------------------------------------------
# one group of layers
# ---------------------------------------------------------------------------

def group_step(x: jax.Array, gp: Dict, cache_g: Optional[Dict],
               cfg: ModelConfig, *, mode: str, enc: Optional[jax.Array],
               cache_len: int, q_chunk: Optional[int], unroll: bool
               ) -> Tuple[jax.Array, Dict, jax.Array]:
    """Apply one layer group. Returns (x, new_caches, moe_aux)."""
    b = x.shape[0]
    new_cache: Dict = {}
    aux = jnp.zeros((2,), jnp.float32)           # [moe_aux_loss, moe_drop_frac]

    def one_layer(lp, x, ce, i):
        mixer, ffn = cfg.pattern[i]
        nce: Dict = {}
        aux_i = jnp.zeros((2,), jnp.float32)
        h = L.rmsnorm(lp["norm1"], x, cfg.norm_eps)
        window = cfg.sliding_window if mixer == "local" else None

        if mixer in ATTN_KINDS:
            if mode == "decode":
                o, nce["self"] = attn.gqa_decode(lp["mixer"], h, ce["self"], cfg,
                                                 window=window)
            else:
                o, (k, v) = attn.gqa_forward(lp["mixer"], h, cfg, window=window,
                                             q_chunk=q_chunk, unroll=unroll,
                                             return_kv=True)
                if mode == "prefill":
                    c0 = attn.init_gqa_cache(cfg, b, cache_len, window=window,
                                             dtype=x.dtype)
                    nce["self"] = attn.fill_gqa_cache(c0, k, v, window=window)
        elif mixer == "mla":
            if mode == "decode":
                o, nce["self"] = attn.mla_decode(lp["mixer"], h, ce["self"], cfg)
            else:
                o, (c_kv, k_rope) = attn.mla_forward(
                    lp["mixer"], h, cfg, q_chunk=q_chunk, unroll=unroll,
                    return_latent=True)
                if mode == "prefill":
                    c0 = attn.init_mla_cache(cfg, b, cache_len, x.dtype)
                    nce["self"] = attn.fill_mla_cache(c0, c_kv, k_rope)
        elif mixer == "rwkv6":
            state = ce["state"] if ce is not None else None
            o, st = rwkv_mod.rwkv6_forward(lp["mixer"], h, cfg, state)
            if mode != "train":
                nce["state"] = st
        elif mixer == "mamba":
            state = ce["state"] if ce is not None else None
            o, st = mamba_mod.mamba_forward(lp["mixer"], h, cfg, state)
            if mode != "train":
                nce["state"] = st
        x = x + o

        if cfg.encdec is not None and mixer in ATTN_KINDS:
            hc = L.rmsnorm(lp["norm_cross"], x, cfg.norm_eps)
            if mode == "decode":
                oc = attn.cross_decode(lp["cross"], hc, ce["cross"], cfg)
                nce["cross"] = ce["cross"]
            else:
                oc = attn.cross_forward(lp["cross"], hc, enc, cfg)
                if mode == "prefill":
                    nce["cross"] = attn.make_cross_cache(lp["cross"], enc, cfg)
            x = x + oc

        if ffn != "none":
            h2 = L.rmsnorm(lp["norm2"], x, cfg.norm_eps)
            if ffn == "mlp":
                f = L.mlp(lp["ffn"], h2)
            else:
                f, mm = moe_mod.moe_forward(lp["ffn"], h2, cfg)
                aux_i = aux_i + jnp.stack([mm["moe_aux_loss"], mm["moe_drop_frac"]])
            x = x + f
        x = _act_constrain(x, cfg)
        return x, nce, aux_i

    for i, _ in enumerate(cfg.pattern):
        lp = gp[f"l{i}"]
        ce = cache_g[f"l{i}"] if cache_g is not None else None
        if cfg.layer_remat and mode == "train":
            # nested (hierarchical) remat: the outer per-group checkpoint
            # re-runs the group forward; per-layer checkpoints keep only one
            # layer's intermediates live during that recompute — essential
            # for long patterns (gemma3's 17-layer period, jamba's 8).
            x, nce, aux_i = jax.checkpoint(
                lambda lp_, x_, i_=i: one_layer(lp_, x_, None, i_))(lp, x)
        else:
            x, nce, aux_i = one_layer(lp, x, ce, i)
        aux = aux + aux_i
        new_cache[f"l{i}"] = nce
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------

def _sinusoidal(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)[:, :d]


def encode(params: Dict, cfg: ModelConfig, frames: jax.Array, *,
           scan: bool = True, q_chunk: Optional[int] = None,
           unroll: bool = False) -> jax.Array:
    """Whisper-style bidirectional encoder over precomputed frame embeddings
    (the conv frontend is the assignment-mandated stub)."""
    x = frames + _sinusoidal(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def body(xc, lp):
        h = L.rmsnorm(lp["norm1"], xc, cfg.norm_eps)
        xc = xc + attn.gqa_forward(lp["mixer"], h, cfg, causal=False,
                                   q_chunk=q_chunk, unroll=unroll)
        h2 = L.rmsnorm(lp["norm2"], xc, cfg.norm_eps)
        return xc + L.mlp(lp["ffn"], h2), None

    if scan:
        x, _ = jax.lax.scan(lambda c, lp: body(c, lp), x,
                            params["encoder"]["layers"])
    else:
        n_enc = jax.tree.leaves(params["encoder"]["layers"])[0].shape[0]
        for i in range(n_enc):
            lp = jax.tree.map(lambda t: t[i], params["encoder"]["layers"])
            x, _ = body(x, lp)
    return L.rmsnorm(params["encoder"]["norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------

def forward(params: Dict, cfg: ModelConfig, tokens: jax.Array, *,
            patches: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None,
            mode: str = "train", caches: Optional[Dict] = None,
            cache_len: Optional[int] = None, q_chunk: Optional[int] = None,
            unroll: bool = False, scan: bool = True):
    """Returns (hidden, new_caches, aux); new_caches is None in train mode."""
    assert mode in ("train", "prefill", "decode"), mode
    enc = None
    if cfg.encdec is not None and mode != "decode":
        assert frames is not None, "enc-dec needs frame embeddings"
        enc = encode(params, cfg, frames, scan=scan, q_chunk=q_chunk,
                     unroll=unroll)

    x = L.embed(params["embed"], tokens)
    if cfg.n_patches and mode != "decode":
        assert patches is not None, "vlm needs patch embeddings"
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    x = _act_constrain(x, cfg)
    if cache_len is None:
        cache_len = x.shape[1]

    step = functools.partial(group_step, cfg=cfg, mode=mode, enc=enc,
                             cache_len=cache_len, q_chunk=q_chunk,
                             unroll=unroll)

    if scan and cfg.n_groups > 1:
        def body(carry, inp):
            xc, aux = carry
            gp, cache_g = inp
            xc, nc, aux_i = step(xc, gp, cache_g)
            return (xc, aux + aux_i), nc

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body)
        xs = (params["groups"], caches)
        (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((2,), jnp.float32)), xs)
    else:
        aux = jnp.zeros((2,), jnp.float32)
        caches_out = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda t: t[g], params["groups"])
            cache_g = (jax.tree.map(lambda t: t[g], caches)
                       if caches is not None else None)
            x, nc, aux_i = step(x, gp, cache_g)
            aux = aux + aux_i
            caches_out.append(nc)
        new_caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *caches_out)
                      if caches_out and caches_out[0] else None)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if mode == "train":
        new_caches = None
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# heads / losses
# ---------------------------------------------------------------------------

def _unembed_table(params: Dict) -> jax.Array:
    return params["head"]["table"] if "head" in params else params["embed"]["table"]


def ce_loss(params: Dict, cfg: ModelConfig, hidden: jax.Array,
            targets: jax.Array, *, chunk: int = 1024,
            unroll: bool = False) -> jax.Array:
    """Sequence-chunked cross-entropy: the (B, C, V) logits block is the only
    vocab-sized live buffer (full (B, S, V) logits at train shapes would be
    TBs).  The chunk body is checkpointed so backward re-forms each block."""
    table = _unembed_table(params).astype(jnp.float32)
    b, s, d = hidden.shape
    if s % chunk or s <= chunk:
        chunk = s

    def body(carry, i):
        hs = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
        ts = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, axis=1)
        logits = hs.astype(jnp.float32) @ table.T                  # (B, C, V)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.float32(0.0),
                            jnp.arange(s // chunk, dtype=jnp.int32),
                            unroll=unroll)
    return total / (b * s)


def logits_last(params: Dict, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    """(B, S, d) -> (B, V) float32 logits of the last position."""
    table = _unembed_table(params)
    return hidden[:, -1].astype(jnp.float32) @ table.astype(jnp.float32).T
