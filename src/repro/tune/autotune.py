"""Analyze-time knob selection from retained column fingerprints.

``autotune_partition`` sweeps a small grid of ``supernode_relax`` /
``supernode_max_size`` candidates — each re-detected from the O(n)
:class:`~repro.supernodes.fingerprint.ColumnFingerprints` the symbolic
fixpoint already produced, so no fixpoint re-run — runs every candidate
through the structure-aware blocking merge pass, scores the resulting
partitions with the roofline cost model, and returns the winner plus a
picklable :class:`TuneReport`.  ``analyze(LUOptions(autotune=True))``
freezes the chosen knob values onto the plan's options, so tuning cost
amortizes with the rest of the symbolic work and a pickled plan replays
bitwise without re-tuning.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.obs import metrics as _om
from repro.obs import trace as _ot
from repro.supernodes.blocking import merge_supernodes, partition_stats
from repro.supernodes.detect import detect_from_fingerprints
from repro.tune.model import RooflineCostModel, cost_model_for

# Candidate grids.  Small on purpose: detection from fingerprints is O(n)
# and the merge pass O(nnz), so the sweep costs a few percent of analyze,
# but the grid still brackets the regimes that matter (exact T2 partitions,
# mild/aggressive T3 relaxation, panel width caps around the GEMM
# sweet spot).  The options' own values are always included so autotune
# can only match or beat the hand-set configuration under the model.
RELAX_GRID = (0, 1, 2, 4)
MAX_SIZE_GRID = (32, 64, 128)

# Byte budget for the fixpoint's (concurrency, n) int32 label matrix when
# choosing ``concurrency``; keeps the working set cache-friendly without
# starving the fixpoint of sources per superstep.
_LABEL_BYTES_BUDGET = 64 << 20
_MIN_CONCURRENCY = 64
_MAX_CONCURRENCY = 1024


@dataclasses.dataclass(frozen=True)
class TuneReport:
    """Picklable record of one autotune sweep (``LUPlan.tuned``)."""

    chosen: dict
    modeled_s: float
    baseline_s: float
    n_panels: int
    candidates: Tuple[dict, ...]


def choose_concurrency(n: int, *, budget_bytes: Optional[int] = None) -> int:
    """Power-of-two source-chunk width for an n-column matrix.

    Sized so the fixpoint's ``(concurrency, n)`` int32 label matrix fits
    ``budget_bytes`` (default 64 MiB), clamped to [64, 1024] and never more
    than ``n``.  Deterministic — pure arithmetic in ``n``.
    """
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    budget = _LABEL_BYTES_BUDGET if budget_bytes is None else budget_bytes
    c = max(1, budget // max(1, 4 * n))
    c = 1 << (int(c).bit_length() - 1)  # round down to a power of two
    c = max(_MIN_CONCURRENCY, min(_MAX_CONCURRENCY, c))
    return min(c, max(1, n))


def autotune_partition(pattern, fingerprints, options, *,
                       peaks: Optional[dict] = None,
                       model: Optional[RooflineCostModel] = None,
                       ) -> Tuple[np.ndarray, TuneReport]:
    """Pick the best (relax, max_size, merge) partition under the model.

    Returns ``(supernodes, report)`` where ``supernodes`` is the winning
    merged partition and ``report.chosen`` maps ``LUOptions`` field names to
    the frozen values (``supernode_relax``, ``supernode_max_size``,
    ``blocking``, ``block_merge_threshold``, ``block_max_width``,
    ``concurrency``).  The baseline score is the options' own
    (relax, max_size) partition *without* merging — what the pipeline would
    have run untuned.
    """
    if fingerprints is None:
        raise ValueError(
            "autotune requires the symbolic result to retain column "
            "fingerprints (SymbolicResult.fingerprints); re-run analyze() — "
            "plans pickled before v1.7.0 predate fingerprint retention")
    if model is None:
        model = cost_model_for(options, peaks)
    threshold = (1.0 if options.block_merge_threshold is None
                 else float(options.block_merge_threshold))
    max_width = int(options.block_max_width)
    with _ot.span("autotune"):
        base = detect_from_fingerprints(
            fingerprints, relax=options.supernode_relax,
            max_size=options.supernode_max_size)
        bstats = partition_stats(pattern, base)
        baseline_s = model.partition_time(bstats["m"], bstats["k"],
                                          bstats["w"])

        relaxes = sorted(set(RELAX_GRID) | {int(options.supernode_relax)})
        max_sizes = sorted(set(MAX_SIZE_GRID)
                           | {int(options.supernode_max_size)})
        best = None
        candidates = []
        for relax in relaxes:
            for max_size in max_sizes:
                ranges = detect_from_fingerprints(fingerprints, relax=relax,
                                                  max_size=max_size)
                merged, mstats = merge_supernodes(
                    pattern, ranges, model, threshold=threshold,
                    max_width=max_width)
                modeled = mstats.modeled_after_s
                candidates.append({
                    "supernode_relax": relax,
                    "supernode_max_size": max_size,
                    "modeled_s": modeled,
                    "n_panels": mstats.n_after,
                    "merges": mstats.merges,
                })
                # Strict < keeps ties on the earliest (smallest-knob)
                # candidate, so the pick is deterministic across runs.
                if best is None or modeled < best[0]:
                    best = (modeled, relax, max_size, merged)
        modeled_s, relax, max_size, supernodes = best
        chosen = {
            "supernode_relax": int(relax),
            "supernode_max_size": int(max_size),
            "blocking": True,
            "block_merge_threshold": threshold,
            "block_max_width": max_width,
            "concurrency": choose_concurrency(pattern.n),
        }
        report = TuneReport(
            chosen=chosen,
            modeled_s=float(modeled_s),
            baseline_s=float(baseline_s),
            n_panels=int(len(supernodes)),
            candidates=tuple(candidates),
        )
        if _ot.ENABLED:
            reg = _om.registry()
            reg.count("tune.candidates", len(candidates))
            reg.gauge("tune.modeled_s", report.modeled_s)
            reg.gauge("tune.baseline_s", report.baseline_s)
    return supernodes, report
