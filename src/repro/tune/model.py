"""Roofline cost model for supernodal panel sweeps (DESIGN.md §16).

Models the seconds one panel costs the left-looking sweep from its packed
shape: a per-panel dispatch overhead ``alpha`` (Python/driver time — the
dominant term for the thousands of tiny panels T2/T3 detection emits), the
trailing-update GEMM charged at ``max(flops / peak_flops, bytes / peak_bw)``
(the roofline), and the in-panel dense factor work.  The byte counts match
the analytic ``gemm.bytes`` accounting in ``numeric/supernodal.py``
(``8 * (m*k + k*w + 2*m*w)`` per panel), so modeled and measured
fraction-of-peak share units.

Peaks come from the caller: the bench layer passes the probed
``benchmarks/roofline.py::machine_peaks()`` dict (``repro`` never imports
from ``benchmarks``); library callers get fixed representative constants so
autotune decisions are deterministic across hosts and processes — a pickled
autotuned plan replays bitwise anywhere because the chosen knobs are frozen
onto the plan, not re-derived.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Fallback peaks when no probe is supplied.  Deliberately fixed constants
# (not a runtime probe): the merge decisions they drive land on the plan,
# and deterministic defaults mean analyze(autotune=True) picks the same
# partition on every host and every run.  Representative of a modest host:
DEFAULT_MEM_BW_GBS = 10.0
DEFAULT_FLOPS_GFLOPS = 50.0
# Per-panel dispatch overhead (Python loop + scatter/gather bookkeeping per
# panel in the numeric sweep).  Measured ~85 us/panel on bbd-20k (0.8 s
# refactorize / 9372 panels); 50 us is conservative enough to still favour
# merging tiny panels without over-merging on fast hosts.
DEFAULT_DISPATCH_OVERHEAD_S = 5e-5


@dataclasses.dataclass(frozen=True)
class RooflineCostModel:
    """Modeled panel/GEMM seconds against machine peaks.

    ``backend`` selects the shape the GEMM is charged at: ``"numpy"`` uses
    logical shapes, ``"kernel"`` pads to the MXU tiles ``kernels.ops``
    actually dispatches (explicit-zero work is real work there).
    """

    mem_bw_gbs: float = DEFAULT_MEM_BW_GBS
    flops_gflops: float = DEFAULT_FLOPS_GFLOPS
    dispatch_overhead_s: float = DEFAULT_DISPATCH_OVERHEAD_S
    backend: str = "numpy"

    @classmethod
    def from_peaks(cls, peaks: Optional[dict], *, backend: str = "numpy",
                   dispatch_overhead_s: float = DEFAULT_DISPATCH_OVERHEAD_S,
                   ) -> "RooflineCostModel":
        """Build from a ``machine_peaks()``-shaped dict (``mem_bw_gbs`` /
        ``flops_gflops`` keys); missing keys fall back to the defaults."""
        peaks = peaks or {}
        return cls(
            mem_bw_gbs=float(peaks.get("mem_bw_gbs", DEFAULT_MEM_BW_GBS)),
            flops_gflops=float(peaks.get("flops_gflops",
                                         DEFAULT_FLOPS_GFLOPS)),
            dispatch_overhead_s=float(dispatch_overhead_s),
            backend=backend,
        )

    # -- primitive costs ---------------------------------------------------

    def gemm_time(self, m, k, n):
        """Roofline seconds of one ``(m, k) @ (k, n)`` trailing update.

        Bytes follow the sweep's analytic accounting: read L ``m*k``, read U
        ``k*n``, read+write the accumulator ``2*m*n``, 8 bytes each.
        Vectorised — accepts scalars or numpy arrays.
        """
        m_, k_, n_ = (np.asarray(x, dtype=np.float64) for x in (m, k, n))
        if self.backend == "kernel":
            from repro.kernels.ops import padded_gemm_shape

            mp, kp, np_ = padded_gemm_shape(m, k, n)
            m_, k_, n_ = (np.asarray(x, dtype=np.float64)
                          for x in (mp, kp, np_))
        flops = 2.0 * m_ * k_ * n_
        nbytes = 8.0 * (m_ * k_ + k_ * n_ + 2.0 * m_ * n_)
        t = np.maximum(flops / (self.flops_gflops * 1e9),
                       nbytes / (self.mem_bw_gbs * 1e9))
        return float(t) if np.ndim(t) == 0 else t

    def panel_time(self, m, k, w):
        """Modeled sweep seconds of one packed panel.

        ``m`` rows at/below the diagonal block, ``k`` ancestor rows above it
        (the GEMM reduction depth), ``w`` columns wide.  Sum of the dispatch
        overhead, the trailing GEMM at the roofline, and the in-panel dense
        factor charged at what the sweep actually runs: ``lu_inplace`` is a
        per-column rank-1 update loop, so the diagonal block rereads and
        rewrites its trailing submatrix every step — ``~16/3 w^3`` bytes of
        traffic, not one pass over ``w^2`` — and the below-diagonal rows get
        one triangular-solve pass (``(m - w) w^2`` flops, one read + write).
        The cubic byte term is what stops the merge pass at a finite width:
        dispatch savings shrink like ``1/w`` while factor traffic grows like
        ``w^2`` per column, giving ``w* = cbrt(3 alpha B / 32)`` (~36 cols
        at the default constants).  Vectorised over arrays.
        """
        m_, k_, w_ = (np.asarray(x, dtype=np.float64) for x in (m, k, w))
        t = self.dispatch_overhead_s + self.gemm_time(m, k, w)
        ml = np.maximum(m_ - w_, 0.0)  # L rows below the diagonal block
        factor_flops = (2.0 / 3.0) * w_ ** 3 + ml * w_ ** 2
        factor_bytes = (16.0 / 3.0) * w_ ** 3 + 16.0 * ml * w_
        t = t + np.maximum(factor_flops / (self.flops_gflops * 1e9),
                           factor_bytes / (self.mem_bw_gbs * 1e9))
        return float(t) if np.ndim(t) == 0 else t

    def partition_time(self, m, k, w):
        """Total modeled seconds of a whole partition (arrays per panel)."""
        return float(np.sum(self.panel_time(m, k, w)))


def cost_model_for(options, peaks: Optional[dict] = None) -> RooflineCostModel:
    """Model matching an ``LUOptions``' numeric backend, fed by ``peaks``
    when the caller probed them (``benchmarks/roofline.py``) or the fixed
    defaults otherwise."""
    return RooflineCostModel.from_peaks(
        peaks, backend=getattr(options, "numeric_backend", "numpy"))
