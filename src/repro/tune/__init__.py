"""Roofline-driven autotuning of the analyze-time knobs (DESIGN.md §16).

``model.py`` owns the roofline cost model — modeled seconds of one
supernodal panel from its GEMM shape against machine peaks plus a
per-dispatch overhead term; ``autotune.py`` sweeps candidate supernode
partitions (re-detected from the retained column fingerprints, so no
fixpoint re-run) through the structure-aware blocking merge pass
(``supernodes/blocking.py``) and freezes the winning knob values onto the
plan.  ``repro`` never imports from ``benchmarks`` — the bench layer passes
its probed ``machine_peaks()`` dict *in*; without one the model falls back
to fixed representative constants so autotune decisions stay deterministic
across processes (a pickled autotuned plan replays bitwise anywhere).
"""
from repro.tune.model import RooflineCostModel, cost_model_for
from repro.tune.autotune import (
    TuneReport, autotune_partition, choose_concurrency,
)

__all__ = [
    "RooflineCostModel", "cost_model_for",
    "TuneReport", "autotune_partition", "choose_concurrency",
]
