"""Serving driver: batched prefill + greedy decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --requests 8 --prompt-len 32 --gen-len 16

The engine keeps one fixed-shape decode batch resident (the jit signature
never changes); requests are packed into free slots after prefill, and
finished slots are recycled — the standard continuous-batching serving loop,
here in its minimal host-driven form.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.train.steps import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dtype = jnp.dtype(args.dtype)
    mesh = make_host_mesh()
    b = args.requests
    cache_len = args.prompt_len + args.gen_len

    pf_shape = ShapeConfig("serve_pf", args.prompt_len, b, "prefill")
    dc_shape = ShapeConfig("serve_dc", cache_len, b, "decode")
    prefill = make_prefill_step(cfg, mesh, pf_shape, dtype=dtype,
                                cache_len=cache_len)
    decode = make_decode_step(cfg, mesh, dc_shape, dtype=dtype, donate=False)

    params = tf.init_params(jax.random.key(0), cfg, dtype)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (b, args.prompt_len)), jnp.int32)}
    if cfg.n_patches:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.d_model)), dtype)
    if cfg.encdec is not None:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encdec.enc_len, cfg.d_model)), dtype)

    t0 = time.time()
    next_tok, caches = prefill.fn(params, batch)
    next_tok.block_until_ready()
    t_prefill = time.time() - t0

    out = [next_tok]
    t1 = time.time()
    tok = next_tok[:, None]
    for _ in range(args.gen_len - 1):
        tok_next, caches = decode.fn(params, caches, tok)
        out.append(tok_next)
        tok = tok_next[:, None]
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t1

    gen = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"prefill: {b} x {args.prompt_len} tokens in {t_prefill*1e3:.1f} ms "
          f"({b*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode:  {b} x {args.gen_len} tokens in {t_decode*1e3:.1f} ms "
          f"({b*args.gen_len/max(t_decode,1e-9):.0f} tok/s)")
    print(f"sample continuation (request 0): {gen[0].tolist()}")


if __name__ == "__main__":
    main()
