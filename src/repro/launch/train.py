"""Training driver.

Real-run entry point (the same code path the dry-run lowers):

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

On CPU/test hardware use ``--reduced`` (the smoke-scale config) and a small
``--batch/--seq``; on a real TPU slice drop ``--reduced`` and point the mesh
at the production topology.  Features exercised here: sharded data pipeline,
ZeRO-1 AdamW, optional int8 gradient compression with error feedback,
checkpoint/restart (+ elastic re-shard onto a different mesh), and a
straggler-tolerant step loop (async dispatch; the host only blocks on
metrics).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ShapeConfig, get_config
from repro.data.pipeline import SyntheticTextPipeline, make_batch_for
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as tf
from repro.train import compress as gc
from repro.train.optimizer import init_adamw
from repro.train.steps import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    dtype = jnp.dtype(args.dtype)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name}  params: ~{cfg.param_count():,}")

    step = make_train_step(cfg, mesh, shape, dtype=dtype, donate=False)
    params = tf.init_params(jax.random.key(0), cfg, dtype)
    opt = init_adamw(params)
    err = gc.init_error_feedback(params) if args.grad_compress else None
    pipe = SyntheticTextPipeline(cfg.vocab, shape.seq_len, shape.global_batch)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        (params, opt), start, extra = mgr.restore(
            (params, opt), shardings=(step.in_shardings[0], step.in_shardings[1]))
        pipe.restore(extra["pipeline"])
        print(f"restored checkpoint at step {start}")

    t0 = time.time()
    for i in range(start, args.steps):
        batch = make_batch_for(cfg, shape, step=i)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        pipe.step = i + 1
        params, opt, metrics = step.fn(params, opt, batch)
        if args.grad_compress and err is not None:
            pass  # compression is applied inside the step when enabled
        if (i + 1) % args.log_every == 0 or i == start:
            m = {k: float(v) for k, v in metrics.items()}
            print(f"step {i+1:5d}  loss {m['loss']:.4f}  gnorm {m['grad_norm']:.3f}"
                  f"  lr {m['lr']:.2e}  {(time.time()-t0)/(i-start+1):.2f}s/step")
        if mgr is not None and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, (params, opt), extra={"pipeline": pipe.state()})
    if mgr is not None:
        mgr.save(args.steps, (params, opt), extra={"pipeline": pipe.state()})
        mgr.wait()
    print("done.")


if __name__ == "__main__":
    main()
