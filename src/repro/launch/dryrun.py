import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be set before any jax import (jax locks the device count on first
# init).  Only this entry point forces 512 placeholder devices; tests and
# benchmarks see the real device list.

"""Multi-pod dry-run driver.

One *cell* = (architecture x input shape x mesh).  For each cell we

  1. build the jitted production step (train_step / prefill / serve_step)
     with full shardings (train/steps.py),
  2. ``.lower().compile()`` it against ShapeDtypeStructs — no allocation —
     which proves the sharding config is coherent on the production mesh,
  3. print/record ``memory_analysis()`` (does it fit) and
     ``cost_analysis()`` + the collective schedule parsed from the
     partitioned HLO (launch/costs.py),
  4. on the single-pod mesh additionally run the *compositional cost
     extraction* (exact per-device FLOPs/bytes/collective bytes; see
     costs.py docstring) that feeds EXPERIMENTS.md §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --gsofa --mesh multipod
  python -m repro.launch.dryrun --sweep            # everything, subprocesses
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")


def _artifact_path(name: str) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    return os.path.join(ARTIFACT_DIR, name + ".json")


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             with_costs: bool = True) -> dict:
    import jax.numpy as jnp

    from repro.configs.base import SHAPES, cell_is_supported, get_config
    from repro.launch import costs as C
    from repro.launch.mesh import make_production_mesh
    from repro.train.steps import make_step

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multipod" if multi_pod else "pod"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind}
    ok, why = cell_is_supported(cfg, shape)
    if not ok:
        rec["skipped"] = why
        print(f"[dryrun] SKIP {arch} x {shape_name} x {mesh_name}: {why}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec["n_devices"] = int(mesh.devices.size)
    rec["params"] = cfg.param_count()
    rec["active_params"] = cfg.active_param_count()

    t0 = time.time()
    step = make_step(cfg, mesh, shape, dtype=jnp.bfloat16)
    with mesh:
        lowered = step.fn.lower(*step.args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
    print(compiled.memory_analysis())     # proves it fits (per device)
    print({k: v for k, v in (compiled.cost_analysis() or {}).items()
           if k in ("flops", "bytes accessed")})
    rec["memory"] = C.memory_record(compiled)
    rec["full_step"] = C.analyze_compiled(compiled)
    # exact per-device resident-state sizes (for the analytic memory model)
    state_bytes = {}
    if shape.kind == "train":
        labels = ("params", "opt", "batch")
    elif shape.kind == "prefill":
        labels = ("params", "batch")
    else:
        labels = ("params", "caches", "tokens")
    for name, struct, sh in zip(labels, step.args, step.in_shardings):
        state_bytes[name] = C.sharded_bytes(struct, sh)
    rec["state_bytes_per_device"] = state_bytes

    if with_costs and not multi_pod:
        t2 = time.time()
        rec["costs"] = C.cell_costs(cfg, mesh, shape, dtype=jnp.bfloat16)
        rec["costs_s"] = round(time.time() - t2, 1)
    print(f"[dryrun] OK {arch} x {shape_name} x {mesh_name} "
          f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
          f"temp={rec['memory']['temp_bytes']/1e9:.2f}GB/dev")
    return rec


def run_gsofa_cell(multi_pod: bool, n: int = 1 << 20, k_in: int = 16,
                   concurrency: int = 64) -> dict:
    """The paper-side distributed cell: GSoFa sources sharded over every mesh
    axis (the 1,000-GPU scaling claim, compile-level).

    One lowering = one *wave* of #C sources per device (the paper's
    concurrency knob; labels are O(#C x |V|) per device, so #C is what the
    memory envelope controls).  The full factorization is
    ceil(n / (n_dev x #C)) host-driven waves with interleaved source order.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.distributed import make_distributed_counts
    from repro.core.gsofa import SymbolicGraph
    from repro.launch import costs as C
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(mesh.devices.size)
    rec = {"arch": "gsofa", "shape": f"n{n}", "kind": "symbolic",
           "mesh": "multipod" if multi_pod else "pod", "n_devices": n_dev}
    graph = SymbolicGraph(
        n=n,
        in_ell=jax.ShapeDtypeStruct((n, k_in), jnp.int32),
        out_ell=jax.ShapeDtypeStruct((n, k_in), jnp.int32),
        out_deg=jax.ShapeDtypeStruct((n,), jnp.int32),
        adj_dense=None)
    srcs = jax.ShapeDtypeStruct((n_dev, concurrency), jnp.int32)
    rec["concurrency"] = concurrency
    rec["waves"] = -(-n // (n_dev * concurrency))
    # bound supersteps by a realistic diameter, not |V| (lowering only)
    step = make_distributed_counts(mesh, n, backend="ell", max_iters=512)
    t0 = time.time()
    with mesh:
        lowered = step.lower(srcs, graph)
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    print(compiled.memory_analysis())
    rec["memory"] = C.memory_record(compiled)
    rec["full_step"] = C.analyze_compiled(compiled)
    print(f"[dryrun] OK gsofa x {rec['mesh']} compile={rec['compile_s']}s")
    return rec


# ---------------------------------------------------------------------------
# sweep driver (subprocess per cell: isolation + bounded memory)
# ---------------------------------------------------------------------------

def all_cells():
    from repro.configs.archs import ALL_ARCHS
    from repro.configs.base import SHAPES
    # cheap archs first so results stream into the roofline analysis early
    order = ["smollm-135m", "whisper-tiny", "qwen3-1.7b", "rwkv6-7b",
             "gemma3-4b", "qwen3-14b", "moonshot-v1-16b-a3b", "internvl2-26b",
             "jamba-1.5-large-398b", "deepseek-v3-671b"]
    assert sorted(order) == sorted(ALL_ARCHS)
    cells = []
    for mesh_name in ("pod", "multipod"):
        for arch in order:
            for shape in SHAPES:
                cells.append((arch, shape, mesh_name))
    return cells


def sweep(timeout: int, only_missing: bool) -> None:
    cells = all_cells() + [("gsofa", "default", "pod"),
                           ("gsofa", "default", "multipod")]
    for arch, shape, mesh_name in cells:
        name = f"{arch}__{shape}__{mesh_name}"
        path = _artifact_path(name)
        if only_missing and os.path.exists(path):
            continue
        args = [sys.executable, "-m", "repro.launch.dryrun",
                "--mesh", mesh_name, "--out", path]
        if arch == "gsofa":
            args += ["--gsofa"]
        else:
            args += ["--arch", arch, "--shape", shape]
        print(f"[sweep] {name}", flush=True)
        try:
            r = subprocess.run(args, timeout=timeout, capture_output=True,
                               text=True)
            if r.returncode != 0:
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mesh_name,
                               "error": r.stderr[-4000:]}, f, indent=1)
                print(f"[sweep] FAIL {name}:\n{r.stderr[-2000:]}", flush=True)
        except subprocess.TimeoutExpired:
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh_name,
                           "error": f"timeout after {timeout}s"}, f, indent=1)
            print(f"[sweep] TIMEOUT {name}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("pod", "multipod"), default="pod")
    ap.add_argument("--gsofa", action="store_true")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--no-costs", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--out")
    args = ap.parse_args()

    if args.sweep:
        sweep(args.timeout, args.only_missing)
        return

    multi = args.mesh == "multipod"
    name = (f"gsofa__default__{args.mesh}" if args.gsofa
            else f"{args.arch}__{args.shape}__{args.mesh}")
    try:
        if args.gsofa:
            rec = run_gsofa_cell(multi)
        else:
            rec = run_cell(args.arch, args.shape, multi,
                           with_costs=not args.no_costs)
    except Exception:
        rec = {"arch": args.arch or "gsofa", "shape": args.shape,
               "mesh": args.mesh, "error": traceback.format_exc()[-4000:]}
        print(traceback.format_exc(), file=sys.stderr)
        with open(args.out or _artifact_path(name), "w") as f:
            json.dump(rec, f, indent=1)
        sys.exit(1)

    out = args.out or _artifact_path(name)
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
