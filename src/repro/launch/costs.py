"""Compositional cost extraction for the roofline analysis.

XLA's ``compiled.cost_analysis()`` visits a while-loop body ONCE, so a
scanned model under-reports FLOPs by ~n_groups and hides the collectives
inside the loop.  We therefore decompose each step's cost into components
whose lowerings contain no while loops (all inner scans run ``unroll``-ed):

    cost(train_step)  = n_groups x cost(group fwd+bwd, remat'd)
                      + cost(stem+head: embed + final-norm + chunked-CE, fwd+bwd)
                      + cost(encoder fwd+bwd)                    [enc-dec only]
                      + cost(optimizer update, ZeRO-1)
    cost(prefill)     = n_groups x cost(group fwd) + stem/head fwd [+ encoder]
    cost(decode)      = n_groups x cost(group decode) + stem/head fwd

Every component is lowered with the production shardings of the full step,
so per-device FLOPs / HBM bytes / collective bytes are what the partitioned
program actually does.  The full (scanned) step is still compiled separately
by dryrun.py — that artifact provides the compile-coherence proof and the
memory analysis; this module provides the exact cost totals.

Known residual under-count (documented in EXPERIMENTS.md §Methodology): the
sequential time-step recurrences of rwkv6/mamba remain while-loops even here
(unrolling 4k-512k steps is infeasible); their body cost is measured once
and multiplied analytically by the trip count.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.train import sharding as shd
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw
from repro.train.steps import cache_specs, param_specs

# ---------------------------------------------------------------------------
# HLO parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute", "collective-broadcast")

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\]{},:()\sTSE#]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast)(-start)?\(")

_SHAPE_RE = re.compile(
    r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-device bytes moved by collectives, summed from the partitioned
    HLO's result shapes (post-SPMD the module is the per-device program, so
    these are local bytes; global bytes = local x n_devices)."""
    per_op: Counter = Counter()
    counts: Counter = Counter()
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group(2)
        per_op[op] += _shape_bytes(m.group(1))
        counts[op] += 1
    return {"bytes_by_op": dict(per_op), "counts_by_op": dict(counts),
            "total_bytes": int(sum(per_op.values()))}


def analyze_compiled(compiled) -> Dict[str, Any]:
    ca = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "hbm_bytes": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
    }


def sharded_bytes(structs, shardings) -> float:
    """Exact per-device bytes of a sharded pytree (from shard shapes)."""
    import math
    total = 0
    for leaf, sh in zip(jax.tree.leaves(structs), jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))):
        shard = sh.shard_shape(leaf.shape)
        total += math.prod(shard) * jnp.dtype(leaf.dtype).itemsize
    return float(total)


def memory_record(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "peak_bytes_est": float(ma.argument_size_in_bytes
                                + ma.output_size_in_bytes
                                + ma.temp_size_in_bytes
                                - ma.alias_size_in_bytes),
    }


# ---------------------------------------------------------------------------
# component shardings
# ---------------------------------------------------------------------------

def _strip_group_axis(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), tree)


def _group_shardings(group_struct, mesh: Mesh, cfg: ModelConfig):
    def one(path, leaf):
        ps = shd.param_pspec("groups/" + shd._path_str(path),
                             (1,) + leaf.shape, mesh, cfg)
        return NamedSharding(mesh, P(*tuple(ps)[1:]))
    return jax.tree_util.tree_map_with_path(one, group_struct)


def _group_cache_shardings(cache_struct, mesh: Mesh, cfg: ModelConfig):
    def one(path, leaf):
        ps = shd.cache_pspec(shd._path_str(path), (1,) + leaf.shape, mesh, cfg)
        return NamedSharding(mesh, P(*tuple(ps)[1:]))
    return jax.tree_util.tree_map_with_path(one, cache_struct)


def _act_sharding(shape: Tuple[int, ...], mesh: Mesh, cfg: ModelConfig):
    return NamedSharding(mesh, shd.batch_pspec(shape, mesh, cfg))


def _seq_total(cfg: ModelConfig, shape: ShapeConfig) -> int:
    return shape.seq_len


# ---------------------------------------------------------------------------
# components
# ---------------------------------------------------------------------------

def _stem_tree(p_specs) -> Dict:
    stem = {"embed": p_specs["embed"], "final_norm": p_specs["final_norm"]}
    if "head" in p_specs:
        stem["head"] = p_specs["head"]
    return stem


def group_component(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                    dtype, q_chunk: Optional[int]) -> Tuple[Any, Tuple, Tuple]:
    """Returns (fn, arg_structs, in_shardings) for one layer group."""
    b = shape.global_batch
    s = 1 if shape.kind == "decode" else _seq_total(cfg, shape)
    d = cfg.d_model
    has_enc = cfg.encdec is not None

    p_specs = param_specs(cfg, dtype)
    gp_struct = _strip_group_axis(p_specs["groups"])
    gp_sh = _group_shardings(gp_struct, mesh, cfg)
    x_struct = jax.ShapeDtypeStruct((b, s, d), dtype)
    x_sh = _act_sharding((b, s, d), mesh, cfg)
    enc_struct = (jax.ShapeDtypeStruct((b, cfg.encdec.enc_len, d), dtype)
                  if has_enc else None)
    enc_sh = (_act_sharding((b, cfg.encdec.enc_len, d), mesh, cfg)
              if has_enc else None)

    if shape.kind == "train":
        def fn(gp, x, dy, enc=None):
            # jax.vjp with an explicit bf16 cotangent: this is what the real
            # scanned train step feeds each group (a sum(f32(out)*dy) proxy
            # would inject f32 cotangents and double every dx collective)
            def fwd(gp, x, enc):
                with shd.step_context(mesh, cfg):
                    out, _, aux = tf.group_step(
                        x, gp, None, cfg=cfg, mode="train", enc=enc,
                        cache_len=s, q_chunk=q_chunk, unroll=True)
                return out, aux
            if cfg.remat:
                fwd = jax.checkpoint(fwd)
            (out, aux), vjp = jax.vjp(fwd, gp, x, enc)
            grads = vjp((dy.astype(out.dtype),
                         jnp.ones_like(aux) * 0.01))
            return grads if enc is not None else grads[:2]

        structs = [gp_struct, x_struct, x_struct] + ([enc_struct] if has_enc else [])
        shards = [gp_sh, x_sh, x_sh] + ([enc_sh] if has_enc else [])
        return fn, tuple(structs), tuple(shards)

    if shape.kind == "prefill":
        def fn(gp, x, enc=None):
            with shd.step_context(mesh, cfg):
                out, cache, _ = tf.group_step(
                    x, gp, None, cfg=cfg, mode="prefill", enc=enc,
                    cache_len=shape.seq_len, q_chunk=q_chunk, unroll=True)
            return out, cache

        structs = [gp_struct, x_struct] + ([enc_struct] if has_enc else [])
        shards = [gp_sh, x_sh] + ([enc_sh] if has_enc else [])
        return fn, tuple(structs), tuple(shards)

    # decode
    cache_struct = _strip_group_axis(cache_specs(cfg, b, shape.seq_len, dtype))
    cache_sh = _group_cache_shardings(cache_struct, mesh, cfg)

    def fn(gp, x, cache):
        with shd.step_context(mesh, cfg):
            out, new_cache, _ = tf.group_step(
                x, gp, cache, cfg=cfg, mode="decode", enc=None,
                cache_len=shape.seq_len, q_chunk=None, unroll=True)
        return out, new_cache

    return fn, (gp_struct, x_struct, cache_struct), (gp_sh, x_sh, cache_sh)


def stem_head_component(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                        dtype) -> Tuple[Any, Tuple, Tuple]:
    """embed + final norm + loss/logits (+ their backward for train)."""
    b = shape.global_batch
    s = 1 if shape.kind == "decode" else _seq_total(cfg, shape)
    s_text = s - cfg.n_patches if (cfg.n_patches and shape.kind != "decode") else s
    d = cfg.d_model

    p_specs = param_specs(cfg, dtype)
    stem_struct = _stem_tree(p_specs)
    stem_sh = shd.param_shardings(stem_struct, mesh, cfg)
    tok_struct = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    tok_sh = _act_sharding((b, s_text), mesh, cfg)
    x_struct = jax.ShapeDtypeStruct((b, s, d), dtype)
    x_sh = _act_sharding((b, s, d), mesh, cfg)

    if shape.kind == "train":
        lbl_struct = jax.ShapeDtypeStruct((b, s), jnp.int32)
        lbl_sh = _act_sharding((b, s), mesh, cfg)

        def fn(stem, x_mid, tokens, labels):
            def fwd(stem, x_mid):
                with shd.step_context(mesh, cfg):
                    x = tf.L.embed(stem["embed"], tokens)
                    if cfg.n_patches:
                        x = jnp.pad(x, ((0, 0), (cfg.n_patches, 0), (0, 0)))
                    hidden = tf.L.rmsnorm(stem["final_norm"], x + x_mid,
                                          cfg.norm_eps)
                    return tf.ce_loss(stem, cfg, hidden, labels, unroll=True)
            return jax.grad(fwd, argnums=(0, 1))(stem, x_mid)

        return (fn, (stem_struct, x_struct, tok_struct, lbl_struct),
                (stem_sh, x_sh, tok_sh, lbl_sh))

    def fn(stem, x_mid, tokens):
        with shd.step_context(mesh, cfg):
            x = tf.L.embed(stem["embed"], tokens)
            if cfg.n_patches and shape.kind != "decode":
                x = jnp.pad(x, ((0, 0), (cfg.n_patches, 0), (0, 0)))
            hidden = tf.L.rmsnorm(stem["final_norm"], x + x_mid, cfg.norm_eps)
            logits = tf.logits_last(stem, cfg, hidden)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return (fn, (stem_struct, x_struct, tok_struct), (stem_sh, x_sh, tok_sh))


def encoder_component(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                      dtype) -> Optional[Tuple[Any, Tuple, Tuple]]:
    if cfg.encdec is None or shape.kind == "decode":
        return None
    b = shape.global_batch
    t, d = cfg.encdec.enc_len, cfg.d_model
    p_specs = param_specs(cfg, dtype)
    enc_struct = {"encoder": p_specs["encoder"]}
    enc_sh = shd.param_shardings(enc_struct, mesh, cfg)
    f_struct = jax.ShapeDtypeStruct((b, t, d), dtype)
    f_sh = _act_sharding((b, t, d), mesh, cfg)

    if shape.kind == "train":
        def fn(ep, frames, dy):
            def fwd(ep, frames):
                with shd.step_context(mesh, cfg):
                    out = tf.encode(ep, cfg, frames, scan=False)
                return jnp.sum(out.astype(jnp.float32) * dy.astype(jnp.float32))
            return jax.grad(fwd, argnums=(0, 1))(ep, frames)
        return fn, (enc_struct, f_struct, f_struct), (enc_sh, f_sh, f_sh)

    def fn(ep, frames):
        with shd.step_context(mesh, cfg):
            return tf.encode(ep, cfg, frames, scan=False)
    return fn, (enc_struct, f_struct), (enc_sh, f_sh)


def optimizer_component(cfg: ModelConfig, mesh: Mesh, dtype,
                        acfg: AdamWConfig = AdamWConfig()
                        ) -> Tuple[Any, Tuple, Tuple]:
    p_specs = param_specs(cfg, dtype)
    o_specs = jax.eval_shape(init_adamw, p_specs)
    p_sh = shd.param_shardings(p_specs, mesh, cfg)
    o_sh = {"master": shd.opt_shardings(p_sh, p_specs, mesh),
            "m": shd.opt_shardings(p_sh, p_specs, mesh),
            "v": shd.opt_shardings(p_sh, p_specs, mesh),
            "count": NamedSharding(mesh, P())}

    def fn(params, opt, grads):
        new_p, new_o, _ = adamw_update(params, grads, opt, acfg)
        return new_p, new_o

    return fn, (p_specs, o_specs, p_specs), (p_sh, o_sh, p_sh)


# ---------------------------------------------------------------------------
# cell costs
# ---------------------------------------------------------------------------

def _lower_component(fn, structs, shards) -> Dict[str, Any]:
    compiled = jax.jit(fn, in_shardings=shards).lower(*structs).compile()
    return analyze_compiled(compiled)


def _ssm_scan_correction(cfg: ModelConfig, shape: ShapeConfig,
                         n_dev: int) -> Dict[str, float]:
    """Analytic add-back for the sequential time recurrences (their while
    bodies are counted once by cost_analysis; real trip count is seq_len).
    Per token per layer (fp32): rwkv6 state update+readout ~ 4*B*H*K^2 flops,
    2 state r/w of B*H*K^2 * 4B; mamba ~ 6*B*di*N flops, 2*B*di*N*4 bytes."""
    if shape.kind == "decode":
        return {"flops": 0.0, "hbm_bytes": 0.0}
    steps = shape.seq_len - 1          # body counted once already
    b_local = max(1, shape.global_batch // n_dev)  # batch-sharded recurrence
    fl = by = 0.0
    for mixer, _ in cfg.full_pattern:
        if mixer == "rwkv6":
            h = cfg.d_model // cfg.ssm.head_size
            k = cfg.ssm.head_size
            fl += cfg.n_groups * steps * 4.0 * b_local * h * k * k
            by += cfg.n_groups * steps * 2.0 * b_local * h * k * k * 4
        elif mixer == "mamba":
            di = cfg.ssm.expand * cfg.d_model
            n = cfg.ssm.d_state
            fl += cfg.n_groups * steps * 6.0 * b_local * di * n
            by += cfg.n_groups * steps * 2.0 * b_local * di * n * 4
    return {"flops": fl, "hbm_bytes": by}


def cell_costs(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
               dtype=jnp.bfloat16, q_chunk: Optional[int] = None
               ) -> Dict[str, Any]:
    """Exact per-device cost totals for one (arch x shape x mesh) cell."""
    if q_chunk is None:
        q_chunk = 1024 if shape.seq_len > 1024 else None
    n_dev = mesh.devices.size
    # gradient accumulation: the group/stem/encoder components run micro_steps
    # times on a (B / micro_steps) microbatch; the optimizer runs once
    micro = 1
    if shape.kind == "train":
        micro = max(1, cfg.micro_steps)
        while shape.global_batch % micro:
            micro //= 2
    eff_shape = dataclasses.replace(shape,
                                    global_batch=shape.global_batch // micro)
    components: List[Tuple[str, int, Tuple]] = [
        ("group", cfg.n_groups * micro,
         group_component(cfg, mesh, eff_shape, dtype, q_chunk)),
        ("stem_head", micro, stem_head_component(cfg, mesh, eff_shape, dtype)),
    ]
    enc = encoder_component(cfg, mesh, eff_shape, dtype)
    if enc is not None:
        components.append(("encoder", micro, enc))
    if shape.kind == "train":
        components.append(("optimizer", 1, optimizer_component(cfg, mesh, dtype)))

    total = {"flops": 0.0, "hbm_bytes": 0.0, "collective_bytes": 0.0}
    detail = {}
    for name, mult, (fn, structs, shards) in components:
        rec = _lower_component(fn, structs, shards)
        detail[name] = {"multiplier": mult, **rec}
        total["flops"] += mult * rec["flops"]
        total["hbm_bytes"] += mult * rec["hbm_bytes"]
        total["collective_bytes"] += mult * rec["collectives"]["total_bytes"]

    corr = _ssm_scan_correction(cfg, shape, n_dev)
    total["flops"] += corr["flops"]
    total["hbm_bytes"] += corr["hbm_bytes"]
    detail["ssm_scan_correction"] = corr
    return {"totals_per_device": total, "components": detail,
            "q_chunk": q_chunk, "n_devices": int(n_dev)}
