"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state; the dry-run sets the 512-placeholder-device XLA flag
before its first jax import, everything else sees the real devices.

Mesh shapes (TPU v5e target):
  * single-pod: (data=16, model=16)           — 256 chips
  * multi-pod:  (pod=2, data=16, model=16)    — 512 chips

Axis semantics across the framework:
  * ``pod``   — slow inter-pod links; batch (and FSDP for the 398B/671B
                archs) shard here; gradient compression targets this axis.
  * ``data``  — batch / ZeRO-1 optimizer sharding / sequence-sharded caches.
  * ``model`` — tensor parallelism + expert parallelism.
GSoFa shards *sources* over every axis flattened (paper's interleave, §V).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

# jax.sharding.AxisType landed after 0.4.x; on older jax every axis is
# implicitly Auto, so the compat builders below simply drop the argument.
from repro.compat import AXIS_TYPE as _AXIS_TYPE


def compat_make_mesh(axis_shapes: tuple, axis_names: tuple) -> Mesh:
    """jax.make_mesh with Auto axis types across jax versions."""
    if _AXIS_TYPE is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(_AXIS_TYPE.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def compat_abstract_mesh(axis_shapes: tuple, axis_names: tuple):
    """AbstractMesh (device-less) with Auto axis types across jax versions."""
    from jax.sharding import AbstractMesh

    if _AXIS_TYPE is not None:
        return AbstractMesh(axis_shapes, axis_names,
                            axis_types=(_AXIS_TYPE.Auto,) * len(axis_names))
    return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return compat_make_mesh((n // model, model), ("data", "model"))


FLAT_AXIS = "shards"


def visible_device_count() -> int:
    """Number of devices jax sees right now — what ``LUPlan.place()`` and
    the dynamic runtime default to.  A function, not a constant: forced
    host-device flags and real accelerator counts are both decided at jax
    init, per process."""
    return len(jax.devices())


def make_flat_mesh(n_devices: int | None = None) -> Mesh:
    """One-axis ``(shards,)`` mesh — the distributed analyze/factorize
    substrate (DESIGN.md §11): GSoFa shards *sources* (and the plan shards
    *panels*) over the flattened device space, so a single axis is the
    whole story at any scale.

    ``n_devices=None`` takes every visible device through the compat
    builder — the same call yields a 1-device mesh on a laptop and an
    8-device mesh under ``XLA_FLAGS=--xla_force_host_platform_device_count
    =8``, which is exactly how the conformance tier runs one code path at
    every device count.  An explicit ``n_devices`` takes a prefix of
    ``jax.devices()`` (must not exceed what exists).
    """
    avail = jax.devices()
    if n_devices is None:
        return compat_make_mesh((len(avail),), (FLAT_AXIS,))
    if not 1 <= n_devices <= len(avail):
        raise ValueError(f"n_devices={n_devices} out of range for "
                         f"{len(avail)} visible device(s)")
    if n_devices == len(avail):
        return compat_make_mesh((n_devices,), (FLAT_AXIS,))
    import numpy as np

    return Mesh(np.asarray(avail[:n_devices]), (FLAT_AXIS,))
