"""Pallas TPU kernel: blocked online-softmax (flash) attention.

The LM side of the framework is dominated by attention at the 32k/500k shapes;
this kernel is the VMEM-tiled implementation: the (Bq, D) query tile and the
running (m, l, o) statistics stay resident while (Bk, D) key/value tiles stream
through the innermost grid axis.  Softmax is computed online (never
materializing the (S, T) score matrix), which converts attention from
HBM-bandwidth-bound at long T to compute-bound — the standard FlashAttention
rescaling, blocked for the MXU (logit matmul) + VPU (rescale) split.

Layout notes for TPU: last dims are multiples of 128 (D padded by the ops.py
wrapper), second-to-last multiples of 8.  GQA is handled by the wrapper
repeating KV heads; a production variant would fold the group into the kv
index_map instead (no materialized repeat) — noted in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  t_real: int, kv_offset: int, num_k: int):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                     # (Bq, D)
    k = k_ref[0].astype(jnp.float32)                     # (Bk, D)
    v = v_ref[0].astype(jnp.float32)                     # (Bk, D)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (Bq, Bk)

    q_ids = i * block_q + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
    k_ids = j * block_k + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = k_ids < t_real                                # drop padded keys
    if causal:
        # decode/prefill against a longer cache: query s attends to cache
        # positions <= s + kv_offset
        mask = mask & (k_ids <= q_ids + kv_offset)
    logits = jnp.where(mask, logits, _NEG_INF)

    m_prev = m_ref[0]                                    # (Bq,)
    l_prev = l_ref[0]
    m_cur = jnp.max(logits, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o_ref[0] = o_ref[0] * alpha[:, None] + pv
    m_ref[0] = m_new
    l_ref[0] = l_new

    @pl.when(j == num_k - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0] = o_ref[0] / denom[:, None]


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, H, T, D) with T >= S. Returns (B, H, S, D).

    When T > S the queries are assumed to be the *last* S positions of the
    sequence (prefill continuation / decode), i.e. query s sees cache
    positions <= s + (T - S).
    """
    b, h, s, d = q.shape
    t = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    kv_offset = t - s

    def pad(x, axis, mult, value=0.0):
        p = (-x.shape[axis]) % mult
        if p == 0:
            return x
        w = [(0, 0)] * x.ndim
        w[axis] = (0, p)
        return jnp.pad(x, w, constant_values=value)

    d_pad = max(128, ((d + 127) // 128) * 128)
    block_q = min(block_q, max(8, ((s + 7) // 8) * 8))
    qq = pad(pad(q.reshape(b * h, s, d), 1, block_q), 2, d_pad)
    kk = pad(pad(k.reshape(b * h, t, d), 1, block_k), 2, d_pad)
    vv = pad(pad(v.reshape(b * h, t, d), 1, block_k), 2, d_pad)
    bh, s_pad, _ = qq.shape
    t_pad = kk.shape[1]
    num_k = t_pad // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, t_real=t, kv_offset=kv_offset, num_k=num_k)

    o, _, _ = pl.pallas_call(
        kernel,
        grid=(bh, s_pad // block_q, num_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d_pad), lambda b_, i, j: (b_, i, 0)),
            pl.BlockSpec((1, block_k, d_pad), lambda b_, i, j: (b_, j, 0)),
            pl.BlockSpec((1, block_k, d_pad), lambda b_, i, j: (b_, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d_pad), lambda b_, i, j: (b_, i, 0)),
            pl.BlockSpec((1, block_q), lambda b_, i, j: (b_, i)),
            pl.BlockSpec((1, block_q), lambda b_, i, j: (b_, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s_pad, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((bh, s_pad), jnp.float32),
            jax.ShapeDtypeStruct((bh, s_pad), jnp.float32),
        ],
        interpret=interpret,
    )(qq, kk, vv)

    return o[:, :s, :d].reshape(b, h, s, d).astype(q.dtype)
