"""Pallas TPU kernel: blocked (min, max)-semiring relaxation for GSoFa.

One GSoFa superstep is ``cand[s, v] = min_u (adj[u, v] ? prop[s, u] : INF)`` —
a "matmul" in the bottleneck semiring between the propagation matrix (S, U)
and the adjacency (U, V).  The MXU only accumulates (+, *), so this contraction
runs on the VPU; what the kernel buys is MXU-style *blocking*: each grid step
keeps a (Bs, Bu) prop tile, a (Bu, Bv) adjacency tile and the (Bs, Bv) output
accumulator resident in VMEM, and the U-dimension is the innermost grid axis so
the output tile is revisited (accumulated) without round-tripping to HBM.

This is the TPU adaptation of the paper's warp-centric frontier expansion
(DESIGN.md §2): the thread/warp-centric choice collapses into the block-shape
choice (Bs × Bv lanes per step), and the paper's atomicMin becomes the
associative min accumulation across U tiles.

Tiling constraints: last dim multiples of 128, second-to-last multiples of 8
(int32/float32 VREG shape 8 x 128).  VMEM footprint per step:
``Bs*Bu + Bu*Bv + Bs*Bv`` elements; defaults (8, 128, 256) -> ~140 KB << 16 MB
VMEM, leaving room for double buffering of the streamed tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import _inf


def _relax_kernel(prop_ref, adj_ref, out_ref, *, block_u: int, u_chunk: int):
    """Grid (S/Bs, V/Bv, U/Bu); accumulate min over the U axis (axis 2)."""
    inf = _inf(out_ref.dtype)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, inf)

    prop = prop_ref[...]            # (Bs, Bu)
    adj = adj_ref[...]              # (Bu, Bv)

    def chunk_body(c, acc):
        # Process u_chunk rows of the adjacency tile at a time: the 3-D
        # broadcast (Bs, u_chunk, Bv) stays small enough for VREGs/VMEM.
        p = jax.lax.dynamic_slice_in_dim(prop, c * u_chunk, u_chunk, axis=1)
        a = jax.lax.dynamic_slice_in_dim(adj, c * u_chunk, u_chunk, axis=0)
        masked = jnp.where(a[None, :, :] != 0, p[:, :, None], inf)
        return jnp.minimum(acc, jnp.min(masked, axis=1))

    acc = jnp.full_like(out_ref, inf)
    acc = jax.lax.fori_loop(0, block_u // u_chunk, chunk_body, acc)
    out_ref[...] = jnp.minimum(out_ref[...], acc)


@functools.partial(
    jax.jit,
    static_argnames=("block_s", "block_u", "block_v", "u_chunk", "interpret"),
)
def minmax_relax_pallas(prop: jax.Array, adj: jax.Array, *, block_s: int = 8,
                        block_u: int = 128, block_v: int = 256, u_chunk: int = 8,
                        interpret: bool = True) -> jax.Array:
    """cand[s, v] = min_u (adj[u, v] != 0 ? prop[s, u] : INF).

    prop: (S, U) int32/float32 — already clamped & source-masked (gsofa.py).
    adj:  (U, V) any integer dtype, nonzero = edge u -> v.
    Shapes must be padded to block multiples by the wrapper (ops.py).
    """
    s, u = prop.shape
    u2, v = adj.shape
    assert u == u2, (prop.shape, adj.shape)
    assert s % block_s == 0 and u % block_u == 0 and v % block_v == 0
    assert block_u % u_chunk == 0

    grid = (s // block_s, v // block_v, u // block_u)
    kernel = functools.partial(_relax_kernel, block_u=block_u, u_chunk=u_chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, block_u), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_u, block_v), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_s, block_v), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, v), prop.dtype),
        interpret=interpret,
    )(prop, adj)
