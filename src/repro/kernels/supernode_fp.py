"""Pallas TPU kernel: per-column supernode fingerprints from GSoFa labels.

Supernode detection (DESIGN.md §3) needs, for every column ``j``, a summary of
the strictly-below-diagonal structure of L's column ``j``:

    cnt[j]  = |{ i > j : filled(i, j) }|
    hsum[j] = sum  over that set of mix1(i)   (wrapping int32)
    hxor[j] = xor  over that set of mix2(i)

Row ``i`` of the filled pattern is exactly the converged label row of source
``i`` (``filled(i, v) <=> maxId[v] < v``), so the fingerprints are a *column
reduction over the source batch* — they can be accumulated chunk by chunk as
the multi-source driver (core/multisource.py) streams converged label
matrices, without ever gathering the dense n x n pattern.

The kernel follows the same VREG-shaped blocking idiom as gsofa_relax.py:
grid ``(V/Bv, S/Bs)`` with the source axis innermost, so each (8, Bv) output
tile stays resident in VMEM while the (Bs, Bv) label tiles stream past it.
The three fingerprint lanes live in rows 0..2 of an (8, V) output (the 8-row
sublane pad is free at int32 tile granularity); row 0 accumulates with ``+``,
row 1 with wrapping ``+``, row 2 with ``^`` — all associative, so the S-axis
grid accumulation is race-free by construction.

Tiling constraints: last dim multiples of 128, second-to-last multiples of 8
(int32 VREG shape 8 x 128).  VMEM per step: ``Bs*Bv + 8*Bs + 8*Bv`` int32
elements; defaults (8, 512) -> ~20 KB << 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fp_kernel(rel_ref, meta_ref, out_ref, *, block_s: int, block_v: int):
    """Grid (V/Bv, S/Bs); accumulate fingerprints over the S axis (axis 1).

    rel_ref:  (Bs, Bv) int32 — offset-free labels: maxId, or n+1 when the
              label is uninitialized/stale (precomputed by the ops.py wrapper
              so no SMEM scalar is needed in the hot loop).
    meta_ref: (8, Bs) int32 — per-source lanes: row 0 = source id, row 1 =
              mix1(source), row 2 = mix2(source), row 3 = 1 for real rows
              (0 for batch padding); rows 4..7 are sublane padding.
    out_ref:  (8, Bv) int32 — row 0 count, row 1 hash-sum, row 2 hash-xor.
    """
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rel = rel_ref[...]                                   # (Bs, Bv)
    meta = meta_ref[...]                                 # (8, Bs)
    src = meta[0, :][:, None]                            # (Bs, 1)
    m1 = meta[1, :][:, None]
    m2 = meta[2, :][:, None]
    valid = meta[3, :][:, None]

    col = (pl.program_id(0) * block_v
           + jax.lax.broadcasted_iota(jnp.int32, rel.shape, 1))
    # Theorem-1 fill test (maxId[v] < v) restricted to the strictly-lower
    # triangle (source row below the column's diagonal).
    mask = (rel < col) & (src > col) & (valid != 0)      # (Bs, Bv)

    cnt = jnp.sum(mask.astype(jnp.int32), axis=0)        # (Bv,)
    hsum = jnp.sum(jnp.where(mask, jnp.broadcast_to(m1, rel.shape), 0), axis=0)
    xor_terms = jnp.where(mask, jnp.broadcast_to(m2, rel.shape), 0)

    def xor_row(i, acc):
        return acc ^ jax.lax.dynamic_index_in_dim(
            xor_terms, i, axis=0, keepdims=False)

    hxor = jax.lax.fori_loop(0, block_s, xor_row,
                             jnp.zeros((rel.shape[1],), jnp.int32))

    row = jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 0)
    cur = out_ref[...]
    out_ref[...] = jnp.where(
        row == 0, cur + cnt[None, :],
        jnp.where(row == 1, cur + hsum[None, :],
                  jnp.where(row == 2, cur ^ hxor[None, :], cur)))


@functools.partial(
    jax.jit, static_argnames=("block_s", "block_v", "interpret"),
)
def supernode_fp_pallas(rel: jax.Array, meta: jax.Array, *, block_s: int = 8,
                        block_v: int = 512, interpret: bool = True) -> jax.Array:
    """(8, V) fingerprint accumulator from a (S, V) relative-label chunk.

    rel:  (S, V) int32 — ``maxId`` of each (source, vertex), with
          uninitialized/stale labels clamped to n+1 (> any column id).
    meta: (8, S) int32 — see ``_fp_kernel``.
    Shapes must be padded to block multiples by the wrapper (ops.py).
    """
    s, v = rel.shape
    assert meta.shape == (8, s), (meta.shape, rel.shape)
    assert s % block_s == 0 and v % block_v == 0

    grid = (v // block_v, s // block_s)
    kernel = functools.partial(_fp_kernel, block_s=block_s, block_v=block_v)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, block_v), lambda j, i: (i, j)),
            pl.BlockSpec((8, block_s), lambda j, i: (0, i)),
        ],
        out_specs=pl.BlockSpec((8, block_v), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((8, v), jnp.int32),
        interpret=interpret,
    )(rel, meta)
