"""Pallas TPU kernel: supernodal panel update on the MXU (DESIGN.md §4).

The supernodal left-looking numeric factorization (repro.numeric) applies the
accumulated updates of a target panel J as one dense GEMM over the gathered
ancestor columns:

    out = acc - L @ U

with ``acc`` the (M, N) gathered target panel rows, ``L`` the (M, K) gathered
L-panel of all ancestor supernodes, and ``U`` the (K, N) solved U-rows of
those ancestors against J.  All three operands are packed dense blocks
assembled from the CSC-panel store's row-index maps (``numeric/storage.py``
— the caller never slices an (n, n) array), and the output writes straight
back into the target panel's packed block.  Sparse LU spends almost all of
its numeric flops here, and the supernode panel shapes are exactly what the
128 x 128 MXU wants (GLU3.0-style batched dense updates).

Blocking follows the same VREG/MXU idiom as ``supernode_fp.py`` /
``gsofa_relax.py``: float32 tiles with the second-to-last dim a multiple of 8
and the last a multiple of 128.  Grid ``(M/Bm, N/Bn, K/Bk)`` with the
contraction axis innermost so the (Bm, Bn) output tile stays resident in VMEM
while the L/U tiles stream past it; the K-axis accumulation is a plain sum,
so grid accumulation is race-free.  VMEM per step:
``Bm*Bn + Bm*Bk + Bk*Bn`` float32 elements — the (128, 128, 128) defaults are
192 KB << 16 MB.

``kernels/ref.py::panel_update_ref`` is the jnp oracle
(tests/test_kernels.py asserts parity); ``ops.panel_update`` pads and
dispatches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _panel_update_kernel(acc_ref, l_ref, u_ref, out_ref):
    """Grid (M/Bm, N/Bn, K/Bk); accumulate ``acc - L @ U`` over axis 2."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = acc_ref[...]

    out_ref[...] = out_ref[...] - jnp.dot(
        l_ref[...], u_ref[...], preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def panel_update_pallas(acc: jax.Array, l_panel: jax.Array, u_panel: jax.Array,
                        *, block_m: int = 128, block_n: int = 128,
                        block_k: int = 128, interpret: bool = True) -> jax.Array:
    """(M, N) float32 ``acc - l_panel @ u_panel`` (MXU panel update).

    acc: (M, N), l_panel: (M, K), u_panel: (K, N) — all float32, padded to
    block multiples by the wrapper (ops.py); zero padding contributes zero
    products, so the slice-back is exact.
    """
    m, n = acc.shape
    k = l_panel.shape[1]
    assert l_panel.shape == (m, k) and u_panel.shape == (k, n), (
        acc.shape, l_panel.shape, u_panel.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _panel_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(acc, l_panel, u_panel)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def panel_update_batched_pallas(acc: jax.Array, l_panel: jax.Array,
                                u_panel: jax.Array, *, block_m: int = 128,
                                block_n: int = 128, block_k: int = 128,
                                interpret: bool = True) -> jax.Array:
    """(B, M, N) float32 stacked panel updates ``acc - l_panel @ u_panel``.

    The batched segment sweep (``numeric/supernodal.py``, DESIGN.md §13)
    groups every same-shape panel of a (level, device) segment into one
    stack and dispatches it here: one vmapped ``pallas_call`` whose batch
    axis becomes a leading grid dimension, so B panels cost one kernel
    launch instead of B.  Each slice runs the exact grid the per-panel
    kernel would (same blocks, same K-accumulation order), so results are
    bitwise-identical to B separate ``panel_update_pallas`` calls.
    """
    b, m, n = acc.shape
    k = l_panel.shape[2]
    assert l_panel.shape == (b, m, k) and u_panel.shape == (b, k, n), (
        acc.shape, l_panel.shape, u_panel.shape)
    f = functools.partial(panel_update_pallas, block_m=block_m,
                          block_n=block_n, block_k=block_k,
                          interpret=interpret)
    return jax.vmap(f)(acc, l_panel, u_panel)
