"""Pure-jnp oracles for the Pallas kernels (the ``ref`` side of every kernel test)."""
from __future__ import annotations

import jax.numpy as jnp


def _inf(dtype):
    """Identity of the min-reduction. For ints this must be the *maximum*
    representable value (not max//2): the GSoFa label arena (spaceopt.py)
    stores stale values from earlier windows which must never be undercut by
    the masked-out sentinel."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.inf, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


def minmax_relax_ref(prop: jnp.ndarray, adj: jnp.ndarray) -> jnp.ndarray:
    """Bottleneck-semiring relaxation oracle.

    cand[s, v] = min over u of (adj[u, v] != 0 ? prop[s, u] : INF)

    ``prop`` (S, U) already carries the GSoFa clamp max(u, maxId[u]) and the
    u < src mask (DESIGN.md §2); ``adj`` (U, V) is the dense 0/1 adjacency
    (edge u -> v).
    """
    inf = _inf(prop.dtype)
    masked = jnp.where(adj[None, :, :] != 0, prop[:, :, None], inf)
    return jnp.min(masked, axis=1)


def supernode_fp_ref(rel: jnp.ndarray, src: jnp.ndarray, m1: jnp.ndarray,
                     m2: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Column-fingerprint oracle for kernels/supernode_fp.py (DESIGN.md §3).

    rel:   (S, V) int32 relative labels: maxId, or > V for invalid/stale.
    src:   (S,) int32 source (= filled-pattern row) ids.
    m1/m2: (S,) int32 row hashes mix1(src) / mix2(src).
    valid: (S,) int32/bool, 0 for batch-padding rows.

    Returns (3, V) int32: row 0 = strictly-below-diagonal count of each
    column of L, row 1 = wrapping sum of m1 over those rows, row 2 = xor of
    m2 over those rows.
    """
    v_ids = jnp.arange(rel.shape[1], dtype=jnp.int32)
    mask = ((rel < v_ids[None, :])
            & (src[:, None] > v_ids[None, :])
            & (valid[:, None] != 0))
    cnt = jnp.sum(mask.astype(jnp.int32), axis=0)
    hsum = jnp.sum(jnp.where(mask, m1[:, None], 0), axis=0)
    hxor = jnp.bitwise_xor.reduce(jnp.where(mask, m2[:, None], 0), axis=0)
    return jnp.stack([cnt, hsum, hxor])


def panel_update_ref(acc: jnp.ndarray, l_panel: jnp.ndarray,
                     u_panel: jnp.ndarray) -> jnp.ndarray:
    """Supernodal panel-update oracle for kernels/panel_update.py
    (DESIGN.md §4): ``acc - l_panel @ u_panel`` in float32.

    acc: (M, N) gathered target-panel rows; l_panel: (M, K) gathered ancestor
    L columns; u_panel: (K, N) solved ancestor U rows.
    """
    return acc - jnp.dot(l_panel, u_panel, preferred_element_type=jnp.float32)


def mamba_scan_ref(x, dt, b_t, c_t, a, d_skip):
    """Sequential-scan oracle of kernels/ssm_scan.mamba_scan (pure jnp)."""
    import jax

    def step(h, inp):
        x_t, dt_t, bb, cc = inp
        h = h * jnp.exp(dt_t[..., None] * a[None]) \
            + (dt_t * x_t)[..., None] * bb[:, None, :]
        return h, jnp.einsum("bdn,bn->bd", h, cc) + d_skip[None] * x_t

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, b_t, c_t))
    h0 = jnp.zeros((x.shape[0], x.shape[2], a.shape[1]), jnp.float32)
    _, y = jax.lax.scan(step, h0, seq)
    return jnp.moveaxis(y, 0, 1)


def rwkv6_scan_ref(r, k, v, w, u):
    """Sequential-scan oracle of kernels/ssm_scan.rwkv6_scan (pure jnp)."""
    import jax

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                      # (BH, K)
        kv = k_t[:, :, None] * v_t[:, None, :]
        o_t = jnp.einsum("bk,bkv->bv", r_t, s + u[:, :, None] * kv)
        return s * w_t[:, :, None] + kv, o_t

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s0 = jnp.zeros((r.shape[0], r.shape[2], r.shape[2]), jnp.float32)
    _, o = jax.lax.scan(step, s0, seq)
    return jnp.moveaxis(o, 0, 1)


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """O(S^2) attention oracle for the flash-attention kernel.

    q: (B, H, S, D), k/v: (B, H, T, D). float32 math.
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    logits = jnp.einsum("bhsd,bhtd->bhst", qf, kf) * scale
    if causal:
        s, t = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((s, t), dtype=bool), k=t - s)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhst,bhtd->bhsd", probs, vf).astype(q.dtype)
