"""Pallas TPU kernels: VMEM-resident linear-recurrence (SSM) scans.

The rwkv6/mamba recurrences are elementwise updates of a per-sequence state
that is tiny (K x V per head / di x N per channel-block) but re-read every
token — on any backend that round-trips the state through HBM they are
memory-latency bound.  The TPU-native form keeps the state in VMEM scratch
across the whole time axis and streams the per-token inputs through
double-buffered tiles: per token the state traffic is zero HBM bytes, so the
layer reverts to being input-bandwidth bound (the roofline's memory term
uses this kernel's traffic model).

Two kernels:

* ``mamba_scan``:  h_t = exp(dt_t A) * h_t-1 + (dt_t x_t) (x) B_t,
                   y_t = h_t . C_t + D x_t
  grid (B, di/Bd, L/Bt), t innermost; scratch h (Bd, N) persists across the
  t-axis (sequential grid semantics), A/D tiles resident.

* ``rwkv6_scan``:  S_t = diag(w_t) S_t-1 + k_t^T v_t,
                   o_t = r_t (S_t-1 + diag(u) k_t^T v_t)
  grid (B*H, L/Bt); scratch S (K, K).

Tiling: K/N are 64/16 for the assigned archs — below the 128-lane VREG
width, so on real TPU the last dim pads to 128 (interpret mode does not
care; the ops.py wrapper passes tiles through unpadded and documents the
padding cost).  Block defaults keep VMEM per step under ~1 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_ref, *,
                  block_t: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0]                   # (Bt, Bd)
    dt = dt_ref[0]                 # (Bt, Bd)
    bb = b_ref[0]                  # (Bt, N)
    cc = c_ref[0]                  # (Bt, N)
    a = a_ref[...]                 # (Bd, N)
    dsk = d_ref[...]               # (1, Bd)

    def step(s, carry):
        h, ys = carry
        dt_s = jax.lax.dynamic_slice_in_dim(dt, s, 1, 0)[0]        # (Bd,)
        x_s = jax.lax.dynamic_slice_in_dim(x, s, 1, 0)[0]
        bb_s = jax.lax.dynamic_slice_in_dim(bb, s, 1, 0)[0]        # (N,)
        cc_s = jax.lax.dynamic_slice_in_dim(cc, s, 1, 0)[0]
        decay = jnp.exp(dt_s[:, None] * a)                         # (Bd, N)
        h = h * decay + (dt_s * x_s)[:, None] * bb_s[None, :]
        y_s = jnp.sum(h * cc_s[None, :], axis=1) + dsk[0] * x_s    # (Bd,)
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y_s[None], s, 0)
        return h, ys

    h0 = h_ref[...]
    h, ys = jax.lax.fori_loop(0, block_t, step,
                              (h0, jnp.zeros_like(x)))
    h_ref[...] = h
    y_ref[0] = ys


@functools.partial(jax.jit, static_argnames=("block_d", "block_t", "interpret"))
def mamba_scan_pallas(x, dt, b_t, c_t, a, d_skip, *, block_d: int = 512,
                      block_t: int = 128, interpret: bool = True):
    """x/dt: (B, L, di) f32; b_t/c_t: (B, L, N); a: (di, N); d_skip: (di,).
    Returns y: (B, L, di).  Shapes must divide the blocks (ops.py pads)."""
    bsz, l, di = x.shape
    n = b_t.shape[-1]
    assert di % block_d == 0 and l % block_t == 0, (x.shape, block_d, block_t)
    grid = (bsz, di // block_d, l // block_t)
    kernel = functools.partial(_mamba_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, block_t, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, block_t, n), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, block_t, n), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((block_d, n), lambda b, d, t: (d, 0)),
            pl.BlockSpec((1, block_d), lambda b, d, t: (0, d)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_d), lambda b, d, t: (b, t, d)),
        out_shape=jax.ShapeDtypeStruct((bsz, l, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, b_t, c_t, a, d_skip[None])


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                 block_t: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0]                  # (Bt, K)
    k = k_ref[0]
    v = v_ref[0]
    w = w_ref[0]
    u = u_ref[...]                # (1, K)

    def step(t, carry):
        s, os = carry
        r_t = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)[0]
        k_t = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)[0]
        v_t = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)[0]
        w_t = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)[0]
        kv = k_t[:, None] * v_t[None, :]                          # (K, K)
        o_t = jnp.sum(r_t[:, None] * (s + u[0][:, None] * kv), axis=0)
        s = s * w_t[:, None] + kv
        os = jax.lax.dynamic_update_slice_in_dim(os, o_t[None], t, 0)
        return s, os

    s0 = s_ref[...]
    s, os = jax.lax.fori_loop(0, block_t, step, (s0, jnp.zeros_like(r)))
    s_ref[...] = s
    o_ref[0] = os


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan_pallas(r, k, v, w, u, *, block_t: int = 128,
                      interpret: bool = True):
    """r/k/v/w: (BH, L, K) f32 (heads folded into batch); u: (BH, K).
    Returns o: (BH, L, K)."""
    bh, l, kk = r.shape
    assert l % block_t == 0, (l, block_t)
    grid = (bh, l // block_t)
    kernel = functools.partial(_rwkv_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block_t, kk), lambda b, t: (b, t, 0))] * 4
        + [pl.BlockSpec((1, kk), lambda b, t: (b, 0))],
        out_specs=pl.BlockSpec((1, block_t, kk), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, l, kk), r.dtype),
        scratch_shapes=[pltpu.VMEM((kk, kk), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
