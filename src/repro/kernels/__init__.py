"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles.

* ``gsofa_relax`` — bottleneck-semiring relaxation, the GSoFa hot spot.
* ``supernode_fp`` — per-column supernode fingerprints from label chunks.
* ``panel_update`` — supernodal numeric panel update (MXU GEMM-subtract).
* ``flash_attention`` — blocked online-softmax attention for the LM substrate.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
