"""Jit'd public wrappers around the Pallas kernels.

Each wrapper pads to block multiples, dispatches to the kernel (interpret mode
everywhere except real TPU), and slices the result back.  ``ref.py`` holds the
pure-jnp oracles the tests compare against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels.gsofa_relax import minmax_relax_pallas
from repro.kernels.panel_update import panel_update_pallas
from repro.kernels.supernode_fp import supernode_fp_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def padded_gemm_shape(m, k, n, *, block_m: int = 128, block_n: int = 128,
                      block_k: int = 128):
    """Padded ``(M, K, N)`` that ``panel_update`` actually dispatches for a
    logical ``m x k @ k x n`` update.

    Mirrors the block-sizing in :func:`panel_update` (sublane multiples of 8
    on M, lane multiples of 128 on K/N) so cost models can charge the
    explicit-zero MXU work instead of the logical shape.  Accepts scalars or
    numpy arrays (vectorised over candidate partitions); zero-sized operands
    stay zero since those dispatches are skipped entirely.
    """
    m_ = np.asarray(m, dtype=np.int64)
    k_ = np.asarray(k, dtype=np.int64)
    n_ = np.asarray(n, dtype=np.int64)
    bm = np.minimum(block_m, np.maximum(8, ((m_ + 7) // 8) * 8))
    bk = np.minimum(block_k, np.maximum(128, ((k_ + 127) // 128) * 128))
    bn = np.minimum(block_n, np.maximum(128, ((n_ + 127) // 128) * 128))
    mp = np.where(m_ > 0, ((m_ + bm - 1) // np.maximum(bm, 1)) * bm, 0)
    kp = np.where(k_ > 0, ((k_ + bk - 1) // np.maximum(bk, 1)) * bk, 0)
    np_ = np.where(n_ > 0, ((n_ + bn - 1) // np.maximum(bn, 1)) * bn, 0)
    dead = (m_ == 0) | (k_ == 0) | (n_ == 0)
    mp, kp, np_ = (np.where(dead, 0, x) for x in (mp, kp, np_))
    if np.isscalar(m) and np.isscalar(k) and np.isscalar(n):
        return int(mp), int(kp), int(np_)
    return mp, kp, np_


def minmax_relax(prop: jax.Array, adj: jax.Array, *, block_s: int = 8,
                 block_u: int = 128, block_v: int = 256,
                 interpret: bool | None = None) -> jax.Array:
    """Bottleneck-semiring relaxation; see gsofa_relax.py.  Pads + dispatches."""
    if interpret is None:
        interpret = not _on_tpu()
    s, u = prop.shape
    _, v = adj.shape
    inf = _ref._inf(prop.dtype)
    block_u = min(block_u, max(8, ((u + 7) // 8) * 8))
    block_v = min(block_v, max(128, ((v + 127) // 128) * 128))
    prop_p = _pad_to(_pad_to(prop, 0, block_s, inf), 1, block_u, inf)
    adj_p = _pad_to(_pad_to(adj, 0, block_u, 0), 1, block_v, 0)
    out = minmax_relax_pallas(prop_p, adj_p, block_s=block_s, block_u=block_u,
                              block_v=block_v, interpret=interpret)
    return out[:s, :v]


def minmax_relax_ref(prop: jax.Array, adj: jax.Array) -> jax.Array:
    return _ref.minmax_relax_ref(prop, adj)


def column_fingerprints(rel: jax.Array, src: jax.Array, m1: jax.Array,
                        m2: jax.Array, valid: jax.Array, *, block_s: int = 8,
                        block_v: int = 512,
                        interpret: bool | None = None) -> jax.Array:
    """(3, V) per-column supernode fingerprints; see supernode_fp.py.

    Pads the source axis to ``block_s`` (invalid rows) and the vertex axis to
    ``block_v`` (labels clamped high so padded columns read as empty), packs
    the per-source lanes into the (8, S) meta layout, and slices back.
    """
    if interpret is None:
        interpret = not _on_tpu()
    s, v = rel.shape
    block_v = min(block_v, max(128, ((v + 127) // 128) * 128))
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    rel_p = _pad_to(_pad_to(rel, 0, block_s, big), 1, block_v, big)
    sp = rel_p.shape[0]
    meta = jnp.zeros((8, sp), dtype=jnp.int32)
    meta = meta.at[0, :s].set(src.astype(jnp.int32))
    meta = meta.at[1, :s].set(m1.astype(jnp.int32))
    meta = meta.at[2, :s].set(m2.astype(jnp.int32))
    meta = meta.at[3, :s].set(valid.astype(jnp.int32))
    out = supernode_fp_pallas(rel_p, meta, block_s=block_s, block_v=block_v,
                              interpret=interpret)
    return out[:3, :v]


def column_fingerprints_ref(rel: jax.Array, src: jax.Array, m1: jax.Array,
                            m2: jax.Array, valid: jax.Array) -> jax.Array:
    return _ref.supernode_fp_ref(rel, src, m1, m2, valid)


def panel_update(acc: jax.Array, l_panel: jax.Array, u_panel: jax.Array, *,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 interpret: bool | None = None) -> jax.Array:
    """(M, N) supernodal panel update ``acc - l_panel @ u_panel``; see
    panel_update.py.  Pads all three operands with zeros (zero products leave
    the padded region inert) and slices back.  float32 — the numeric driver
    (repro.numeric) keeps its float64 path on numpy and routes the heavy GEMM
    here on TPU."""
    if interpret is None:
        interpret = not _on_tpu()
    acc = jnp.asarray(acc, jnp.float32)
    l_panel = jnp.asarray(l_panel, jnp.float32)
    u_panel = jnp.asarray(u_panel, jnp.float32)
    m, n = acc.shape
    k = l_panel.shape[1]
    if m == 0 or n == 0:
        return acc
    if k == 0:
        return acc
    block_m = min(block_m, max(8, ((m + 7) // 8) * 8))
    block_n = min(block_n, max(128, ((n + 127) // 128) * 128))
    block_k = min(block_k, max(128, ((k + 127) // 128) * 128))
    acc_p = _pad_to(_pad_to(acc, 0, block_m, 0.0), 1, block_n, 0.0)
    l_p = _pad_to(_pad_to(l_panel, 0, block_m, 0.0), 1, block_k, 0.0)
    u_p = _pad_to(_pad_to(u_panel, 0, block_k, 0.0), 1, block_n, 0.0)
    out = panel_update_pallas(acc_p, l_p, u_p, block_m=block_m,
                              block_n=block_n, block_k=block_k,
                              interpret=interpret)
    return out[:m, :n]


def panel_update_ref(acc, l_panel, u_panel):
    return _ref.panel_update_ref(jnp.asarray(acc, jnp.float32),
                                 jnp.asarray(l_panel, jnp.float32),
                                 jnp.asarray(u_panel, jnp.float32))


def panel_update_batched(acc: jax.Array, l_panel: jax.Array,
                         u_panel: jax.Array, *, block_m: int = 128,
                         block_n: int = 128, block_k: int = 128,
                         interpret: bool | None = None) -> jax.Array:
    """(B, M, N) stacked supernodal panel updates in ONE kernel launch; see
    ``panel_update_batched_pallas``.  Pads the trailing dims with the exact
    block sizes the per-panel ``panel_update`` wrapper would pick for
    (M, N, K), so every slice is bitwise-identical to its own per-panel
    dispatch — the batched segment sweep's conformance contract."""
    from repro.kernels.panel_update import panel_update_batched_pallas

    if interpret is None:
        interpret = not _on_tpu()
    acc = jnp.asarray(acc, jnp.float32)
    l_panel = jnp.asarray(l_panel, jnp.float32)
    u_panel = jnp.asarray(u_panel, jnp.float32)
    b, m, n = acc.shape
    k = l_panel.shape[2]
    if b == 0 or m == 0 or n == 0 or k == 0:
        return acc
    block_m = min(block_m, max(8, ((m + 7) // 8) * 8))
    block_n = min(block_n, max(128, ((n + 127) // 128) * 128))
    block_k = min(block_k, max(128, ((k + 127) // 128) * 128))
    acc_p = _pad_to(_pad_to(acc, 1, block_m, 0.0), 2, block_n, 0.0)
    l_p = _pad_to(_pad_to(l_panel, 1, block_m, 0.0), 2, block_k, 0.0)
    u_p = _pad_to(_pad_to(u_panel, 1, block_k, 0.0), 2, block_n, 0.0)
    out = panel_update_batched_pallas(acc_p, l_p, u_p, block_m=block_m,
                                      block_n=block_n, block_k=block_k,
                                      interpret=interpret)
    return out[:, :m, :n]


def panel_update_systems(acc, l_panel, u_panel, *,
                         interpret: bool | None = None) -> jax.Array:
    """Stacked panel updates with arbitrary leading batch axes — the
    many-matrix tier's GEMM entry point (DESIGN.md §14).

    ``acc`` is (..., M, N), ``l_panel`` (..., M, K), ``u_panel`` (..., K, N);
    every leading axis (systems, same-shape panel groups, or both) is
    flattened into the one stacked-batch axis ``panel_update_batched``
    already launches over, so a (B_systems, M, N) system batch and a
    (B_systems, G, M, N) system-x-group batch reuse the same single Pallas
    dispatch — and every slice stays bitwise-identical to its own
    per-panel ``panel_update`` call (the vmap per-slice grid parity that
    the within-plan segment batching relies on)."""
    acc = jnp.asarray(acc, jnp.float32)
    l_panel = jnp.asarray(l_panel, jnp.float32)
    u_panel = jnp.asarray(u_panel, jnp.float32)
    lead = acc.shape[:-2]
    m, n = acc.shape[-2:]
    k = l_panel.shape[-1]
    out = panel_update_batched(acc.reshape((-1, m, n)),
                               l_panel.reshape((-1, m, k)),
                               u_panel.reshape((-1, k, n)),
                               interpret=interpret)
    return out.reshape(lead + (m, n))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """Blocked online-softmax attention; see flash_attention.py."""
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)


def mamba_scan(x, dt, b_t, c_t, a, d_skip, *, block_d: int = 512,
               block_t: int = 128, interpret: bool | None = None):
    """VMEM-resident selective scan; see ssm_scan.py.  Pads L/di to blocks."""
    from repro.kernels.ssm_scan import mamba_scan_pallas
    if interpret is None:
        interpret = not _on_tpu()
    bsz, l, di = x.shape
    block_d = min(block_d, di)
    block_t = min(block_t, max(8, l))
    def padded(t, axis, mult):
        return _pad_to(t, axis, mult, 0.0)
    xp = padded(padded(x, 1, block_t), 2, block_d)
    dtp = padded(padded(dt, 1, block_t), 2, block_d)
    btp = padded(b_t, 1, block_t)
    ctp = padded(c_t, 1, block_t)
    ap = _pad_to(a, 0, block_d, -1.0)
    dp = _pad_to(d_skip, 0, block_d, 0.0)
    y = mamba_scan_pallas(xp, dtp, btp, ctp, ap, dp, block_d=block_d,
                          block_t=block_t, interpret=interpret)
    return y[:, :l, :di]


def mamba_scan_ref(x, dt, b_t, c_t, a, d_skip):
    return _ref.mamba_scan_ref(x, dt, b_t, c_t, a, d_skip)


def rwkv6_scan(r, k, v, w, u, *, block_t: int = 128,
               interpret: bool | None = None):
    """VMEM-resident rwkv6 time-mix recurrence; see ssm_scan.py."""
    from repro.kernels.ssm_scan import rwkv6_scan_pallas
    if interpret is None:
        interpret = not _on_tpu()
    bh, l, kk = r.shape
    block_t = min(block_t, max(8, l))
    rp, kp, vp = (_pad_to(t, 1, block_t, 0.0) for t in (r, k, v))
    wp = _pad_to(w, 1, block_t, 1.0)
    o = rwkv6_scan_pallas(rp, kp, vp, wp, u, block_t=block_t,
                          interpret=interpret)
    return o[:, :l]


def rwkv6_scan_ref(r, k, v, w, u):
    return _ref.rwkv6_scan_ref(r, k, v, w, u)
