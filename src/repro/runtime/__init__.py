"""Distributed runtime substrate: fault tolerance, straggler mitigation,
gradient compression, manual compute/communication overlap."""
