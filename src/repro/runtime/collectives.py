"""Explicit collectives: chunked ring all-reduce with optional int8
compression — the distributed-optimization layer for slow (cross-pod) links.

GSPMD's automatic all-reduce is optimal on fast ICI; across pods the links
are the bottleneck and two classic tricks apply:

* **chunked ring** (``ppermute``): the reduce-scatter/all-gather ring is
  expressed explicitly so each chunk's transfer overlaps the reduction of
  the previous chunk (XLA pipelines successive ppermutes), and so we can
  transform the payload per hop;
* **int8 payload** with per-chunk scales: 4x fewer bytes over the link at
  the cost of quantization error on partial sums — pair with error feedback
  (train/compress.py) at the caller.

``ring_allreduce`` runs inside ``shard_map`` over one mesh axis.  With
``compress=True`` the wire format of every hop is (int8 payload, f32
scale); accumulation happens in f32 after dequantize, so error does not
compound multiplicatively with ring length.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.train.compress import dequantize, quantize


def _ring_allreduce_local(x: jax.Array, axis_name: str, *,
                          compress: bool = False) -> jax.Array:
    """Reduce-scatter + all-gather ring over ``axis_name`` (inside shard_map).

    x: (n*chunk,) flat per-device values (same logical tensor everywhere);
    returns the all-reduced tensor.
    """
    n = axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    if n == 1:
        return x
    chunks = x.reshape(n, -1)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def wire(v):
        if not compress:
            return v, jnp.float32(0)
        q, s = quantize(v)
        return q, s

    def unwire(q, s):
        return dequantize(q, s) if compress else q

    # --- reduce-scatter: after n-1 hops, device d owns the full sum of
    # chunk (d+1) % n ---
    def rs_body(i, acc):
        # send the partial sum of chunk (me - i), receive (me - i - 1)
        idx = (me - i) % n
        send = acc[idx]
        q, s = wire(send)
        q_r = jax.lax.ppermute(q, axis_name, perm)
        s_r = jax.lax.ppermute(s, axis_name, perm)
        recv = unwire(q_r, s_r).astype(acc.dtype)
        tgt = (me - i - 1) % n
        return acc.at[tgt].add(recv)

    acc = jax.lax.fori_loop(0, n - 1, rs_body, chunks.astype(jnp.float32))

    # --- all-gather: circulate the owned (fully reduced) chunks ---
    def ag_body(i, acc):
        idx = (me + 1 - i) % n
        send = acc[idx]
        q, s = wire(send)
        q_r = jax.lax.ppermute(q, axis_name, perm)
        s_r = jax.lax.ppermute(s, axis_name, perm)
        recv = unwire(q_r, s_r).astype(acc.dtype)
        tgt = (me - i) % n
        return acc.at[tgt].set(recv)

    acc = jax.lax.fori_loop(0, n - 1, ag_body, acc)
    return acc.reshape(x.shape).astype(x.dtype)


def make_ring_allreduce(mesh: Mesh, axis: str, *, compress: bool = False):
    """Jitted ring all-reduce.

    Input: (n, k) sharded on dim 0 over ``axis`` — one summand per device.
    Output: (n, k) sharded the same way, every row holding the full sum
    (i.e. each device's local copy of the all-reduced tensor).
    """
    n = mesh.shape[axis]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=P(axis, None), out_specs=P(axis, None))
    def body(x_local):                       # (1, k) on each device
        flat = x_local.reshape(-1)
        pad = (-flat.shape[0]) % n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        out = _ring_allreduce_local(flat, axis, compress=compress)
        return out[: x_local.size].reshape(x_local.shape)

    return jax.jit(body)
