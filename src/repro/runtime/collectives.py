"""Explicit collectives: chunked ring all-reduce with optional int8
compression — the distributed-optimization layer for slow (cross-pod) links.

GSPMD's automatic all-reduce is optimal on fast ICI; across pods the links
are the bottleneck and two classic tricks apply:

* **chunked ring** (``ppermute``): the reduce-scatter/all-gather ring is
  expressed explicitly so each chunk's transfer overlaps the reduction of
  the previous chunk (XLA pipelines successive ppermutes), and so we can
  transform the payload per hop;
* **int8 payload** with per-chunk scales: 4x fewer bytes over the link at
  the cost of quantization error on partial sums — pair with error feedback
  (train/compress.py) at the caller.

``ring_allreduce`` runs inside ``shard_map`` over one mesh axis.  With
``compress=True`` the wire format of every hop is (int8 payload, f32
scale); accumulation happens in f32 after dequantize, so error does not
compound multiplicatively with ring length.

The reduction ``op`` generalizes beyond ``add``: supernode fingerprint
shards (supernodes/fingerprint.py) merge with *mixed* reductions — counts
and hash-sums by wrapping integer addition, the xor hash by ``xor``, and
the subdiagonal/seen flags by ``max`` (boolean or).  All three are
associative and commutative, so the same reduce-scatter/all-gather ring
applies unchanged; ``merge_fingerprint_shards`` stacks the per-shard
accumulator arrays and runs one ring per accumulator — this is the
device-side merge path of distributed supernode detection
(core/distributed.py), with ``ColumnFingerprints.merge`` as its host
oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import axis_size, shard_map

_RING_OPS = ("add", "xor", "max")


def quantize(g: jax.Array) -> "tuple[jax.Array, jax.Array]":
    """Int8 wire format of one ring hop: max-abs/127 scale, symmetric
    rounding.  The live sparse runtime owns its wire codec (the train tree
    keeps an identical pair for its optimizer-boundary demo — the runtime
    must not depend on that substrate)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _combine(op: str, a: jax.Array, b: jax.Array) -> jax.Array:
    if op == "add":
        return a + b
    if op == "xor":
        return jnp.bitwise_xor(a, b)
    return jnp.maximum(a, b)


def _ring_allreduce_local(x: jax.Array, axis_name: str, *,
                          compress: bool = False,
                          op: str = "add") -> jax.Array:
    """Reduce-scatter + all-gather ring over ``axis_name`` (inside shard_map).

    x: (n*chunk,) flat per-device values (same logical tensor everywhere);
    returns the all-reduced tensor.  ``op`` picks the (associative,
    commutative) combine; int8 compression only composes with ``add``
    (quantizing xor/max payloads would corrupt exact bit reductions).
    """
    if op not in _RING_OPS:
        raise ValueError(f"unknown ring op {op!r}; pick from {_RING_OPS}")
    if compress and op != "add":
        raise ValueError(f"int8 compression only supports op='add', "
                         f"got {op!r}")
    n = axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    if n == 1:
        return x
    chunks = x.reshape(n, -1)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def wire(v):
        if not compress:
            return v, jnp.float32(0)
        q, s = quantize(v)
        return q, s

    def unwire(q, s):
        return dequantize(q, s) if compress else q

    # --- reduce-scatter: after n-1 hops, device d owns the full reduction
    # of chunk (d+1) % n ---
    def rs_body(i, acc):
        # send the partial reduction of chunk (me - i), receive (me - i - 1)
        idx = (me - i) % n
        send = acc[idx]
        q, s = wire(send)
        q_r = jax.lax.ppermute(q, axis_name, perm)
        s_r = jax.lax.ppermute(s, axis_name, perm)
        recv = unwire(q_r, s_r).astype(acc.dtype)
        tgt = (me - i - 1) % n
        return acc.at[tgt].set(_combine(op, acc[tgt], recv))

    # compressed rings accumulate in f32 after dequantize; exact rings
    # (incl. the integer fingerprint merges) stay in the payload dtype
    acc0 = chunks.astype(jnp.float32) if compress else chunks
    acc = jax.lax.fori_loop(0, n - 1, rs_body, acc0)

    # --- all-gather: circulate the owned (fully reduced) chunks ---
    def ag_body(i, acc):
        idx = (me + 1 - i) % n
        send = acc[idx]
        q, s = wire(send)
        q_r = jax.lax.ppermute(q, axis_name, perm)
        s_r = jax.lax.ppermute(s, axis_name, perm)
        recv = unwire(q_r, s_r).astype(acc.dtype)
        tgt = (me - i) % n
        return acc.at[tgt].set(recv)

    acc = jax.lax.fori_loop(0, n - 1, ag_body, acc)
    return acc.reshape(x.shape).astype(x.dtype)


def make_ring_allreduce(mesh: Mesh, axis: str, *, compress: bool = False,
                        op: str = "add"):
    """Jitted ring all-reduce.

    Input: (n, k) sharded on dim 0 over ``axis`` — one summand per device.
    Output: (n, k) sharded the same way, every row holding the full
    reduction (i.e. each device's local copy of the all-reduced tensor).
    ``op``: "add" (default), "xor", or "max" — the ring pads with 0, the
    identity of all three on the non-negative payloads used here.
    """
    n = mesh.shape[axis]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=P(axis, None), out_specs=P(axis, None))
    def body(x_local):                       # (1, k) on each device
        flat = x_local.reshape(-1)
        pad = (-flat.shape[0]) % n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        out = _ring_allreduce_local(flat, axis, compress=compress, op=op)
        return out[: x_local.size].reshape(x_local.shape)

    return jax.jit(body)


# ---------------------------------------------------------------------------
# distributed supernode-fingerprint merge (core/distributed.py analyze path)
# ---------------------------------------------------------------------------

def merge_fingerprint_shards(mesh: Mesh, axis: str, shards):
    """Merge per-shard ``ColumnFingerprints`` through device-side ring
    collectives: counts/hsum by wrapping integer ``add``, hxor by ``xor``,
    subdiag/seen by ``max`` (boolean or).

    ``shards`` is one ``ColumnFingerprints`` per device on the ``axis``
    (disjoint sources by construction — the distributed driver masks shard
    ownership before accumulating).  Returns a merged ``ColumnFingerprints``
    bitwise-equal to folding the shards on the host with
    ``ColumnFingerprints.merge`` (the property-tested oracle).  On a
    1-device mesh the rings are identity, so the single-device and
    multi-device analyze paths are literally the same code.
    """
    from repro.supernodes.fingerprint import ColumnFingerprints

    d = mesh.shape[axis]
    if len(shards) != d:
        raise ValueError(f"got {len(shards)} fingerprint shards for a "
                         f"{d}-device '{axis}' axis")
    n = shards[0].n
    # jax without x64 carries 32-bit integers: counts fit (<= n), and the
    # uint32 hashes wrap identically in int32 two's complement
    stack = {
        "counts": np.stack([s.counts for s in shards]).astype(np.int32),
        "hsum": np.stack([s.hsum.view(np.int32) for s in shards]),
        "hxor": np.stack([s.hxor.view(np.int32) for s in shards]),
        "subdiag": np.stack([s.subdiag for s in shards]).astype(np.int32),
        "seen": np.stack([s.seen for s in shards]).astype(np.int32),
    }
    ops = {"counts": "add", "hsum": "add", "hxor": "xor",
           "subdiag": "max", "seen": "max"}
    merged = ColumnFingerprints(n=n)
    rings = {op: make_ring_allreduce(mesh, axis, op=op)
             for op in set(ops.values())}
    for name, arr in stack.items():
        out = np.asarray(rings[ops[name]](jnp.asarray(arr)))[0]
        if name == "counts":
            merged.counts = out.astype(np.int64)
        elif name == "hsum":
            merged.hsum = out.view(np.uint32).copy()
        elif name == "hxor":
            merged.hxor = out.view(np.uint32).copy()
        elif name == "subdiag":
            merged.subdiag = out.astype(bool)
        else:
            merged.seen = out.astype(bool)
    return merged
