"""Dynamic chunk scheduler: work stealing + straggler re-issue + elastic
scaling, plan-integrated (DESIGN.md §13).

The SPMD shard_map path (core.distributed) assigns sources statically; on a
real 1,000-GPU run, stragglers (slow/failed nodes) break static balance.  This
host-driven scheduler treats source chunks as a work queue over the *same*
chunk-step closure the static drivers run (``core.distributed.make_chunk_
step``): each completed chunk streams its converged label matrix and fill
mask back to the host, so supernode fingerprints and the sparse pattern
accumulate exactly as in ``run_multisource`` / ``distributed_multisource`` —
which is what lets ``repro.analyze`` itself run on this scheduler
(``LUOptions(runtime="dynamic")``, ``core.symbolic``).

* each device pulls the next chunk when its previous one completes (work
  stealing — the fast devices naturally absorb the straggler's queue; a pull
  of a chunk whose round-robin home is another device counts as a *steal*);
* a chunk whose device exceeds ``timeout_factor`` x the median chunk time is
  re-issued to an idle device (speculative re-execution; per-source fixpoints
  are unique and collector updates idempotent, so duplicates are harmless —
  and once any copy completes, the superseded flights are *retired* so their
  devices rejoin the idle pool instead of serving a dead race);
* devices can join/leave between chunks (elastic scaling) — the queue is
  indifferent to the device count;
* completed chunks go through the ChunkCheckpointer, so a full restart
  resumes pending work only.

Steal/re-issue/retire counts are reported both in the return dict and — when
tracing is enabled — as ``runtime.steals`` / ``runtime.reissues`` /
``runtime.retired`` counters in the obs registry; the whole drain loop runs
under a ``runtime`` span.

JAX dispatch is async: ``device_put`` + jitted call returns immediately and we
poll readiness via ``is_ready()`` on the output buffers.  Results are
delivered to the collectors exactly once per chunk (first copy wins), and
every per-source fixpoint is unique, so counts, fingerprints, and patterns
are bitwise-identical to the static drivers regardless of device count,
completion order, steals, or duplicated flights.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import make_chunk_step
from repro.core.gsofa import SymbolicGraph
from repro.core.symbolic import ChunkCheckpointer
from repro.obs import metrics as _om
from repro.obs import trace as _ot


@dataclasses.dataclass
class _InFlight:
    chunk_id: int
    srcs: np.ndarray             # unpadded sources of this chunk
    started: float
    outs: tuple                  # (labels, mask, l, u, edges, iters) futures


class DynamicScheduler:
    """Work-stealing scheduler over a set of JAX devices.

    ``on_chunk(labels, srcs, offset)`` receives each chunk's converged
    (G, n) label matrix exactly once (``ColumnFingerprints.update`` shape);
    ``on_mask(mask, srcs)`` the matching bool fill masks
    (``PatternCollector.update`` shape).  ``devices`` may repeat a physical
    device to model independent executor slots (tests use this to exercise
    steals and re-issues on a single-CPU host).
    """

    def __init__(self, graph: SymbolicGraph, *, devices: Optional[Sequence] = None,
                 concurrency: int = 64, backend: str = "ell",
                 timeout_factor: float = 4.0,
                 checkpointer: Optional[ChunkCheckpointer] = None,
                 on_chunk: Optional[Callable] = None,
                 on_mask: Optional[Callable] = None):
        self.graph = graph
        self.devices = list(devices if devices is not None else jax.devices())
        self.concurrency = concurrency
        self.backend = backend
        self.timeout_factor = timeout_factor
        self.ckpt = checkpointer
        self.on_chunk = on_chunk
        self.on_mask = on_mask
        self._step = make_chunk_step(graph.n, backend=backend)
        self._graphs: Dict[int, SymbolicGraph] = {}
        self._chunk_times: List[float] = []
        self.steals = 0
        self.reissues = 0
        self.retired = 0

    def _graph_on(self, dev) -> SymbolicGraph:
        key = id(dev)
        if key not in self._graphs:
            self._graphs[key] = jax.device_put(self.graph, dev)
        return self._graphs[key]

    def _launch(self, dev, chunk_id: int, srcs: np.ndarray) -> _InFlight:
        g = self._graph_on(dev)
        pad = self.concurrency - len(srcs)
        padded = (np.concatenate([srcs, np.full(pad, srcs[-1], np.int32)])
                  if pad else srcs)
        sj = jax.device_put(jnp.asarray(padded, jnp.int32), dev)
        outs = self._step(sj, g)
        return _InFlight(chunk_id=chunk_id, srcs=srcs,
                         started=time.perf_counter(), outs=outs)

    @staticmethod
    def _ready(flight: _InFlight) -> bool:
        try:
            return all(o.is_ready() for o in flight.outs)
        except AttributeError:  # older jax: block (still correct, less async)
            return True

    def run(self, *, drop_devices_after: Optional[int] = None,
            join_devices_after: Optional[int] = None) -> dict:
        """Process all chunks.

        ``drop_devices_after``: after N completed chunks, shrink to one
        device; ``join_devices_after``: start on one device and activate
        the rest after N completed chunks (elastic leave/join simulation
        for tests — the queue never cares how many devices are active).
        """
        if not _ot.ENABLED:
            return self._run(drop_devices_after, join_devices_after)
        with _ot.span("runtime"):
            return self._run(drop_devices_after, join_devices_after)

    def _run(self, drop_devices_after: Optional[int],
             join_devices_after: Optional[int]) -> dict:
        n = self.graph.n
        n_dev = len(self.devices)
        chunk_starts = list(range(0, n, self.concurrency))
        queue: collections.deque[int] = collections.deque()
        l_counts = np.zeros(n, dtype=np.int64)
        u_counts = np.zeros(n, dtype=np.int64)
        edge_checks = np.zeros(n, dtype=np.int64)
        for ci, start in enumerate(chunk_starts):
            srcs = np.arange(start, min(start + self.concurrency, n))
            # coverage is per source, not per grid start: a checkpoint
            # recorded under a different concurrency still restarts correctly
            # (a partially-covered chunk recomputes, which is idempotent)
            if self.ckpt is not None and self.ckpt.covered[srcs].all():
                continue
            queue.append(ci)
        if self.ckpt is not None:
            self.ckpt.restore_into(l_counts, u_counts)

        inflight: Dict[int, _InFlight] = {}   # device idx -> flight
        done_chunks: set[int] = set()
        completed = 0
        supersteps = 0
        active_devices = (list(range(n_dev)) if join_devices_after is None
                          else [0])

        def srcs_of(ci: int) -> np.ndarray:
            s = chunk_starts[ci]
            return np.arange(s, min(s + self.concurrency, n), dtype=np.int32)

        def consume(fl: _InFlight) -> None:
            """Deliver one chunk's results exactly once (first copy wins)."""
            nonlocal completed, supersteps
            labels, mask, l, u, edges, iters = (np.asarray(o)
                                                for o in fl.outs)
            k = len(fl.srcs)
            l_counts[fl.srcs] = l[:k]
            u_counts[fl.srcs] = u[:k]
            edge_checks[fl.srcs] = edges[:k]
            if self.on_chunk is not None:
                self.on_chunk(labels[:k], fl.srcs, 0)
            if self.on_mask is not None:
                self.on_mask(mask[:k], fl.srcs)
            supersteps += int(iters)
            done_chunks.add(fl.chunk_id)
            completed += 1
            self._chunk_times.append(time.perf_counter() - fl.started)
            if self.ckpt is not None:
                self.ckpt.record(chunk_starts[fl.chunk_id], fl.srcs,
                                 l[:k], u[:k])

        while queue or inflight:
            # fill idle devices; pulling a chunk whose round-robin home
            # device differs is a steal (static assignment would have put
            # chunk ci on device ci % n_dev)
            for d in list(active_devices):
                if d not in inflight and queue:
                    ci = queue.popleft()
                    if ci in done_chunks:
                        continue
                    if n_dev > 1 and ci % n_dev != d:
                        self.steals += 1
                    inflight[d] = self._launch(self.devices[d], ci, srcs_of(ci))
            if not inflight:
                break
            # poll
            progressed = False
            for d, fl in list(inflight.items()):
                if d not in inflight:          # retired this sweep
                    continue
                if self._ready(fl):
                    if fl.chunk_id not in done_chunks:
                        consume(fl)
                        # retire superseded duplicate flights: the race is
                        # decided, so losers must not keep occupying devices
                        for d2, fl2 in list(inflight.items()):
                            if d2 != d and fl2.chunk_id == fl.chunk_id:
                                del inflight[d2]
                                self.retired += 1
                        if (drop_devices_after is not None
                                and completed >= drop_devices_after
                                and len(active_devices) > 1):
                            active_devices = active_devices[:1]  # shrink
                        if (join_devices_after is not None
                                and completed >= join_devices_after
                                and len(active_devices) < n_dev):
                            active_devices = list(range(n_dev))   # join
                    del inflight[d]
                    progressed = True
                elif self._chunk_times:
                    # straggler: re-issue to an idle device (speculative)
                    med = float(np.median(self._chunk_times))
                    racing = any(f.chunk_id == fl.chunk_id
                                 for x, f in inflight.items() if x != d)
                    if (time.perf_counter() - fl.started > self.timeout_factor * med
                            and fl.chunk_id not in done_chunks and not racing):
                        idle = [x for x in active_devices if x not in inflight]
                        if idle:
                            self.reissues += 1
                            inflight[idle[0]] = self._launch(
                                self.devices[idle[0]], fl.chunk_id, fl.srcs)
            if not progressed:
                time.sleep(0.001)

        if _ot.ENABLED:
            reg = _om.registry()
            reg.count("runtime.steals", self.steals)
            reg.count("runtime.reissues", self.reissues)
            reg.count("runtime.retired", self.retired)
            reg.count("runtime.chunks", completed)

        return {"l_counts": l_counts, "u_counts": u_counts,
                "edge_checks": edge_checks,
                "chunks": len(chunk_starts), "completed": completed,
                "supersteps": supersteps,
                "steals": self.steals, "reissues": self.reissues,
                "retired": self.retired, "chunk_times": self._chunk_times}
