"""Dynamic chunk scheduler: straggler mitigation + elastic scaling for GSoFa.

The SPMD shard_map path (core.distributed) assigns sources statically; on a
real 1,000-GPU run, stragglers (slow/failed nodes) break static balance.  This
host-driven scheduler treats source chunks as a work queue:

* each device pulls the next chunk when its previous one completes (work
  stealing — the fast devices naturally absorb the straggler's queue);
* a chunk whose device exceeds ``timeout_factor`` x the median chunk time is
  re-issued to an idle device (speculative re-execution; results are
  idempotent so duplicates are harmless);
* devices can join/leave between chunks (elastic scaling) — the queue is
  indifferent to the device count;
* completed chunks go through the ChunkCheckpointer, so a full restart
  resumes pending work only.

JAX dispatch is async: ``device_put`` + jitted call returns immediately and we
poll readiness via ``is_ready()`` on the output buffers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gsofa import SymbolicGraph, gsofa_batch, row_counts
from repro.core.symbolic import ChunkCheckpointer


@dataclasses.dataclass
class _InFlight:
    chunk_id: int
    srcs: np.ndarray
    started: float
    fut_l: jax.Array
    fut_u: jax.Array


class DynamicScheduler:
    """Work-stealing scheduler over a set of JAX devices."""

    def __init__(self, graph: SymbolicGraph, *, devices: Optional[Sequence] = None,
                 concurrency: int = 64, backend: str = "ell",
                 timeout_factor: float = 4.0,
                 checkpointer: Optional[ChunkCheckpointer] = None):
        self.graph = graph
        self.devices = list(devices if devices is not None else jax.devices())
        self.concurrency = concurrency
        self.backend = backend
        self.timeout_factor = timeout_factor
        self.ckpt = checkpointer
        self._graphs: Dict[int, SymbolicGraph] = {}
        self._chunk_times: List[float] = []
        self.reissues = 0

    def _graph_on(self, dev) -> SymbolicGraph:
        key = id(dev)
        if key not in self._graphs:
            self._graphs[key] = jax.device_put(self.graph, dev)
        return self._graphs[key]

    def _launch(self, dev, chunk_id: int, srcs: np.ndarray) -> _InFlight:
        g = self._graph_on(dev)
        pad = self.concurrency - len(srcs)
        padded = np.concatenate([srcs, np.full(pad, srcs[-1], np.int32)]) if pad else srcs
        sj = jax.device_put(jnp.asarray(padded, jnp.int32), dev)
        res = gsofa_batch(g, sj, backend=self.backend)
        l, u = row_counts(res.labels, sj)
        return _InFlight(chunk_id=chunk_id, srcs=srcs, started=time.perf_counter(),
                         fut_l=l, fut_u=u)

    @staticmethod
    def _ready(flight: _InFlight) -> bool:
        try:
            return flight.fut_l.is_ready() and flight.fut_u.is_ready()
        except AttributeError:  # older jax: block (still correct, less async)
            return True

    def run(self, *, drop_devices_after: Optional[int] = None) -> dict:
        """Process all chunks. ``drop_devices_after``: after N completed chunks,
        shrink to one device (elastic-scaling simulation for tests)."""
        n = self.graph.n
        chunk_starts = list(range(0, n, self.concurrency))
        queue: List[int] = []
        l_counts = np.zeros(n, dtype=np.int64)
        u_counts = np.zeros(n, dtype=np.int64)
        for ci, start in enumerate(chunk_starts):
            srcs = np.arange(start, min(start + self.concurrency, n))
            # coverage is per source, not per grid start: a checkpoint
            # recorded under a different concurrency still restarts correctly
            # (a partially-covered chunk recomputes, which is idempotent)
            if self.ckpt is not None and self.ckpt.covered[srcs].all():
                continue
            queue.append(ci)
        if self.ckpt is not None:
            self.ckpt.restore_into(l_counts, u_counts)

        inflight: Dict[int, _InFlight] = {}   # device idx -> flight
        done_chunks: set[int] = set()
        completed = 0
        active_devices = list(range(len(self.devices)))

        def srcs_of(ci: int) -> np.ndarray:
            s = chunk_starts[ci]
            return np.arange(s, min(s + self.concurrency, n), dtype=np.int32)

        while queue or inflight:
            # fill idle devices
            for d in list(active_devices):
                if d not in inflight and queue:
                    ci = queue.pop(0)
                    if ci in done_chunks:
                        continue
                    inflight[d] = self._launch(self.devices[d], ci, srcs_of(ci))
            if not inflight:
                break
            # poll
            progressed = False
            for d, fl in list(inflight.items()):
                if self._ready(fl):
                    if fl.chunk_id not in done_chunks:
                        l = np.asarray(fl.fut_l)[: len(fl.srcs)]
                        u = np.asarray(fl.fut_u)[: len(fl.srcs)]
                        l_counts[fl.srcs] = l
                        u_counts[fl.srcs] = u
                        done_chunks.add(fl.chunk_id)
                        completed += 1
                        self._chunk_times.append(time.perf_counter() - fl.started)
                        if self.ckpt is not None:
                            self.ckpt.record(chunk_starts[fl.chunk_id], fl.srcs, l, u)
                        if (drop_devices_after is not None
                                and completed >= drop_devices_after
                                and len(active_devices) > 1):
                            active_devices = active_devices[:1]  # elastic shrink
                    del inflight[d]
                    progressed = True
                elif self._chunk_times:
                    # straggler: re-issue to an idle device (speculative)
                    med = float(np.median(self._chunk_times))
                    if (time.perf_counter() - fl.started > self.timeout_factor * med
                            and fl.chunk_id not in done_chunks):
                        idle = [x for x in active_devices if x not in inflight]
                        if idle:
                            self.reissues += 1
                            inflight[idle[0]] = self._launch(
                                self.devices[idle[0]], fl.chunk_id, fl.srcs)
            if not progressed:
                time.sleep(0.001)

        return {"l_counts": l_counts, "u_counts": u_counts,
                "chunks": len(chunk_starts), "reissues": self.reissues,
                "chunk_times": self._chunk_times}
