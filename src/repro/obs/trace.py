"""Nested span tracing for the LU pipeline (DESIGN.md §12).

Zero-overhead-when-disabled is the design contract: every instrumentation
site in the pipeline calls ``span("name")``, and when tracing is off that
call is a module-level boolean check returning a cached no-op context
manager — no ``Span`` allocation, no ``perf_counter`` read, no lock.  The
tier-1 bitwise gates and the committed bench ratio gates therefore see the
instrumented code paths unchanged.

When enabled (``tracing(path=...)``, ``enable()``, or
``LUOptions(trace=True)``) the active ``Tracer`` records one *complete*
event per span — name, start, duration, track, nesting depth — with a
per-thread span stack (``threading.local``) so the chunk driver's worker
threads and the per-device segment sweeps each get coherent nesting, and a
single lock protecting only the append to the shared event list.

Exports:

* Chrome trace-event JSON (``Tracer.export_chrome`` / ``write_chrome``):
  ``ph="X"`` complete events with microsecond ``ts``/``dur``, one ``pid``
  per track (``track="device 3"`` spans land on their own Perfetto track,
  named via ``"M"`` metadata events).
* A picklable summary tree (``Tracer.summary`` -> ``SpanSummary``):
  spans aggregated by (depth, name) path with call counts and total
  seconds, rendered as an indented text tree — this is what
  ``LUPlan.stats`` / ``LUFactorization.stats`` carry.
* Flat phase totals (``Tracer.phase_totals``) for the bench ``metrics``
  blocks.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

ENABLED = False                 # module-level hot-path gate — read, not called
_TRACER: Optional["Tracer"] = None
_LOCK = threading.Lock()

_MAIN_TRACK = "main"


@dataclasses.dataclass
class SpanEvent:
    """One closed span, times in seconds relative to the tracer epoch."""

    name: str
    start: float
    dur: float
    track: str
    depth: int
    tid: int


class _NullSpan:
    """Cached do-nothing context manager returned while tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """A live span: records its event on exit."""

    __slots__ = ("tracer", "name", "track", "start", "depth")

    def __init__(self, tracer: "Tracer", name: str, track: Optional[str]):
        self.tracer = tracer
        self.name = name
        self.track = track

    def __enter__(self):
        tl = self.tracer._tl()
        if self.track is None:
            self.track = tl.track
        self.depth = len(tl.stack)
        tl.stack.append(self.name)
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc):
        end = time.perf_counter()
        tl = self.tracer._tl()
        tl.stack.pop()
        self.tracer._record(SpanEvent(
            name=self.name, start=self.start - self.tracer.epoch,
            dur=end - self.start, track=self.track, depth=self.depth,
            tid=threading.get_ident()))
        return False


class Tracer:
    """Collects spans; thread-safe; one instance active at a time."""

    def __init__(self):
        self.epoch = time.perf_counter()
        self.events: List[SpanEvent] = []
        self._lock = threading.Lock()
        self._local = threading.local()

    def _tl(self):
        tl = self._local
        if not hasattr(tl, "stack"):
            tl.stack = []
            tl.track = _MAIN_TRACK
        return tl

    def _record(self, ev: SpanEvent) -> None:
        with self._lock:
            self.events.append(ev)

    @contextlib.contextmanager
    def track(self, name: str):
        """Route this thread's spans to a named track (e.g. "device 2")."""
        tl = self._tl()
        prev = tl.track
        tl.track = name
        try:
            yield
        finally:
            tl.track = prev

    # ---- exports ---------------------------------------------------------

    def export_chrome(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        with self._lock:
            events = list(self.events)
        tracks = sorted({ev.track for ev in events},
                        key=lambda t: (t != _MAIN_TRACK, t))
        pid_of = {t: i for i, t in enumerate(tracks)}
        out = []
        for t, pid in pid_of.items():
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "args": {"name": t}})
        for ev in events:
            out.append({
                "ph": "X",
                "name": ev.name,
                "ts": round(ev.start * 1e6, 3),
                "dur": round(ev.dur * 1e6, 3),
                "pid": pid_of[ev.track],
                "tid": ev.tid % 100000,
                "args": {"depth": ev.depth},
            })
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write_chrome(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.export_chrome(), f)

    def mark(self) -> int:
        """Current event count — pass to ``summary``/``phase_totals`` to
        aggregate only spans recorded after this point."""
        with self._lock:
            return len(self.events)

    def summary(self, start: int = 0) -> "SpanSummary":
        """Aggregate events[start:] into a picklable ``SpanSummary`` tree.

        Spans nest by (track, tid, time containment); aggregation is by
        name path, so e.g. all ``factor_level`` spans under ``factorize``
        fold into one node with a call count.
        """
        with self._lock:
            events = list(self.events[start:])
        root = SpanSummary(name="total", count=1, total_s=0.0, children=[])
        # Rebuild ancestry per (track, tid) from start/end ordering: a span
        # is a child of the innermost open span that contains it.
        by_thread: Dict[Tuple[str, int], List[SpanEvent]] = {}
        for ev in events:
            by_thread.setdefault((ev.track, ev.tid), []).append(ev)
        for evs in by_thread.values():
            # sort by start; containment via an explicit stack of (end, node)
            evs.sort(key=lambda e: (e.start, -e.dur))
            stack: List[Tuple[float, SpanSummary]] = []
            for ev in evs:
                while stack and ev.start >= stack[-1][0] - 1e-12:
                    stack.pop()
                parent = stack[-1][1] if stack else root
                node = parent.child(ev.name)
                node.count += 1
                node.total_s += ev.dur
                stack.append((ev.start + ev.dur, node))
        root.total_s = sum(c.total_s for c in root.children)
        return root

    def phase_totals(self, start: int = 0) -> Dict[str, dict]:
        """Flat {name: {count, total_s}} roll-up (all depths merged)."""
        with self._lock:
            events = list(self.events[start:])
        out: Dict[str, dict] = {}
        for ev in events:
            d = out.setdefault(ev.name, {"count": 0, "total_s": 0.0})
            d["count"] += 1
            d["total_s"] += ev.dur
        for d in out.values():
            d["total_s"] = float(d["total_s"])
        return out


@dataclasses.dataclass
class SpanSummary:
    """Aggregated span tree node — picklable, carried on plan/factor
    ``.stats`` so a traced analysis can be saved and inspected later."""

    name: str
    count: int
    total_s: float
    children: List["SpanSummary"] = dataclasses.field(default_factory=list)

    def child(self, name: str) -> "SpanSummary":
        for c in self.children:
            if c.name == name:
                return c
        c = SpanSummary(name=name, count=0, total_s=0.0, children=[])
        self.children.append(c)
        return c

    def find(self, name: str) -> Optional["SpanSummary"]:
        """Depth-first lookup by span name."""
        for c in self.children:
            if c.name == name:
                return c
            hit = c.find(name)
            if hit is not None:
                return hit
        return None

    def render(self, indent: int = 0) -> str:
        """Indented text tree: name, total seconds, call count."""
        lines = []
        pad = "  " * indent
        lines.append(f"{pad}{self.name:<28s} {self.total_s * 1e3:10.2f} ms"
                     f"  x{self.count}")
        for c in sorted(self.children, key=lambda c: -c.total_s):
            lines.append(c.render(indent + 1))
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.render()


# ---- module-level API (what the pipeline calls) --------------------------

def span(name: str, *, track: Optional[str] = None):
    """Open a nested span.  THE hot-path entry point: when tracing is off
    this is one global-bool check plus returning a cached null object."""
    if not ENABLED:
        return _NULL_SPAN
    return _Span(_TRACER, name, track)


def traced(name: Optional[str] = None) -> Callable:
    """Decorator form of ``span`` (span name defaults to the function's)."""
    def deco(fn):
        sname = name or fn.__name__

        def wrapper(*args, **kwargs):
            if not ENABLED:
                return fn(*args, **kwargs)
            with _Span(_TRACER, sname, None):
                return fn(*args, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__wrapped__ = fn
        return wrapper
    return deco


def device_track(device: Optional[int]):
    """Context routing this thread's spans to a per-device track; a no-op
    null context when tracing is off or ``device`` is None."""
    if not ENABLED or device is None:
        return _NULL_SPAN
    return _TRACER.track(f"device {int(device)}")


def tracer() -> Optional[Tracer]:
    """The active tracer, or None when disabled."""
    return _TRACER


def enable() -> Tracer:
    """Switch tracing on (idempotent); returns the active tracer."""
    global ENABLED, _TRACER
    with _LOCK:
        if _TRACER is None:
            _TRACER = Tracer()
        ENABLED = True
        return _TRACER


def disable() -> Optional[Tracer]:
    """Switch tracing off; returns the tracer that was active (so callers
    can still export), clearing the global slot."""
    global ENABLED, _TRACER
    with _LOCK:
        tr, _TRACER = _TRACER, None
        ENABLED = False
        return tr


@contextlib.contextmanager
def tracing(path=None):
    """``with repro.obs.tracing("trace.json"):`` — enable for the block,
    write Chrome trace JSON to ``path`` on exit, restore the prior state."""
    global ENABLED, _TRACER
    prev_enabled, prev_tracer = ENABLED, _TRACER
    tr = enable()
    try:
        yield tr
    finally:
        with _LOCK:
            ENABLED, _TRACER = prev_enabled, prev_tracer
        if path is not None:
            tr.write_chrome(path)


@contextlib.contextmanager
def ensure(flag: bool):
    """Enable tracing for the block iff ``flag`` and it is not already on —
    the ``LUOptions(trace=True)`` plumbing.  Yields the active tracer (or
    None).  Never disables a tracer someone outside the block owns."""
    global ENABLED, _TRACER
    if not flag:
        yield _TRACER if ENABLED else None
        return
    if ENABLED:
        yield _TRACER
        return
    tr = enable()
    try:
        yield tr
    finally:
        with _LOCK:
            # only tear down if still the tracer we installed
            if _TRACER is tr:
                ENABLED = False
                _TRACER = None
