"""Counters / gauges / histograms registry + roofline math (DESIGN.md §12).

The registry is deliberately simple: a process-global named-metric store the
pipeline writes *only when tracing is enabled* (call sites gate on
``trace.ENABLED`` so the disabled path stays a boolean check).  Recorded
quantities (span taxonomy table in DESIGN.md §12):

==============================  ========  =====================================
metric                          kind      meaning
==============================  ========  =====================================
fixpoint.iterations             hist      converged supersteps per chunk
fixpoint.chunks                 counter   chunks processed
fill.lu_nnz                     gauge     structural nnz(L+U) incl. diagonal
fill.input_nnz                  gauge     nnz(A)
supernodes.count                gauge     number of detected panels
supernodes.size                 hist      panel widths (columns per supernode)
placement.imbalance_modeled     hist      per-level max/mean modeled bin weight
factor.level_imbalance_measured hist      per-level max/mean measured segment s
fingerprint.bytes               counter   bytes moved by fingerprint updates
fingerprint.seconds             counter   wall seconds inside those updates
gemm.flops                      counter   flops of the accumulated panel GEMMs
gemm.bytes                      counter   analytic bytes gathered + scattered
gemm.seconds                    counter   wall seconds of the panel sweep
robust.perturbed_pivots         counter   tiny pivots bumped by the sweep
robust.growth                   gauge     element growth max|L\\U|/max|A_f|
robust.cond_estimate            gauge     Hager cond_1 estimate (-1 = inf)
blocking.merges                 counter   supernode pairs coalesced by blocking
blocking.panels_before          gauge     panels entering the merge pass
blocking.panels_after           gauge     panels after structure-aware merging
blocking.pad_entries            gauge     explicit zeros the merged blocks carry
blocking.modeled_gain_s         gauge     modeled sweep seconds saved by merging
tune.candidates                 counter   partitions scored by the autotune sweep
tune.modeled_s                  gauge     modeled sweep seconds of the chosen
tune.baseline_s                 gauge     modeled seconds of the untuned knobs
==============================  ========  =====================================

Roofline: ``fraction_of_peak`` / ``roofline_report`` are pure functions of
(bytes, seconds, flops, machine peaks); the machine peaks themselves are
probed and cached by ``benchmarks/roofline.py`` (the bench layer owns
timing hardware, ``repro`` never imports from ``benchmarks``).  Achieved
bandwidth over peak bandwidth is the repo's analogue of GSoFa's reported
47%-of-V100-peak memory throughput.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional


@dataclasses.dataclass
class Histogram:
    """Streaming histogram: exact count/sum/min/max + small-sample values.

    Keeps up to ``keep`` raw observations (enough for the pipeline's
    per-chunk / per-level cardinalities) so percentiles stay exact for the
    sizes we record; beyond that only the moments update.
    """

    keep: int = 4096
    count: int = 0
    total: float = 0.0
    min: float = math.inf
    max: float = -math.inf
    values: List[float] = dataclasses.field(default_factory=list)

    def record(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self.values) < self.keep:
            self.values.append(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Exact percentile over the kept sample (q in [0, 100])."""
        if not self.values:
            return 0.0
        vs = sorted(self.values)
        idx = min(len(vs) - 1, max(0, int(round(q / 100 * (len(vs) - 1)))))
        return vs[idx]

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
        }


class MetricsRegistry:
    """Named counters/gauges/histograms; thread-safe; cheap to snapshot."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}

    def count(self, name: str, value: float = 1) -> None:
        if hasattr(value, "item"):       # numpy scalars -> JSON-safe python
            value = value.item()
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + value

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self.histograms.get(name)
            if h is None:
                h = self.histograms[name] = Histogram()
            h.record(value)

    def get(self, name: str):
        """Counter or gauge value, or the Histogram object, or None."""
        with self._lock:
            if name in self.counters:
                return self.counters[name]
            if name in self.gauges:
                return self.gauges[name]
            return self.histograms.get(name)

    def reset(self) -> None:
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()

    def snapshot(self) -> dict:
        """JSON-ready dump: {counters, gauges, histograms}."""
        with self._lock:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: h.to_dict()
                               for k, h in self.histograms.items()},
            }


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global registry the pipeline writes into."""
    return _REGISTRY


# ---- roofline math -------------------------------------------------------
#
# ``peaks`` is the dict benchmarks/roofline.machine_peaks() produces:
#   {"mem_bw_gbs": float, "flops_gflops": float, ...}

def achieved_bandwidth_gbs(nbytes: float, seconds: float) -> float:
    """Achieved memory bandwidth in GB/s (0 when no time was measured)."""
    return (nbytes / seconds) / 1e9 if seconds > 0 else 0.0


def achieved_gflops(flops: float, seconds: float) -> float:
    return (flops / seconds) / 1e9 if seconds > 0 else 0.0


def fraction_of_peak(nbytes: float, seconds: float,
                     peaks: dict, *, flops: float = 0.0) -> dict:
    """Achieved throughput as a fraction of the probed machine roofline.

    Returns both the bandwidth fraction and (when ``flops`` given) the
    compute fraction; which one binds is the roofline verdict — GSoFa's
    fingerprint-style kernels are bandwidth-bound, so ``bw_fraction`` is
    the analogue of the paper's 47%-of-peak figure.
    """
    bw = achieved_bandwidth_gbs(nbytes, seconds)
    out = {
        "achieved_gbs": bw,
        "peak_gbs": float(peaks.get("mem_bw_gbs", 0.0)),
        "bw_fraction": bw / peaks["mem_bw_gbs"]
        if peaks.get("mem_bw_gbs") else 0.0,
    }
    if flops:
        gf = achieved_gflops(flops, seconds)
        out["achieved_gflops"] = gf
        out["peak_gflops"] = float(peaks.get("flops_gflops", 0.0))
        out["flop_fraction"] = (gf / peaks["flops_gflops"]
                                if peaks.get("flops_gflops") else 0.0)
        # arithmetic intensity decides which roof applies
        out["intensity_flops_per_byte"] = flops / nbytes if nbytes else 0.0
    return out


def roofline_report(name: str, *, nbytes: float, seconds: float,
                    peaks: dict, flops: float = 0.0) -> dict:
    """``fraction_of_peak`` wrapped with identification fields — the shape
    bench scripts embed under ``results[...]["roofline"]``."""
    rep = {"kernel": name, "bytes": float(nbytes), "seconds": float(seconds),
           "flops": float(flops)}
    rep.update(fraction_of_peak(nbytes, seconds, peaks, flops=flops))
    return rep


# ---- progress reporting (satellite: on_progress / ETA) -------------------

class ProgressMeter:
    """Rolling-rate progress/ETA helper behind the ``on_progress`` callback
    plumbing: call ``update(done, total)`` per unit of work; the wrapped
    callback receives ``(done, total, eta_s)`` with ``eta_s`` from the
    rolling completion rate (None until a rate exists)."""

    def __init__(self, callback, *, window: int = 8):
        import time as _time

        self._cb = callback
        self._clock = _time.perf_counter
        self._window = window
        self._ticks: List[tuple] = []          # (time, done)

    def update(self, done: int, total: int) -> None:
        now = self._clock()
        self._ticks.append((now, done))
        if len(self._ticks) > self._window:
            self._ticks.pop(0)
        eta = None
        if len(self._ticks) >= 2:
            t0, d0 = self._ticks[0]
            dt, dd = now - t0, done - d0
            if dd > 0 and dt > 0:
                eta = (total - done) * dt / dd
        self._cb(done, total, eta)


def stderr_progress(label: str, *, min_interval_s: float = 1.0):
    """An ``on_progress`` callback printing rate-limited lines to stderr —
    what ``benchmarks/run.py --trace`` installs for long analyzes."""
    import sys
    import time as _time

    state = {"last": 0.0}

    def cb(done: int, total: int, eta_s: Optional[float]) -> None:
        now = _time.perf_counter()
        if done < total and now - state["last"] < min_interval_s:
            return
        state["last"] = now
        eta = f", eta {eta_s:.0f}s" if eta_s is not None else ""
        print(f"[{label}] {done}/{total} chunks{eta}", file=sys.stderr,
              flush=True)
    return cb
