"""repro.obs — unified tracing + metrics for the LU pipeline (DESIGN.md §12).

Quickstart::

    import repro

    with repro.obs.tracing("trace.json"):       # Perfetto-loadable on exit
        plan = repro.analyze(a)
        factor = plan.factorize(values)
    print(plan.stats)                           # text summary tree
    print(repro.obs.metrics.registry().snapshot()["gauges"])

Disabled (the default) every instrumentation site is a module-level boolean
check — tier-1 timings and bitwise gates are unaffected.
"""
from repro.obs import metrics, trace
from repro.obs.metrics import (
    MetricsRegistry, ProgressMeter, fraction_of_peak, registry,
    roofline_report, stderr_progress,
)
from repro.obs.trace import (
    SpanSummary, Tracer, device_track, disable, enable, ensure, span,
    traced, tracer, tracing,
)

__all__ = [
    "metrics", "trace",
    "MetricsRegistry", "ProgressMeter", "fraction_of_peak", "registry",
    "roofline_report", "stderr_progress",
    "SpanSummary", "Tracer", "device_track", "disable", "enable", "ensure",
    "span", "traced", "tracer", "tracing",
]
