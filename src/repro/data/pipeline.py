"""Deterministic synthetic token pipeline.

Every (step, sample, position) maps to a token through a counter-mode
threefry hash, so the stream is:

* **deterministic** — any host can regenerate any batch, which is what makes
  checkpoint-restart and elastic re-sharding exact (the data state is one
  integer);
* **sharding-aware** — a host materializes only its addressable shard of the
  global batch (``local_batch`` below), the layout mirroring the batch
  sharding of train/steps.py;
* **learnable** — tokens follow a periodic Markov-ish pattern (next token is
  a hash of the previous token and a per-sequence key) so the ~100M-model
  example (examples/train_smollm.py) shows a genuinely decreasing loss, not
  noise-floor flatlining.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _hash2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Cheap 32-bit mix (xxhash-style), vectorized."""
    x = (a.astype(np.uint32) * np.uint32(2654435761)) ^ (
        b.astype(np.uint32) * np.uint32(2246822519))
    x ^= x >> np.uint32(13)
    x = x * np.uint32(3266489917)
    x ^= x >> np.uint32(16)
    return x


@dataclasses.dataclass
class SyntheticTextPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    step: int = 0                      # checkpointable state
    pattern_period: int = 64           # learnable structure strength

    def next_batch(self, local_slice: Optional[slice] = None) -> Dict[str, np.ndarray]:
        """Returns {tokens, labels} for this step; ``local_slice`` selects the
        host's rows of the global batch (data-parallel input sharding)."""
        sl = local_slice or slice(0, self.global_batch)
        rows = np.arange(sl.start, sl.stop, dtype=np.uint32)
        pos = np.arange(self.seq_len + 1, dtype=np.uint32)
        seq_key = _hash2(rows + np.uint32(self.seed * 7919),
                         np.full_like(rows, self.step, dtype=np.uint32))
        # periodic structure: token depends on (sequence key, pos % period)
        grid = _hash2(seq_key[:, None], (pos[None, :] % self.pattern_period))
        # sprinkle position-dependent noise at low rate to avoid triviality
        noise = _hash2(seq_key[:, None] + np.uint32(1), pos[None, :])
        use_noise = (noise % np.uint32(17)) == 0
        tok = np.where(use_noise, noise, grid) % np.uint32(self.vocab)
        tok = tok.astype(np.int32)
        self.step += 1
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: Dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])


def make_batch_for(cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                   step: int = 0, dtype=np.float32) -> Dict[str, np.ndarray]:
    """One concrete batch matching train/steps.batch_specs (smoke tests and
    the example drivers; dry-runs use ShapeDtypeStructs instead)."""
    s_text = shape.seq_len - cfg.n_patches if cfg.n_patches else shape.seq_len
    pipe = SyntheticTextPipeline(cfg.vocab, s_text, shape.global_batch,
                                 seed=seed, step=step)
    b = pipe.next_batch()
    batch: Dict[str, np.ndarray] = {"tokens": b["tokens"]}
    if shape.kind == "train":
        # labels span the full (patch + text) sequence for VLMs
        if cfg.n_patches:
            pad = np.zeros((shape.global_batch, cfg.n_patches), np.int32)
            batch["labels"] = np.concatenate([pad, b["labels"]], axis=1)
        else:
            batch["labels"] = b["labels"]
    rng = np.random.default_rng(seed + 1)
    if cfg.n_patches:
        batch["patches"] = rng.standard_normal(
            (shape.global_batch, cfg.n_patches, cfg.d_model)).astype(dtype)
    if cfg.encdec is not None:
        batch["frames"] = rng.standard_normal(
            (shape.global_batch, cfg.encdec.enc_len, cfg.d_model)).astype(dtype)
    return batch
