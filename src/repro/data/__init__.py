"""Deterministic synthetic data pipeline (sharding-aware, checkpointable)."""
from repro.data.pipeline import SyntheticTextPipeline, make_batch_for

__all__ = ["SyntheticTextPipeline", "make_batch_for"]
