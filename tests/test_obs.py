"""The observability layer (repro.obs, DESIGN.md §12): span tracing,
Chrome trace export, the metrics registry, and the zero-overhead disabled
mode.

The two contracts under test:

* **enabled** — spans nest correctly across threads and tracks, the Chrome
  export is schema-valid with one pid per device track, and the metrics
  the pipeline records are *identical* between a single-device analyze and
  the 8-virtual-device sharded analyze (fill nnz, supernode histogram) —
  observability must not observe different numbers on different meshes.
* **disabled** — ``span()`` is a module-bool check returning a cached
  singleton; no span object is ever constructed, no tracer exists, and
  the registry stays empty through a full analyze/factorize.
"""
import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.obs import metrics as om
from repro.obs import trace as ot


@pytest.fixture(autouse=True)
def _clean_obs_state():
    # never leak an enabled tracer or registry contents across tests
    ot.disable()
    om.registry().reset()
    yield
    ot.disable()
    om.registry().reset()


# ---- span tracing --------------------------------------------------------

def test_span_nesting_and_ordering():
    with ot.tracing() as tr:
        with ot.span("outer"):
            with ot.span("inner"):
                pass
            with ot.span("inner"):
                pass
        with ot.span("sibling"):
            pass
    s = tr.summary()
    outer = s.find("outer")
    assert outer is not None and outer.count == 1
    inner = outer.find("inner")
    assert inner is not None and inner.count == 2
    # sibling is a top-level child, not swallowed by outer
    assert outer.find("sibling") is None
    assert s.find("sibling") is not None
    # children's time is contained in the parent's
    assert inner.total_s <= outer.total_s + 1e-9
    # the rendered tree carries the same data
    text = str(s)
    assert "outer" in text and "inner" in text and "x2" in text


def test_traced_decorator_records_function_span():
    @ot.traced()
    def work():
        return 7

    with ot.tracing() as tr:
        assert work() == 7
    assert tr.phase_totals()["work"]["count"] == 1
    assert work() == 7          # and still works with tracing off


def test_mark_scopes_summary_and_phase_totals():
    with ot.tracing() as tr:
        with ot.span("before"):
            pass
        mark = tr.mark()
        with ot.span("after"):
            pass
        s = tr.summary(mark)
        totals = tr.phase_totals(mark)
    assert s.find("after") is not None
    assert s.find("before") is None
    assert list(totals) == ["after"]


def test_thread_safety():
    n_threads, per_thread = 8, 50

    def work():
        for _ in range(per_thread):
            with ot.span("t_outer"):
                with ot.span("t_inner"):
                    pass

    with ot.tracing() as tr:
        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    totals = tr.phase_totals()
    assert totals["t_outer"]["count"] == n_threads * per_thread
    assert totals["t_inner"]["count"] == n_threads * per_thread
    # per-thread nesting stayed coherent despite the shared event list
    s = tr.summary()
    assert s.find("t_outer").find("t_inner") is not None


def test_chrome_trace_schema(tmp_path):
    path = tmp_path / "trace.json"
    with ot.tracing(str(path)) as tr:
        with ot.span("analyze"):
            pass
        for d in (0, 1):
            with ot.device_track(d):
                with ot.span("factor_segment"):
                    pass
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    ms = [e for e in events if e["ph"] == "M"]
    for e in xs:
        assert {"name", "ts", "dur", "pid", "tid"} <= e.keys()
        assert e["ts"] >= 0 and e["dur"] >= 0
    # one named track per pid: main + both device tracks
    pid_of = {m["args"]["name"]: m["pid"] for m in ms}
    assert {"main", "device 0", "device 1"} <= pid_of.keys()
    assert len(set(pid_of.values())) == len(pid_of)
    seg_pids = {e["pid"] for e in xs if e["name"] == "factor_segment"}
    assert seg_pids == {pid_of["device 0"], pid_of["device 1"]}
    assert {e["pid"] for e in xs if e["name"] == "analyze"} == {
        pid_of["main"]}


def test_ensure_never_tears_down_outer_tracer():
    with ot.tracing() as outer:
        with ot.ensure(True) as tr:
            assert tr is outer
        assert ot.ENABLED          # outer block still owns the tracer
    assert not ot.ENABLED
    with ot.ensure(False) as tr:
        assert tr is None and not ot.ENABLED
    with ot.ensure(True) as tr:
        assert tr is not None and ot.ENABLED
    assert not ot.ENABLED          # ensure-installed tracer torn down


# ---- metrics registry ----------------------------------------------------

def test_counter_gauge_math_and_numpy_normalization():
    reg = om.MetricsRegistry()
    reg.count("c")
    reg.count("c", 2.5)
    reg.count("c", np.int64(2))
    assert reg.get("c") == 5.5
    reg.gauge("g", np.float64(3.0))
    reg.gauge("g", 4.0)            # gauges overwrite
    assert reg.get("g") == 4.0
    # the snapshot must be plain-JSON serializable (no numpy scalars)
    json.dumps(reg.snapshot())


def test_histogram_math():
    h = om.Histogram()
    for v in range(1, 11):
        h.record(v)
    assert h.count == 10
    assert h.mean == pytest.approx(5.5)
    assert (h.min, h.max) == (1.0, 10.0)
    d = h.to_dict()
    assert set(d) == {"count", "mean", "min", "max", "p50", "p90"}
    assert 4.0 <= d["p50"] <= 6.0 and d["p90"] >= 8.0
    # beyond the kept sample only the moments update
    h2 = om.Histogram(keep=4)
    for v in range(100):
        h2.record(v)
    assert h2.count == 100 and len(h2.values) == 4
    assert h2.mean == pytest.approx(49.5)


def test_fraction_of_peak_math():
    peaks = {"mem_bw_gbs": 10.0, "flops_gflops": 100.0}
    rep = om.fraction_of_peak(5e9, 1.0, peaks, flops=50e9)
    assert rep["achieved_gbs"] == pytest.approx(5.0)
    assert rep["bw_fraction"] == pytest.approx(0.5)
    assert rep["achieved_gflops"] == pytest.approx(50.0)
    assert rep["flop_fraction"] == pytest.approx(0.5)
    assert rep["intensity_flops_per_byte"] == pytest.approx(10.0)
    # no measured time -> zero rates, not a ZeroDivisionError
    assert om.fraction_of_peak(1e9, 0.0, peaks)["achieved_gbs"] == 0.0


def test_progress_meter_eta():
    calls = []
    meter = om.ProgressMeter(lambda d, t, eta: calls.append((d, t, eta)))
    meter.update(1, 4)
    meter.update(2, 4)
    assert calls[0][:2] == (1, 4) and calls[0][2] is None
    assert calls[1][:2] == (2, 4)
    assert calls[1][2] is None or calls[1][2] >= 0.0


# ---- disabled mode: zero-overhead contract -------------------------------

def test_disabled_span_is_cached_singleton(monkeypatch):
    assert not ot.ENABLED
    assert ot.span("a") is ot.span("b") is ot._NULL_SPAN
    # prove no _Span is ever constructed on the disabled path
    class Boom:
        def __init__(self, *a, **k):
            raise AssertionError("span constructed while tracing disabled")
    monkeypatch.setattr(ot, "_Span", Boom)
    with ot.span("anything"):
        pass
    with ot.device_track(3):
        pass
    assert ot.tracer() is None


def test_disabled_pipeline_records_nothing():
    from repro.api import LUOptions, analyze
    from repro.sparse import grid2d_laplacian
    from repro.sparse.numeric import generic_values

    a = grid2d_laplacian(6)
    plan = analyze(a, LUOptions(concurrency=32))
    factor = plan.factorize(generic_values(a))
    assert plan.stats is None and factor.stats is None
    assert om.registry().snapshot() == {
        "counters": {}, "gauges": {}, "histograms": {}}


def test_lu_options_trace_populates_stats():
    from repro.api import LUOptions, analyze
    from repro.sparse import grid2d_laplacian
    from repro.sparse.numeric import generic_values

    a = grid2d_laplacian(6)
    plan = analyze(a, LUOptions(concurrency=32, trace=True))
    assert not ot.ENABLED          # analyze's ensure() tore tracing down
    assert plan.stats is not None
    for phase in ("analyze", "fixpoint", "build_schedule"):
        assert plan.stats.find(phase) is not None, phase
    factor = plan.factorize(generic_values(a))
    assert factor.stats is not None
    assert factor.stats.find("factorize") is not None
    assert factor.stats.find("factor_level") is not None
    # the registry saw the traced run
    assert om.registry().get("fill.lu_nnz") > 0


# ---- metrics parity: single device vs 8 virtual devices ------------------

_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, "src")
import json
import jax
assert len(jax.devices()) == 8, len(jax.devices())

from repro import obs
from repro.api import LUOptions, analyze
from repro.launch.mesh import make_flat_mesh
from repro.sparse import circuit_like, permute_csr, rcm_order

a = circuit_like(400, seed=11)
a = permute_csr(a, rcm_order(a))
opts = LUOptions(concurrency=64, supernode_relax=2)

def traced_metrics(mesh):
    obs.registry().reset()
    with obs.tracing():
        analyze(a, opts, mesh=mesh)
    return obs.registry().snapshot()

single = traced_metrics(None)
dist = traced_metrics(make_flat_mesh())
out = {}
for label, snap in (("single", single), ("dist", dist)):
    out[f"fill_{label}"] = snap["gauges"]["fill.lu_nnz"]
    out[f"input_{label}"] = snap["gauges"]["fill.input_nnz"]
    out[f"sn_count_{label}"] = snap["gauges"]["supernodes.count"]
    out[f"sn_hist_{label}"] = snap["histograms"]["supernodes.size"]
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def parity(tmp_path_factory):
    path = tmp_path_factory.mktemp("obs") / "parity.py"
    path.write_text(_PARITY_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, str(path)], capture_output=True,
                       text=True, timeout=1200, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_metrics_parity_fill_nnz(parity):
    assert parity["fill_single"] == parity["fill_dist"] > 0
    assert parity["input_single"] == parity["input_dist"] > 0


def test_metrics_parity_supernode_histogram(parity):
    assert parity["sn_count_single"] == parity["sn_count_dist"] > 0
    assert parity["sn_hist_single"] == parity["sn_hist_dist"]
