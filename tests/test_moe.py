"""MoE dispatch correctness: the capacity-sort dispatch must equal the dense
mixture reference when nothing is dropped, and degrade monotonically (only
dropped pairs lose contribution) under tight capacity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig, get_config
from repro.models import moe as moe_mod


def _cfg(cf=8.0, n_experts=8, top_k=2, n_shared=0):
    base = get_config("moonshot-v1-16b-a3b").reduced()
    return dataclasses.replace(
        base, moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_expert=32,
                            n_shared=n_shared, capacity_factor=cf))


def _dense_reference(params, x, cfg):
    """Every expert computes every token; combine with top-k softmax gates."""
    m = cfg.moe
    b, s, d = x.shape
    logits = x.astype(jnp.float32) @ params["router"]
    gate_logits, idx = jax.lax.top_k(logits, m.top_k)
    gates = jax.nn.softmax(gate_logits, axis=-1)
    h_gate = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, params["w_gate"]))
    h_up = jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    h = jnp.einsum("bsef,efd->bsed", h_gate * h_up, params["w_down"])
    onehot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32)  # (b,s,k,E)
    w = jnp.einsum("bske,bsk->bse", onehot, gates)
    return jnp.einsum("bsed,bse->bsd", h, w)


def test_moe_matches_dense_reference_when_no_drops():
    cfg = _cfg(cf=8.0)
    key = jax.random.key(1)
    params = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model))
    y, metrics = moe_mod.moe_forward(params, x, cfg)
    assert float(metrics["moe_drop_frac"]) == 0.0
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_moe_drops_under_tight_capacity():
    cfg = _cfg(cf=0.25)
    params = moe_mod.init_moe(jax.random.key(1), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (2, 64, cfg.d_model))
    y, metrics = moe_mod.moe_forward(params, x, cfg)
    assert 0.0 < float(metrics["moe_drop_frac"]) < 1.0
    assert not bool(jnp.any(jnp.isnan(y)))


def test_shared_expert_added():
    cfg0 = _cfg(cf=8.0, n_shared=0)
    cfg1 = _cfg(cf=8.0, n_shared=2)
    p1 = moe_mod.init_moe(jax.random.key(1), cfg1, jnp.float32)
    p0 = {k: v for k, v in p1.items() if k != "shared"}
    x = jax.random.normal(jax.random.key(2), (1, 8, cfg0.d_model))
    y0, _ = moe_mod.moe_forward(p0, x, cfg0)
    y1, _ = moe_mod.moe_forward(p1, x, cfg1)
    from repro.models.layers import mlp
    shared = mlp(p1["shared"], x.reshape(-1, cfg0.d_model)).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0 + shared),
                               rtol=1e-5, atol=1e-5)


def test_aux_loss_favors_balance():
    cfg = _cfg(cf=8.0, n_experts=4, top_k=1)
    params = moe_mod.init_moe(jax.random.key(1), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (2, 32, cfg.d_model))
    # force total collapse onto expert 0 via the router
    collapsed = dict(params)
    router = np.zeros(params["router"].shape, np.float32)
    router[:, 0] = 10.0
    collapsed["router"] = jnp.asarray(router)
    _, m_bal = moe_mod.moe_forward(params, x, cfg)
    _, m_col = moe_mod.moe_forward(collapsed, x, cfg)
    assert float(m_col["moe_aux_loss"]) > float(m_bal["moe_aux_loss"])
