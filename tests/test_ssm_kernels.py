"""Shape/dtype sweeps for the SSM Pallas kernels vs their jnp oracles, and
consistency between the kernels and the model-layer scan implementations."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

RNG = np.random.default_rng(42)


def _mamba_inputs(b, l, di, n, dtype=jnp.float32):
    x = jnp.asarray(RNG.standard_normal((b, l, di)), dtype)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, l, di))) * 0.05, dtype)
    bt = jnp.asarray(RNG.standard_normal((b, l, n)), dtype)
    ct = jnp.asarray(RNG.standard_normal((b, l, n)), dtype)
    a = -jnp.asarray(np.abs(RNG.standard_normal((di, n))) + 0.1, jnp.float32)
    d = jnp.asarray(RNG.standard_normal((di,)), jnp.float32)
    return x, dt, bt, ct, a, d


@pytest.mark.parametrize("b,l,di,n", [
    (1, 16, 64, 4), (2, 128, 256, 16), (3, 100, 96, 8), (2, 64, 512, 16),
])
def test_mamba_kernel_vs_ref(b, l, di, n):
    args = _mamba_inputs(b, l, di, n)
    got = ops.mamba_scan(*args, block_d=64, block_t=32)
    want = ops.mamba_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_d,block_t", [(32, 16), (64, 64), (128, 128)])
def test_mamba_kernel_block_sweep(block_d, block_t):
    args = _mamba_inputs(2, 128, 128, 16)
    got = ops.mamba_scan(*args, block_d=block_d, block_t=block_t)
    want = ops.mamba_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def _rwkv_inputs(bh, l, k):
    r = jnp.asarray(RNG.standard_normal((bh, l, k)), jnp.float32)
    kk = jnp.asarray(RNG.standard_normal((bh, l, k)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, l, k)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.5, 0.999, (bh, l, k)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((bh, k)) * 0.3, jnp.float32)
    return r, kk, v, w, u


@pytest.mark.parametrize("bh,l,k", [(1, 16, 16), (4, 128, 64), (2, 96, 32)])
def test_rwkv6_kernel_vs_ref(bh, l, k):
    args = _rwkv_inputs(bh, l, k)
    got = ops.rwkv6_scan(*args, block_t=32)
    want = ops.rwkv6_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_kernel_matches_model_layer():
    """kernels/ssm_scan == models/mamba._selective_scan on the same inputs."""
    from repro.models.mamba import _selective_scan
    x, dt, bt, ct, a, d = _mamba_inputs(2, 64, 128, 16)
    y_model, _ = _selective_scan(x, dt, bt, ct, a, d,
                                 jnp.zeros((2, 128, 16), jnp.float32))
    y_kernel = ops.mamba_scan(x, dt, bt, ct, a, d, block_d=64, block_t=32)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel),
                               rtol=1e-5, atol=1e-5)


def test_rwkv_kernel_matches_model_layer():
    from repro.models.rwkv6 import _recurrence
    bh, l, k = 3, 64, 32
    r, kk, v, w, u = _rwkv_inputs(bh, l, k)
    # model layout: (B, L, H, K) with H=1
    o_model, _ = _recurrence(r[:, :, None], kk[:, :, None], v[:, :, None],
                             w[:, :, None], u[:1].reshape(1, k),
                             jnp.zeros((bh, 1, k, k)))
    o_kernel = ops.rwkv6_scan(r, kk, v, w,
                              jnp.broadcast_to(u[:1], (bh, k)), block_t=32)
    np.testing.assert_allclose(np.asarray(o_model[:, :, 0]),
                               np.asarray(o_kernel), rtol=1e-4, atol=1e-4)
