"""Benchmark-regression gate (`benchmarks/run.py --check-baseline`) and
artifact metadata stamping (ISSUE 3 satellites)."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import (  # noqa: E402
    artifact_meta, check_baselines, save_artifact,
)


def _write(directory, name, payload):
    os.makedirs(directory, exist_ok=True)
    with open(os.path.join(directory, name + ".json"), "w") as f:
        json.dump(payload, f)


@pytest.fixture
def dirs(tmp_path):
    return str(tmp_path / "artifacts"), str(tmp_path / "baselines")


BASE = {"m1": {"speedup": 2.0, "t_run_s": 1.0, "mem_ratio": 100.0,
               "n": 500}}


def test_gate_passes_on_identical_artifacts(dirs):
    art, base = dirs
    _write(base, "bench_x", BASE)
    _write(art, "bench_x", BASE)
    assert check_baselines(artifacts_dir=art, baseline_dir=base) == []


def test_gate_fails_on_speedup_regression(dirs):
    art, base = dirs
    _write(base, "bench_x", BASE)
    fresh = {"m1": dict(BASE["m1"], speedup=1.4)}     # 30% drop
    _write(art, "bench_x", fresh)
    v = check_baselines(artifacts_dir=art, baseline_dir=base)
    assert len(v) == 1
    assert v[0]["kind"] == "ratio-regression"
    assert "speedup" in v[0]["path"]


def test_gate_fails_on_mem_ratio_collapse(dirs):
    """Reintroducing dense working storage collapses mem_ratio — gated."""
    art, base = dirs
    _write(base, "bench_x", BASE)
    _write(art, "bench_x", {"m1": dict(BASE["m1"], mem_ratio=1.0)})
    v = check_baselines(artifacts_dir=art, baseline_dir=base)
    assert [x["kind"] for x in v] == ["ratio-regression"]
    assert "mem_ratio" in v[0]["path"]


def test_gate_respects_tolerance(dirs):
    art, base = dirs
    _write(base, "bench_x", BASE)
    fresh = {"m1": dict(BASE["m1"], speedup=1.6)}     # 20% drop < 25% tol
    _write(art, "bench_x", fresh)
    assert check_baselines(artifacts_dir=art, baseline_dir=base,
                           tolerance=0.25) == []
    v = check_baselines(artifacts_dir=art, baseline_dir=base,
                        tolerance=0.10)
    assert len(v) == 1


def test_times_gated_only_on_request(dirs):
    art, base = dirs
    _write(base, "bench_x", BASE)
    fresh = {"m1": dict(BASE["m1"], t_run_s=1.5)}     # 50% slower
    _write(art, "bench_x", fresh)
    assert check_baselines(artifacts_dir=art, baseline_dir=base) == []
    v = check_baselines(artifacts_dir=art, baseline_dir=base,
                        include_times=True)
    assert [x["kind"] for x in v] == ["time-regression"]


def test_throughput_rates_are_not_gated_as_times(dirs):
    """cols_per_s is a higher-is-better rate, not a wall-clock metric —
    a rise (or fall) must never be flagged as a time regression."""
    art, base = dirs
    _write(base, "bench_x", {"m1": {"cols_per_s": 1000.0}})
    _write(art, "bench_x", {"m1": {"cols_per_s": 2000.0}})
    assert check_baselines(artifacts_dir=art, baseline_dir=base,
                           include_times=True) == []


def test_missing_fresh_artifact_is_a_violation(dirs):
    art, base = dirs
    _write(base, "bench_x", BASE)
    os.makedirs(art, exist_ok=True)
    v = check_baselines(artifacts_dir=art, baseline_dir=base)
    assert [x["kind"] for x in v] == ["missing"]


def test_missing_metric_is_a_violation(dirs):
    art, base = dirs
    _write(base, "bench_x", BASE)
    _write(art, "bench_x", {"m1": {"speedup": 2.0}})
    kinds = {x["kind"] for x in
             check_baselines(artifacts_dir=art, baseline_dir=base)}
    assert kinds == {"missing"}           # t_run_s / mem_ratio / n absent


def test_meta_never_participates(dirs):
    art, base = dirs
    _write(base, "bench_x", {**BASE, "_meta": {"git_sha": "old"}})
    _write(art, "bench_x", {**BASE, "_meta": {"git_sha": "new"}})
    assert check_baselines(artifacts_dir=art, baseline_dir=base) == []


def test_save_artifact_stamps_metadata(tmp_path):
    payload = {"m1": {"speedup": 2.0}}
    path = save_artifact("bench_meta_test", payload,
                         directory=str(tmp_path))
    with open(path) as f:
        on_disk = json.load(f)
    meta = on_disk["_meta"]
    for key in ("git_sha", "jax_version", "backend", "timestamp"):
        assert key in meta and meta[key]
    assert "_meta" not in payload         # caller's dict untouched


def test_artifact_meta_shape():
    meta = artifact_meta()
    assert set(meta) >= {"git_sha", "jax_version", "backend", "timestamp"}


def test_committed_baselines_exist_and_gate_runs():
    """The real committed baselines are well-formed; against their own
    copies the gate is clean."""
    repo = os.path.join(os.path.dirname(__file__), "..")
    base = os.path.join(repo, "benchmarks", "baselines")
    names = [f for f in os.listdir(base) if f.endswith(".json")]
    assert {"bench_numeric.json", "bench_supernode.json",
            "bench_solve.json", "bench_refactorize.json",
            "bench_distributed.json"} <= set(names)
    assert check_baselines(artifacts_dir=base, baseline_dir=base) == []


# ---------------------------------------------------------------------------
# the bench_distributed gate (ISSUE 5): placement speedups are ratio-gated,
# structural/parity fields ride along ungated
# ---------------------------------------------------------------------------

DIST = {"bbd": {"placement2_speedup": 1.9, "placement8_speedup": 6.5,
                "devices_used_d8": 8, "max_level_width": 1282},
        "multidevice-8": {"parity": 1, "balance_ratio": 1.1,
                          "t_analyze_dist_s": 1.3}}


def test_gate_fails_on_placement_speedup_regression(dirs):
    """A placement change that lengthens the modeled level critical path
    (e.g. reverting per-level LPT to global-bin modulo) collapses
    placement*_speedup — gated as a ratio metric."""
    art, base = dirs
    _write(base, "bench_distributed", DIST)
    fresh = {**DIST, "bbd": dict(DIST["bbd"], placement8_speedup=1.4)}
    _write(art, "bench_distributed", fresh)
    v = check_baselines(artifacts_dir=art, baseline_dir=base)
    assert [x["kind"] for x in v] == ["ratio-regression"]
    assert "placement8_speedup" in v[0]["path"]


def test_gate_ignores_parity_and_coverage_fields(dirs):
    """parity / devices_used / balance_ratio are enforced *inside*
    bench_distributed (hard failures), not by the drift gate — shifting
    them here alone must not trip ratio or time checks."""
    art, base = dirs
    _write(base, "bench_distributed", DIST)
    fresh = {"bbd": dict(DIST["bbd"], devices_used_d8=4),
             "multidevice-8": dict(DIST["multidevice-8"], parity=0,
                                   balance_ratio=9.9)}
    _write(art, "bench_distributed", fresh)
    assert check_baselines(artifacts_dir=art, baseline_dir=base) == []


def test_gate_times_in_distributed_artifact_opt_in(dirs):
    art, base = dirs
    _write(base, "bench_distributed", DIST)
    fresh = {**DIST, "multidevice-8": dict(DIST["multidevice-8"],
                                           t_analyze_dist_s=99.0)}
    _write(art, "bench_distributed", fresh)
    assert check_baselines(artifacts_dir=art, baseline_dir=base) == []
    v = check_baselines(artifacts_dir=art, baseline_dir=base,
                        include_times=True)
    assert [x["kind"] for x in v] == ["time-regression"]
    assert "t_analyze_dist_s" in v[0]["path"]
