"""End-to-end solve() + packed CSC-panel storage (ISSUE 3 / DESIGN.md §9).

Contract: on every matrices.py generator, ``solve`` matches
``numpy.linalg.solve`` and reaches a relative residual <= 1e-10; iterative
refinement's recorded residual history is non-increasing; zero pivots
propagate as ``ZeroPivotError``; and the packed store never materializes an
(n, n) working array — checked structurally and with a tracemalloc ceiling
at n = 20_000, a size the dense path (3.2 GB of float64 scratch) could not
even allocate here.
"""
import tracemalloc

import numpy as np
import pytest

from repro.core.gsofa import dense_pattern, prepare_graph
from repro.core.symbolic import symbolic_factorize
from repro.numeric import (
    CSCPattern, PanelStore, backward_substitute, build_solve_schedule,
    forward_substitute, numeric_factorize, solve, solve_factored,
    uniform_supernodes,
)
from repro.sparse import (
    banded_full, banded_random, chemical_like, circuit_like, economic_like,
    grid2d_laplacian, grid3d_laplacian, indefinite, permute_csr,
    random_pattern, rcm_order, shuffled_dominant,
)
from repro.sparse.csr import csr_from_dense
from repro.sparse.numeric import (
    ZeroPivotError, csr_matvec, generic_values, generic_values_csr,
)

# every generator in sparse/matrices.py, at n <= 1024
GENERATORS = {
    "grid2d": lambda: grid2d_laplacian(14),
    "grid3d": lambda: grid3d_laplacian(6),
    "circuit": lambda: circuit_like(300, seed=7),
    "economic": lambda: economic_like(256, block=16, seed=2),
    "chemical": lambda: chemical_like(320, stage=16, seed=3),
    "banded": lambda: banded_random(240, band=6, seed=4),
    "banded_full": lambda: banded_full(200, band=5),
    "random": lambda: random_pattern(160, density=0.02, seed=5),
    "indefinite": lambda: indefinite(160, band=6, seed=1),
    "shuffled": lambda: shuffled_dominant(160, band=5, seed=2),
}


def _setup(name, relax=0):
    a = GENERATORS[name]()
    a = permute_csr(a, rcm_order(a))
    sym = symbolic_factorize(a, concurrency=64, detect_supernodes=True,
                             supernode_relax=relax)
    pattern = dense_pattern(prepare_graph(a))
    values = generic_values(a)
    return a, sym, pattern, values


# ---------------------------------------------------------------------------
# solve() parity + residual across the generator suite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_solve_matches_dense_oracle(name):
    a, sym, pattern, values = _setup(name)
    b = np.random.default_rng(1).standard_normal(a.n)
    res = solve(a, b, sym=sym, values=values, pattern=pattern)
    x0 = np.linalg.solve(values, b)
    assert np.abs(res.x - x0).max() / np.abs(x0).max() <= 1e-10
    assert res.residual <= 1e-10
    # the history is the initial solve plus accepted refinements only
    assert len(res.residuals) == res.refine_accepted + 1


@pytest.mark.parametrize("name", ["grid2d", "circuit"])
def test_relaxed_panels_still_solve(name):
    a, sym, pattern, values = _setup(name, relax=4)
    b = np.random.default_rng(2).standard_normal(a.n)
    res = solve(a, b, sym=sym, values=values, pattern=pattern)
    assert res.residual <= 1e-10


@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_refinement_residual_monotone(name):
    a, sym, pattern, values = _setup(name)
    b = np.random.default_rng(3).standard_normal(a.n)
    # refine_tol=0.0 forces refinement sweeps even at machine precision,
    # so the history actually has entries to check
    res = solve(a, b, sym=sym, values=values, pattern=pattern,
                refine_iters=5, refine_tol=0.0)
    hist = np.array(res.residuals)
    assert (np.diff(hist) <= 0).all(), f"non-monotone history {hist}"


def test_refine_tol_stops_early():
    a, sym, pattern, values = _setup("grid2d")
    b = np.random.default_rng(4).standard_normal(a.n)
    res = solve(a, b, sym=sym, values=values, pattern=pattern,
                refine_iters=10, refine_tol=1.0)
    assert len(res.residuals) == 1        # initial solve already below tol


def test_solve_reuses_factorization():
    a, sym, pattern, values = _setup("economic")
    b = np.random.default_rng(5).standard_normal(a.n)
    num = numeric_factorize(a, sym, values=values, pattern=pattern)
    res1 = solve(a, b, sym=sym, values=values, pattern=pattern)
    res2 = solve(a, b, values=values, num=num)
    assert np.array_equal(res1.x, res2.x)
    assert res2.num is num


def test_sparse_path_matches_dense_path_bitwise():
    """CSR-aligned values + CSCPattern must produce bit-identical factors
    and solution to the legacy dense-values/dense-pattern path."""
    a, sym, pattern, _ = _setup("banded")
    vals = generic_values_csr(a)
    dense = np.zeros((a.n, a.n))
    for i in range(a.n):
        dense[i, a.row(i)] = vals[a.indptr[i]:a.indptr[i + 1]]
    b = np.random.default_rng(6).standard_normal(a.n)
    # refinement off: the two paths' matvecs sum in different orders, so
    # only the pure factor+substitute pipeline is bitwise comparable
    res_sparse = solve(a, b, sym=sym, values=vals, refine_iters=0,
                       pattern=CSCPattern.from_dense(pattern))
    res_dense = solve(a, b, sym=sym, values=dense, refine_iters=0,
                      pattern=pattern)
    assert np.array_equal(res_sparse.x, res_dense.x)
    assert res_sparse.residual <= 1e-10 and res_dense.residual <= 1e-10
    ls, us = res_sparse.num.store.dense_lu()
    ld, ud = res_dense.num.store.dense_lu()
    assert np.array_equal(ls, ld) and np.array_equal(us, ud)


def test_generic_values_csr_matches_dense():
    a = GENERATORS["circuit"]()
    dense = generic_values(a)
    vals = generic_values_csr(a)
    for i in range(a.n):
        np.testing.assert_allclose(dense[i, a.row(i)],
                                   vals[a.indptr[i]:a.indptr[i + 1]],
                                   rtol=1e-15)
    x = np.random.default_rng(7).standard_normal(a.n)
    np.testing.assert_allclose(csr_matvec(a, vals, x), dense @ x,
                               rtol=1e-12, atol=1e-12)


def test_substitution_against_scipy():
    from scipy.linalg import solve_triangular

    a, sym, pattern, values = _setup("grid3d")
    num = numeric_factorize(a, sym, values=values, pattern=pattern)
    b = np.random.default_rng(8).standard_normal(a.n)
    y = forward_substitute(num.store, b)
    y0 = solve_triangular(num.l, b, lower=True, unit_diagonal=True)
    np.testing.assert_allclose(y, y0, rtol=1e-10, atol=1e-12)
    x = backward_substitute(num.store, y)
    x0 = solve_triangular(num.u, y0, lower=False)
    np.testing.assert_allclose(x, x0, rtol=1e-9,
                               atol=1e-9 * np.abs(x0).max())


def test_batched_level_solves_match_per_panel_path():
    """The level-batched (vmapped) diagonal-solve path agrees with the
    per-panel scipy path on both sweeps, single and multi-RHS."""
    a, sym, pattern, values = _setup("grid2d", relax=2)
    num = numeric_factorize(a, sym, values=values, pattern=pattern)
    rng = np.random.default_rng(9)
    for b in (rng.standard_normal(a.n), rng.standard_normal((a.n, 5))):
        y_ref = forward_substitute(num.store, b, batched=False)
        y_bat = forward_substitute(num.store, b, batched=True)
        np.testing.assert_allclose(y_bat, y_ref, rtol=1e-12,
                                   atol=1e-12 * np.abs(y_ref).max())
        x_ref = backward_substitute(num.store, y_ref, batched=False)
        x_bat = backward_substitute(num.store, y_bat, batched=True)
        np.testing.assert_allclose(x_bat, x_ref, rtol=1e-9,
                                   atol=1e-9 * np.abs(x_ref).max())


def test_batched_multi_rhs_matches_per_column_loop():
    """Parity: one batched multi-RHS substitution == the k-fold per-column
    loop of single-RHS substitutions, column for column."""
    a, sym, pattern, values = _setup("banded_full", relax=2)
    num = numeric_factorize(a, sym, values=values, pattern=pattern)
    rhs = np.random.default_rng(10).standard_normal((a.n, 8))
    multi = solve_factored(num, rhs, batched=True)
    for c in range(rhs.shape[1]):
        single = solve_factored(num, rhs[:, c], batched=False)
        np.testing.assert_allclose(multi[:, c], single, rtol=1e-10,
                                   atol=1e-10 * np.abs(single).max())


def test_batched_multi_rhs_beats_per_column_loop():
    """Timing: k columns through the batched sweep must beat k separate
    single-RHS sweeps (that is the point of batching the level solves
    into one call; best-of-3 keeps CI load spikes out of the gate)."""
    import time as _time

    a, sym, pattern, values = _setup("banded_full", relax=2)
    num = numeric_factorize(a, sym, values=values, pattern=pattern)
    k = 32
    rhs = np.random.default_rng(11).standard_normal((a.n, k))

    def best_of(fn, n=3):
        ts = []
        for _ in range(n):
            t0 = _time.perf_counter()
            fn()
            ts.append(_time.perf_counter() - t0)
        return min(ts)

    solve_factored(num, rhs, batched=True)            # warm
    t_batched = best_of(lambda: solve_factored(num, rhs, batched=True))
    t_loop = best_of(lambda: [solve_factored(num, rhs[:, c], batched=False)
                              for c in range(k)])
    assert t_batched < t_loop, (t_batched, t_loop)


def test_multi_rhs_default_is_batched_and_consistent():
    """solve() auto-picks the batched path for (n, k) — explicit
    batched=True is bitwise the default multi-RHS result."""
    a, sym, pattern, values = _setup("grid3d", relax=2)
    b = np.random.default_rng(12).standard_normal((a.n, 4))
    auto = solve(a, b, sym=sym, values=values, pattern=pattern,
                 refine_iters=0)
    forced = solve(a, b, sym=sym, values=values, pattern=pattern,
                   refine_iters=0, batched=True)
    assert np.array_equal(auto.x, forced.x)


def test_solve_schedule_is_topological():
    a, sym, pattern, values = _setup("circuit", relax=2)
    num = numeric_factorize(a, sym, values=values, pattern=pattern)
    store = num.store
    sched = build_solve_schedule(store)
    fwd_level = np.empty(store.n_panels, dtype=np.int64)
    for lv, members in enumerate(sched.fwd_levels):
        fwd_level[members] = lv
    bwd_level = np.empty(store.n_panels, dtype=np.int64)
    for lv, members in enumerate(sched.bwd_levels):
        bwd_level[members] = lv
    for j in range(store.n_panels):
        s, e = store.supernodes[j]
        d = int(store.diag[j])
        below = store.rows[j][d + (e - s):]
        for k in np.unique(store.sup_of_col[below]):
            assert fwd_level[k] > fwd_level[j]       # L-dep: k waits on j
        above = store.rows[j][:d]
        for k in np.unique(store.sup_of_col[above]):
            assert bwd_level[k] > bwd_level[j]       # U-dep: k waits on j
    # every panel scheduled exactly once in each sweep
    assert sorted(np.concatenate(sched.fwd_levels)) == \
        list(range(store.n_panels))
    assert sorted(np.concatenate(sched.bwd_levels)) == \
        list(range(store.n_panels))


# ---------------------------------------------------------------------------
# error propagation
# ---------------------------------------------------------------------------

def test_zero_pivot_propagates_through_solve():
    a = csr_from_dense(np.ones((2, 2)))
    vals = np.array([[0.0, 1.0], [1.0, 1.0]])
    with pytest.raises(ZeroPivotError) as ei:
        solve(a, np.ones(2), values=vals)
    assert ei.value.k == 0


def test_solve_with_num_requires_matching_values():
    """Refinement residuals must be computed against the matrix that was
    factored — defaulting values silently would corrupt the answer."""
    a, sym, pattern, values = _setup("grid2d")
    num = numeric_factorize(a, sym, values=values, pattern=pattern)
    with pytest.raises(ValueError, match="needs the values"):
        solve(a, np.ones(a.n), num=num)


def test_with_diagonal_adds_missing_entries():
    pat = CSCPattern(n=3, indptr=np.array([0, 1, 2, 3]),
                     rowind=np.array([0, 2, 1]))     # cols 1, 2 lack diag
    fixed = pat.with_diagonal()
    dense = fixed.to_dense()
    assert dense.diagonal().all()
    assert fixed.nnz == pat.nnz + 2
    # already-complete patterns come back untouched
    assert fixed.with_diagonal() is fixed


def test_solve_rejects_bad_rhs_shape():
    a = GENERATORS["grid2d"]()
    with pytest.raises(ValueError, match="b must be"):
        solve(a, np.ones(a.n + 1))


def test_factorize_rejects_bad_csr_values_shape():
    a = GENERATORS["grid2d"]()
    with pytest.raises(ValueError, match="CSR-aligned"):
        numeric_factorize(a, values=np.ones(a.nnz + 3))


# ---------------------------------------------------------------------------
# packed storage: structure + memory shape
# ---------------------------------------------------------------------------

def test_cscpattern_roundtrip_and_diagonal():
    a, _, pattern, _ = _setup("random")
    pat = CSCPattern.from_dense(pattern)
    dense = pat.to_dense()
    ref = pattern.copy()
    np.fill_diagonal(ref, True)
    assert np.array_equal(dense, ref)
    assert pat.with_diagonal() is pat      # already has every diagonal
    # below-diag counts agree with the dense computation
    ids = np.arange(a.n)
    ref_counts = (ref & (ids[:, None] > ids[None, :])).sum(axis=0)
    assert np.array_equal(pat.below_diag_counts(), ref_counts)


def test_cscpattern_banded_matches_dense_band():
    n, band = 37, 3
    pat = CSCPattern.banded(n, band)
    ids = np.arange(n)
    ref = np.abs(ids[:, None] - ids[None, :]) <= band
    assert np.array_equal(pat.to_dense(), ref)


def test_uniform_supernodes_cover():
    sup = uniform_supernodes(103, 8)
    assert sup[0, 0] == 0 and sup[-1, 1] == 103
    assert (sup[1:, 0] == sup[:-1, 1]).all()
    with pytest.raises(ValueError):
        uniform_supernodes(10, 0)


def test_store_is_o_nnz_not_n_squared():
    """Structure-only allocation check at n >= 20_000: building the packed
    store must stay O(nnz(L+U)) — no (n, n) array anywhere (that would be
    3.2 GB of float64; the tracemalloc ceiling is 256 MB)."""
    n, band, width = 20_000, 4, 8
    pat = CSCPattern.banded(n, band)
    tracemalloc.start()
    store = PanelStore(pat, uniform_supernodes(n, width))
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert store.total_entries <= 4 * pat.nnz
    assert max(b.size for b in store.blocks) < n
    assert store.nbytes < 64 * 1024 * 1024
    assert peak < 256 * 1024 * 1024, f"peak {peak/1e6:.0f} MB"
    assert store.pad_entries >= 0


def test_numeric_factorize_20k_never_goes_dense():
    """Full sparse-path factorization + solve at n = 20_000 under a
    tracemalloc ceiling far below any (n, n) allocation."""
    n, band, width = 20_000, 4, 8
    a = banded_full(n, band=band)
    pat = CSCPattern.banded(n, band)
    sup = uniform_supernodes(n, width)
    vals = generic_values_csr(a)
    b = np.random.default_rng(9).standard_normal(n)
    tracemalloc.start()
    num = numeric_factorize(a, values=vals, pattern=pat, supernodes=sup)
    x = solve_factored(num, b)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < 256 * 1024 * 1024, f"peak {peak/1e6:.0f} MB"
    assert num.store_entries <= 4 * pat.nnz
    resid = np.linalg.norm(b - csr_matvec(a, vals, x)) / np.linalg.norm(b)
    assert resid <= 1e-10


def test_store_scatter_detects_escaping_values():
    """A value whose slot the prediction lacks must raise, sparse path too
    (the dense path's validate_symbolic contract)."""
    a, sym, pattern, _ = _setup("banded")
    vals = generic_values_csr(a) * 1e-6
    bad = pattern.copy()
    for r in range(a.n - 1, -1, -1):
        cs = a.row(r)
        cs = cs[cs != r]
        if len(cs):
            bad[r, cs[0]] = False
            break
    with pytest.raises(ValueError, match="escaped the symbolic prediction"):
        numeric_factorize(a, sym, values=vals,
                          pattern=CSCPattern.from_dense(bad))
