"""Import hypothesis, or stub it so the suite still collects and runs.

``hypothesis`` is an optional dev dependency (``pip install repro[test]``).
When it is absent the property-based tests are skipped — everything else in
the module must keep running, so the stub mirrors the tiny API surface the
tests use: ``given`` (skips the test), ``settings`` (identity decorator), and
a ``strategies`` namespace whose members are inert callables (``st.composite``
returns a function so module-level ``digraphs()`` calls still work).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        @staticmethod
        def composite(fn):
            return lambda *a, **k: None

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
