"""Multi-device conformance tier for the distributed plan pipeline
(DESIGN.md §11).

The contract under test: ``analyze`` -> ``factorize`` -> ``solve`` through
a sharded mesh produces **bitwise-identical** results at every device
count.  Device count is locked at jax init, so each count {1, 2, 8} runs
in its own subprocess under ``XLA_FLAGS=--xla_force_host_platform_device_
count=N`` over *every* generator in ``sparse/matrices.py``; the parent
process computes the mesh-less reference digests and requires equality of
counts, pattern, panel partition, factors, solutions, and
pickle-roundtrip factors — plus cross-process pickling (a plan analyzed
on 8 devices refactorizes bitwise in the 1-device parent).

The property-based half (via ``_hypothesis_compat``) pins the fingerprint
merge algebra the tier relies on: per-shard partial fingerprints over any
source sharding fold to exactly the single-shard fingerprints, and the
T2/T3 supernode boundaries are invariant under the shard count.
"""
import hashlib
import json
import os
import pickle
import subprocess
import sys

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.distributed import assign_sources, ownership_mask
from repro.core.gsofa import prepare_graph
from repro.core.multisource import run_multisource
from repro.sparse.csr import csr_from_dense
from repro.supernodes import ColumnFingerprints, detect_from_fingerprints
from repro.supernodes.fingerprint import fingerprints_from_graph

DEVICE_COUNTS = (1, 2, 8)

# every generator in sparse/matrices.py, sized for subprocess turnaround
_GEN_SRC = """
GENERATORS = {
    "grid2d": lambda: grid2d_laplacian(10),
    "grid3d": lambda: grid3d_laplacian(5),
    "circuit": lambda: circuit_like(200, seed=7),
    "economic": lambda: economic_like(192, block=16, seed=2),
    "chemical": lambda: chemical_like(240, stage=16, seed=3),
    "banded": lambda: banded_random(160, band=6, seed=4),
    "banded_full": lambda: banded_full(150, band=5),
    "random": lambda: random_pattern(120, density=0.02, seed=5),
    "bbd": lambda: bordered_block_diagonal(320, block=16, border=32, seed=6),
}
"""

_SCRIPT = r"""
import sys, json, pickle, hashlib
import numpy as np
import jax

n_dev = int(sys.argv[1])
plan_out = sys.argv[2]
assert len(jax.devices()) == n_dev, (len(jax.devices()), n_dev)

from repro.api import LUOptions, analyze
from repro.launch.mesh import make_flat_mesh
from repro.sparse import (
    banded_full, banded_random, bordered_block_diagonal, chemical_like,
    circuit_like, economic_like, grid2d_laplacian, grid3d_laplacian,
    permute_csr, random_pattern, rcm_order,
)
from repro.sparse.numeric import generic_values_csr

__GEN_SRC__

def digest(*arrays):
    h = hashlib.sha256()
    for arr in arrays:
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()

out = {}
for name in sorted(GENERATORS):
    a = GENERATORS[name]()
    a = permute_csr(a, rcm_order(a))
    mesh = make_flat_mesh()
    plan = analyze(a, LUOptions(concurrency=32, supernode_relax=2),
                   mesh=mesh)
    values = generic_values_csr(a)
    factor = plan.factorize(values)
    rng = np.random.default_rng(0)
    b1 = rng.standard_normal(a.n)
    bk = rng.standard_normal((a.n, 3))
    plan2 = pickle.loads(pickle.dumps(plan))
    factor2 = plan2.factorize(values)
    out[name] = {
        "counts": digest(plan.sym.l_counts, plan.sym.u_counts),
        "pattern": digest(plan.pattern.indptr, plan.pattern.rowind),
        "partition": digest(plan.schedule.supernodes,
                            plan.schedule.partition.assignment),
        "factors": digest(*factor.num.store.blocks),
        "solve": digest(factor.solve(b1).x, factor.solve(bk).x),
        "pickle_roundtrip": digest(*factor2.num.store.blocks),
        "n_devices": plan.n_devices,
        "n_panels": plan.n_supernodes,
        "max_level_width": max(len(lv) for lv in plan.schedule.levels),
        "devices_with_panels":
            int(np.unique(plan.placement.device_of_panel).size),
    }
    if name == "circuit":
        with open(plan_out, "wb") as f:
            pickle.dump(plan, f)

# dynamic-runtime sweep (8-device leg only): the work-stealing scheduler
# drives the analyze over the forced devices; every plan is saved so the
# parent can run the elasticity round-trip (place() onto smaller meshes)
if n_dev == 8:
    dyn_plans = {}
    for name in sorted(GENERATORS):
        a = GENERATORS[name]()
        a = permute_csr(a, rcm_order(a))
        dplan = analyze(a, LUOptions(concurrency=32, supernode_relax=2,
                                     runtime="dynamic"))
        out[name]["dyn_counts"] = digest(dplan.sym.l_counts,
                                         dplan.sym.u_counts)
        out[name]["dyn_pattern"] = digest(dplan.pattern.indptr,
                                          dplan.pattern.rowind)
        out[name]["dyn_devices"] = dplan.sym.runtime["n_devices"]
        dyn_plans[name] = dplan
    with open(plan_out + ".dyn", "wb") as f:
        pickle.dump(dyn_plans, f)
print("RESULT " + json.dumps(out))
""".replace("__GEN_SRC__", _GEN_SRC)


def _digest(*arrays) -> str:
    h = hashlib.sha256()
    for arr in arrays:
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _generators():
    from repro.sparse import (  # noqa: F401 - names used by _GEN_SRC
        banded_full, banded_random, bordered_block_diagonal, chemical_like,
        circuit_like, economic_like, grid2d_laplacian, grid3d_laplacian,
        random_pattern,
    )

    ns = dict(locals())
    exec(_GEN_SRC, ns)          # the literal dict the subprocesses run
    return ns["GENERATORS"]


@pytest.fixture(scope="module")
def reference():
    """Mesh-less single-device digests computed in-process — the anchor
    every forced device count must match bitwise."""
    from repro.api import LUOptions, analyze
    from repro.sparse import permute_csr, rcm_order
    from repro.sparse.numeric import generic_values_csr

    out = {}
    for name, gen in sorted(_generators().items()):
        a = gen()
        a = permute_csr(a, rcm_order(a))
        plan = analyze(a, LUOptions(concurrency=32, supernode_relax=2))
        values = generic_values_csr(a)
        factor = plan.factorize(values)
        rng = np.random.default_rng(0)
        b1 = rng.standard_normal(a.n)
        bk = rng.standard_normal((a.n, 3))
        out[name] = {
            "counts": _digest(plan.sym.l_counts, plan.sym.u_counts),
            "pattern": _digest(plan.pattern.indptr, plan.pattern.rowind),
            "partition": _digest(plan.schedule.supernodes,
                                 plan.schedule.partition.assignment),
            "factors": _digest(*factor.num.store.blocks),
            "solve": _digest(factor.solve(b1).x, factor.solve(bk).x),
        }
    return out


@pytest.fixture(scope="module")
def conformance(tmp_path_factory):
    """One subprocess per forced device count; returns
    {count: (digests, pickled-plan path)}."""
    tmp = tmp_path_factory.mktemp("dplan")
    script = tmp / "conformance.py"
    script.write_text(_SCRIPT)
    results = {}
    for count in DEVICE_COUNTS:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={count}"
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        plan_path = tmp / f"plan_{count}.pkl"
        proc = subprocess.run(
            [sys.executable, str(script), str(count), str(plan_path)],
            env=env, capture_output=True, text=True, timeout=1200)
        assert proc.returncode == 0, proc.stderr[-4000:]
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT ")][-1]
        results[count] = (json.loads(line[len("RESULT "):]), plan_path)
    return results


@pytest.mark.parametrize("count", DEVICE_COUNTS)
def test_symbolic_outputs_match_reference(count, conformance, reference):
    """Counts, streamed pattern, and panel partition are identical to the
    mesh-less single-device analysis at every device count."""
    got, _ = conformance[count]
    for name, ref in reference.items():
        for key in ("counts", "pattern", "partition"):
            assert got[name][key] == ref[key], (count, name, key)


@pytest.mark.parametrize("count", DEVICE_COUNTS)
def test_factors_bitwise_identical(count, conformance, reference):
    got, _ = conformance[count]
    for name, ref in reference.items():
        assert got[name]["factors"] == ref["factors"], (count, name)


@pytest.mark.parametrize("count", DEVICE_COUNTS)
def test_solve_bitwise_identical(count, conformance, reference):
    """Single-RHS and multi-RHS solutions (batched level solves + per-
    device segments) are bitwise-identical at every device count."""
    got, _ = conformance[count]
    for name, ref in reference.items():
        assert got[name]["solve"] == ref["solve"], (count, name)


@pytest.mark.parametrize("count", DEVICE_COUNTS)
def test_distributed_plans_pickle(count, conformance, reference):
    """In-subprocess pickle roundtrips refactorize bitwise, and the plan's
    recorded mesh width matches the forced device count."""
    got, _ = conformance[count]
    for name, ref in reference.items():
        assert got[name]["pickle_roundtrip"] == ref["factors"], (count, name)
        assert got[name]["n_devices"] == count


@pytest.mark.parametrize("count", DEVICE_COUNTS)
def test_placement_spreads_panels(count, conformance):
    """Every device the level widths can reach receives panel work: the
    per-level LPT packing fills min(devices, level width) bins, so the
    widest level bounds coverage."""
    got, _ = conformance[count]
    for name, rec in got.items():
        expect = min(count, rec["max_level_width"])
        assert rec["devices_with_panels"] == expect, (count, name)


def test_dynamic_runtime_matches_reference_on_8_devices(conformance,
                                                        reference):
    """``LUOptions(runtime="dynamic")`` under 8 forced devices: the
    work-stealing scheduler's counts and streamed pattern are bitwise the
    mesh-less reference on every generator."""
    got, _ = conformance[8]
    for name, ref in reference.items():
        assert got[name]["dyn_counts"] == ref["counts"], name
        assert got[name]["dyn_pattern"] == ref["pattern"], name
        assert got[name]["dyn_devices"] == 8, name


def test_dynamic_plan_elastic_replacement(conformance, reference):
    """Elasticity round-trip: plans the dynamic runtime analyzed under 8
    forced devices reload in this (1-device) process, ``place()`` onto
    D in {1, 2}, and factorize + solve bitwise-identically to the
    mesh-less reference on every generator."""
    from repro.sparse.numeric import generic_values_csr

    _, plan_path = conformance[8]
    with open(str(plan_path) + ".dyn", "rb") as f:
        dyn_plans = pickle.load(f)
    assert sorted(dyn_plans) == sorted(reference)
    for name, plan in sorted(dyn_plans.items()):
        values = generic_values_csr(plan.a)
        rng = np.random.default_rng(0)
        b1 = rng.standard_normal(plan.n)
        bk = rng.standard_normal((plan.n, 3))
        for d in (1, 2):
            p = pickle.loads(pickle.dumps(plan)).place(d)
            assert p.placement.n_devices <= d
            factor = p.factorize(values)
            assert _digest(*factor.num.store.blocks) == \
                reference[name]["factors"], (name, d)
            assert _digest(factor.solve(b1).x, factor.solve(bk).x) == \
                reference[name]["solve"], (name, d)


def test_cross_process_plan_reuse(conformance, reference):
    """A plan analyzed on 8 forced devices unpickles in this (1-device)
    process and refactorizes bitwise — the refactorization-server pattern
    survives distribution."""
    from repro.sparse.numeric import generic_values_csr

    _, plan_path = conformance[8]
    with open(plan_path, "rb") as f:
        plan = pickle.load(f)
    assert plan.n_devices == 8
    factor = plan.factorize(generic_values_csr(plan.a))
    assert _digest(*factor.num.store.blocks) == \
        reference["circuit"]["factors"]


# ---------------------------------------------------------------------------
# fingerprint merge: sharded partials == single-shard (the algebra the
# distributed analyze path rests on)
# ---------------------------------------------------------------------------

def _sharded_fingerprints(a, n_shards: int):
    """Accumulate per-shard fingerprints exactly like the distributed
    driver (ownership-masked sources), then fold them on the host."""
    graph = prepare_graph(a)
    srcs_mat = assign_sources(a.n, n_shards)
    owned = ownership_mask(srcs_mat)
    shards = []
    for d in range(n_shards):
        fp = ColumnFingerprints(n=a.n)
        srcs = srcs_mat[d][owned[d]]
        if len(srcs):
            run_multisource(graph, concurrency=16, sources=srcs,
                            on_chunk=fp.update)
        shards.append(fp)
    merged = shards[0]
    for shard in shards[1:]:
        merged.merge(shard)
    return merged


def _assert_fingerprints_equal(got: ColumnFingerprints,
                               want: ColumnFingerprints) -> None:
    assert np.array_equal(got.counts, want.counts)
    assert np.array_equal(got.hsum, want.hsum)
    assert np.array_equal(got.hxor, want.hxor)
    assert np.array_equal(got.subdiag, want.subdiag)
    assert got.complete and want.complete


@st.composite
def digraph_shards(draw):
    n = draw(st.integers(min_value=2, max_value=32))
    density = draw(st.floats(min_value=0.03, max_value=0.35))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    n_shards = draw(st.integers(min_value=1, max_value=6))
    rng = np.random.default_rng(seed)
    dense = rng.random((n, n)) < density
    np.fill_diagonal(dense, True)
    return csr_from_dense(dense), n_shards


@given(digraph_shards())
@settings(max_examples=25, deadline=None)
def test_property_sharded_merge_equals_single_shard(case):
    a, n_shards = case
    single = fingerprints_from_graph(prepare_graph(a), concurrency=16)
    merged = _sharded_fingerprints(a, n_shards)
    _assert_fingerprints_equal(merged, single)


@given(digraph_shards(), st.integers(min_value=0, max_value=3))
@settings(max_examples=25, deadline=None)
def test_property_boundaries_invariant_under_shard_count(case, relax):
    """T2 (relax=0) and T3 (relax>0) supernode ranges do not depend on how
    sources were sharded."""
    a, n_shards = case
    single = fingerprints_from_graph(prepare_graph(a), concurrency=16)
    merged = _sharded_fingerprints(a, n_shards)
    assert np.array_equal(
        detect_from_fingerprints(merged, relax=relax),
        detect_from_fingerprints(single, relax=relax))


# deterministic counterparts: same helper, fixed cases, so the contract is
# exercised even when hypothesis is not installed
@pytest.mark.parametrize("seed,n_shards", [(0, 2), (1, 3), (2, 5), (3, 8)])
def test_sharded_merge_equals_single_shard(seed, n_shards):
    rng = np.random.default_rng(seed)
    n = 40
    dense = rng.random((n, n)) < 0.08
    np.fill_diagonal(dense, True)
    a = csr_from_dense(dense)
    single = fingerprints_from_graph(prepare_graph(a), concurrency=16)
    merged = _sharded_fingerprints(a, n_shards)
    _assert_fingerprints_equal(merged, single)
    for relax in (0, 2):
        assert np.array_equal(
            detect_from_fingerprints(merged, relax=relax),
            detect_from_fingerprints(single, relax=relax))


def test_merge_rejects_overlapping_shards():
    rng = np.random.default_rng(4)
    dense = rng.random((12, 12)) < 0.3
    np.fill_diagonal(dense, True)
    a = csr_from_dense(dense)
    graph = prepare_graph(a)
    fp1 = ColumnFingerprints(n=a.n)
    fp2 = ColumnFingerprints(n=a.n)
    run_multisource(graph, concurrency=8, on_chunk=fp1.update)
    run_multisource(graph, concurrency=8,
                    sources=np.array([0, 1], np.int32), on_chunk=fp2.update)
    with pytest.raises(ValueError, match="overlapping"):
        fp1.merge(fp2)


def test_device_merge_matches_host_merge_on_one_device():
    """merge_fingerprint_shards on a 1-device flat mesh is the identity
    ring — bitwise the host fingerprints (the conformance subprocesses
    cover the >1-device rings)."""
    from repro.launch.mesh import make_flat_mesh
    from repro.runtime.collectives import merge_fingerprint_shards

    rng = np.random.default_rng(5)
    dense = rng.random((20, 20)) < 0.2
    np.fill_diagonal(dense, True)
    a = csr_from_dense(dense)
    fp = fingerprints_from_graph(prepare_graph(a), concurrency=8)
    mesh = make_flat_mesh(1)
    merged = merge_fingerprint_shards(mesh, mesh.axis_names[0], [fp])
    _assert_fingerprints_equal(merged, fp)


# ---------------------------------------------------------------------------
# blocked / autotuned plan cross-process replay (DESIGN.md §16): the chosen
# knobs and merged partition are frozen onto the pickled plan, so factorize
# and solve digests must replay bitwise in a different process
# ---------------------------------------------------------------------------

_BLOCKED_SCRIPT = r"""
import sys, json, pickle, hashlib
import numpy as np

plan_out = sys.argv[1]

from repro.api import LUOptions, analyze
from repro.sparse import (
    banded_full, banded_random, bordered_block_diagonal, chemical_like,
    circuit_like, economic_like, grid2d_laplacian, grid3d_laplacian,
    permute_csr, random_pattern, rcm_order,
)
from repro.sparse.numeric import generic_values_csr

__GEN_SRC__

def digest(*arrays):
    h = hashlib.sha256()
    for arr in arrays:
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()

CASES = {
    "blocked": LUOptions(concurrency=32, supernode_relax=2, blocking=True),
    "autotuned": LUOptions(concurrency=32, autotune=True),
}
out = {}
plans = {}
for name in ("circuit", "bbd", "grid2d"):
    a = GENERATORS[name]()
    a = permute_csr(a, rcm_order(a))
    values = generic_values_csr(a)
    rng = np.random.default_rng(0)
    b1 = rng.standard_normal(a.n)
    bk = rng.standard_normal((a.n, 3))
    for case, opts in CASES.items():
        plan = analyze(a, opts)
        factor = plan.factorize(values)
        out[f"{name}/{case}"] = {
            "factors": digest(*factor.num.store.blocks),
            "solve": digest(factor.solve(b1).x, factor.solve(bk).x),
            "n_panels": plan.n_supernodes,
            "chosen": (plan.tuned.chosen if plan.tuned is not None
                       else None),
        }
        plans[f"{name}/{case}"] = plan
with open(plan_out, "wb") as f:
    pickle.dump(plans, f)
print("RESULT " + json.dumps(out))
""".replace("__GEN_SRC__", _GEN_SRC)


@pytest.fixture(scope="module")
def blocked_conformance(tmp_path_factory):
    """One subprocess that analyzes with blocking / autotune on, digests
    its factors + solves, and pickles every plan for the parent."""
    tmp = tmp_path_factory.mktemp("blocked_plan")
    script = tmp / "blocked.py"
    script.write_text(_BLOCKED_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    plan_path = tmp / "plans.pkl"
    proc = subprocess.run(
        [sys.executable, str(script), str(plan_path)],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):]), plan_path


def test_blocked_plans_replay_bitwise_across_processes(blocked_conformance):
    """Every pickled blocked/autotuned plan factorizes and solves in THIS
    process to exactly the digests the analyzing process recorded — the
    frozen partition + knobs leave nothing host- or process-dependent."""
    from repro.sparse.numeric import generic_values_csr

    digests, plan_path = blocked_conformance
    with open(plan_path, "rb") as f:
        plans = pickle.load(f)
    assert sorted(plans) == sorted(digests)
    for key, plan in sorted(plans.items()):
        values = generic_values_csr(plan.a)
        factor = plan.factorize(values)
        assert _digest(*factor.num.store.blocks) == \
            digests[key]["factors"], key
        rng = np.random.default_rng(0)
        b1 = rng.standard_normal(plan.n)
        bk = rng.standard_normal((plan.n, 3))
        assert _digest(factor.solve(b1).x, factor.solve(bk).x) == \
            digests[key]["solve"], key
        assert plan.n_supernodes == digests[key]["n_panels"], key


def test_autotuned_plans_freeze_chosen_knobs(blocked_conformance):
    """The subprocess's TuneReport survives pickling with the chosen knob
    values applied to the plan's options (replay never re-tunes)."""
    digests, plan_path = blocked_conformance
    with open(plan_path, "rb") as f:
        plans = pickle.load(f)
    for key, plan in sorted(plans.items()):
        if not key.endswith("/autotuned"):
            assert plan.tuned is None
            continue
        assert plan.tuned is not None
        assert plan.tuned.chosen == digests[key]["chosen"], key
        assert plan.options.blocking is True
        assert plan.options.supernode_relax == \
            plan.tuned.chosen["supernode_relax"]
        # replanning the loaded plan with its own (frozen) options
        # reproduces the same partition without re-running autotune
        from repro.api import replan

        re = replan(plan, plan.options.replace(autotune=False))
        assert np.array_equal(re.schedule.supernodes,
                              plan.schedule.supernodes), key


def test_ownership_mask_covers_every_source_once():
    for n, d in ((10, 4), (17, 8), (3, 8), (64, 3)):
        mat = assign_sources(n, d)
        owned = ownership_mask(mat)
        srcs = mat[owned]
        assert len(srcs) == n
        assert np.array_equal(np.sort(srcs), np.arange(n))
