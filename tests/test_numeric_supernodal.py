"""Supernodal numeric LU (repro.numeric) vs the dense no-pivot oracle.

Contract (ISSUE 2 / DESIGN.md §4): on every matrices.py generator the
supernodal factors match ``lu_nopivot`` to <= 1e-10 relative error, every
nonzero stays inside the symbolic prediction, and the factors are bitwise
invariant to the panel packing policy.  Plus the PR's bugfix regressions:
checkpoint restart under a changed concurrency, zero-pivot surfacing, and
pack_panels validation.
"""
import os

import numpy as np
import pytest

from repro.core.gsofa import dense_pattern, prepare_graph
from repro.core.symbolic import symbolic_factorize
from repro.numeric import (
    NumericResult, build_schedule, factorize_columns, numeric_factorize,
)
from repro.sparse import (
    banded_random, chemical_like, circuit_like, economic_like,
    grid2d_laplacian, grid3d_laplacian, permute_csr, random_pattern,
    rcm_order,
)
from repro.sparse.csr import csr_from_dense
from repro.sparse.numeric import ZeroPivotError, generic_values, lu_nopivot
from repro.supernodes import pack_panels

# every generator in sparse/matrices.py, at n <= 1024
GENERATORS = {
    "grid2d": lambda: grid2d_laplacian(14),
    "grid3d": lambda: grid3d_laplacian(6),
    "circuit": lambda: circuit_like(300, seed=7),
    "economic": lambda: economic_like(256, block=16, seed=2),
    "chemical": lambda: chemical_like(320, stage=16, seed=3),
    "banded": lambda: banded_random(240, band=6, seed=4),
    "random": lambda: random_pattern(160, density=0.02, seed=5),
}


def _setup(name, relax=0):
    a = GENERATORS[name]()
    a = permute_csr(a, rcm_order(a))
    sym = symbolic_factorize(a, concurrency=64, detect_supernodes=True,
                             supernode_relax=relax)
    pattern = dense_pattern(prepare_graph(a))
    values = generic_values(a)
    return a, sym, pattern, values


def _rel_err(got, ref):
    return np.abs(got - ref).max() / np.abs(ref).max()


# ---------------------------------------------------------------------------
# value parity + pattern containment across the generator suite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_parity_and_containment(name):
    a, sym, pattern, values = _setup(name)
    num = numeric_factorize(a, sym, values=values, pattern=pattern)
    l0, u0 = lu_nopivot(values)
    assert _rel_err(num.l, l0) <= 1e-10
    assert _rel_err(num.u, u0) <= 1e-10
    # every nonzero inside the symbolic prediction (validate_symbolic contract)
    pat = pattern.copy()
    np.fill_diagonal(pat, True)
    assert not ((num.l != 0) & ~(pat | np.eye(a.n, dtype=bool))).any()
    assert not ((num.u != 0) & ~pat).any()
    # reconstruction: L @ U == A on A's structure
    np.testing.assert_allclose(num.reconstruct(), values,
                               rtol=1e-9, atol=1e-9 * np.abs(values).max())


@pytest.mark.parametrize("name", ["grid2d", "circuit"])
def test_relaxed_supernodes_keep_parity(name):
    """T3-merged panels carry explicit zeros; values must not change."""
    a, sym, pattern, values = _setup(name, relax=4)
    num = numeric_factorize(a, sym, values=values, pattern=pattern)
    l0, u0 = lu_nopivot(values)
    assert _rel_err(num.l, l0) <= 1e-10
    assert _rel_err(num.u, u0) <= 1e-10


def test_column_baseline_parity():
    a, _, pattern, values = _setup("economic")
    l, u = factorize_columns(values, pattern)
    l0, u0 = lu_nopivot(values)
    assert _rel_err(l, l0) <= 1e-10
    assert _rel_err(u, u0) <= 1e-10


def test_default_arguments_end_to_end():
    """numeric_factorize(a) alone: symbolic + pattern computed on the fly."""
    a = circuit_like(96, seed=11)
    num = numeric_factorize(a)
    l0, u0 = lu_nopivot(generic_values(a))
    assert _rel_err(num.l, l0) <= 1e-10
    assert _rel_err(num.u, u0) <= 1e-10


def test_symbolic_without_supernodes_falls_back():
    """A SymbolicResult lacking the partition still factorizes (serial
    detector on the pattern)."""
    a = banded_random(120, band=5, seed=9)
    sym = symbolic_factorize(a, concurrency=32)          # no detection
    assert sym.supernodes is None
    num = numeric_factorize(a, sym, values=generic_values(a))
    l0, u0 = lu_nopivot(generic_values(a))
    assert _rel_err(num.l, l0) <= 1e-10


def test_badly_scaled_values_keep_relative_contract():
    """The pattern-escape guard is relative to the matrix scale — tiny-scale
    inputs must neither false-raise nor silently mask real escapes."""
    a, sym, pattern, values = _setup("banded")
    tiny = values * 1e-6
    num = numeric_factorize(a, sym, values=tiny, pattern=pattern)
    l0, u0 = lu_nopivot(tiny)
    assert _rel_err(num.l, l0) <= 1e-10
    assert _rel_err(num.u, u0) <= 1e-10
    # a genuine under-prediction (pattern missing a position where A itself
    # is nonzero) raises even at tiny scale instead of being zeroed away
    bad = pattern.copy()
    for r in range(a.n - 1, -1, -1):
        cs = a.row(r)
        cs = cs[cs != r]
        if len(cs):
            bad[r, cs[0]] = False
            break
    with pytest.raises(ValueError, match="escaped the symbolic prediction"):
        numeric_factorize(a, sym, values=tiny, pattern=bad)


def test_kernel_backend_close_in_f32():
    a, sym, pattern, values = _setup("random")
    num = numeric_factorize(a, sym, values=values, pattern=pattern,
                            backend="kernel")
    l0, u0 = lu_nopivot(values)
    assert _rel_err(num.l, l0) <= 1e-4
    assert _rel_err(num.u, u0) <= 1e-4


# ---------------------------------------------------------------------------
# panel-schedule independence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["grid2d", "chemical"])
def test_packing_policy_does_not_change_factors(name):
    """LPT vs contiguous bins only regroup independent panels within a
    dependency level — factors must be bitwise identical."""
    a, sym, pattern, values = _setup(name, relax=2)
    lpt = numeric_factorize(a, sym, values=values, pattern=pattern,
                            policy="lpt")
    contig = numeric_factorize(a, sym, values=values, pattern=pattern,
                               policy="contiguous")
    assert np.array_equal(lpt.l, contig.l)
    assert np.array_equal(lpt.u, contig.u)
    more_bins = numeric_factorize(a, sym, values=values, pattern=pattern,
                                  n_bins=3)
    assert np.array_equal(lpt.l, more_bins.l)


def test_schedule_levels_are_topological():
    a, sym, pattern, _ = _setup("circuit", relax=2)
    sched = build_schedule(pattern, sym.supernodes)
    for j, anc in enumerate(sched.ancestors):
        assert (anc < j).all()
        assert (sched.level[anc] < sched.level[j]).all()
    executed = np.concatenate(sched.levels)
    assert sorted(executed.tolist()) == list(range(sched.n_panels))
    stats = sched.stats()
    assert stats["n_panels"] == len(sym.supernodes)
    assert stats["n_levels"] == sched.n_levels


def test_schedule_rejects_bad_supernodes():
    pattern = np.eye(6, dtype=bool)
    with pytest.raises(ValueError):
        build_schedule(pattern, np.array([[0, 3], [4, 6]]))   # gap
    with pytest.raises(ValueError):
        build_schedule(pattern, np.array([[0, 3]]))           # short cover


# ---------------------------------------------------------------------------
# zero-pivot regression (confirmed bug: silent inf/NaN propagation)
# ---------------------------------------------------------------------------

def test_lu_nopivot_raises_on_zero_pivot():
    with pytest.raises(ZeroPivotError) as ei:
        lu_nopivot(np.array([[0.0, 1.0], [1.0, 1.0]]))
    assert ei.value.k == 0
    # near-zero and non-finite pivots are rejected too
    with pytest.raises(ZeroPivotError):
        lu_nopivot(np.array([[1e-300, 1.0], [1.0, 1.0]]))
    with pytest.raises(ZeroPivotError):
        lu_nopivot(np.array([[np.nan, 1.0], [1.0, 1.0]]))


def test_lu_nopivot_no_silent_nan():
    """The old behavior: RuntimeWarning only, inf/NaN in the factors."""
    dense = np.array([[1.0, 2.0], [2.0, 4.0]])    # pivot 2 becomes exactly 0
    with pytest.raises(ZeroPivotError) as ei:
        lu_nopivot(dense)
    assert ei.value.k == 1


def test_supernodal_surfaces_zero_pivot_per_panel():
    vals = np.array([[0.0, 1.0], [1.0, 1.0]])
    a = csr_from_dense(np.ones((2, 2)))
    with pytest.raises(ZeroPivotError) as ei:
        numeric_factorize(a, values=vals)
    assert ei.value.k == 0

    with pytest.raises(ZeroPivotError):
        factorize_columns(vals, np.ones((2, 2), dtype=bool))


# ---------------------------------------------------------------------------
# checkpoint-restart regression (confirmed bug: changed concurrency dropped
# sources silently)
# ---------------------------------------------------------------------------

def test_checkpoint_restart_with_changed_concurrency(tmp_path):
    a = economic_like(128, block=16, seed=33)
    ref = symbolic_factorize(a, concurrency=32)
    path = os.path.join(tmp_path, "ckpt.jsonl")
    symbolic_factorize(a, concurrency=32, checkpoint_path=path)
    # crash after the first chunk: truncate to one record
    with open(path) as f:
        first = f.readline()
    with open(path, "w") as f:
        f.write(first)
    # restart on a DIFFERENT grid: the old code matched recorded starts
    # against the new grid and silently zeroed rows 32..63
    r = symbolic_factorize(a, concurrency=64, checkpoint_path=path)
    assert np.array_equal(r.l_counts, ref.l_counts)
    assert np.array_equal(r.u_counts, ref.u_counts)
    assert r.lu_nnz == ref.lu_nnz


@pytest.mark.parametrize("restart_c", [16, 48, 128])
def test_checkpoint_restart_grid_sweep(tmp_path, restart_c):
    a = circuit_like(96, seed=21)
    ref = symbolic_factorize(a, concurrency=32)
    path = os.path.join(tmp_path, "ckpt.jsonl")
    symbolic_factorize(a, concurrency=32, checkpoint_path=path)
    with open(path) as f:
        keep = f.readlines()[:2]
    with open(path, "w") as f:
        f.writelines(keep)
    r = symbolic_factorize(a, concurrency=restart_c, checkpoint_path=path)
    assert np.array_equal(r.l_counts, ref.l_counts)
    assert np.array_equal(r.u_counts, ref.u_counts)


# ---------------------------------------------------------------------------
# pack_panels validation regression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_panels", [0, -1])
def test_pack_panels_rejects_empty_partition_with_work(n_panels):
    ranges = np.array([[0, 2], [2, 3]])
    counts = np.array([2, 1, 0])
    with pytest.raises(ValueError):
        pack_panels(ranges, counts, n_panels)


def test_pack_panels_empty_inputs_still_fine():
    part = pack_panels(np.zeros((0, 2), np.int64), np.zeros(0, np.int64), 0)
    assert part.n_panels == 0 and part.balance_ratio == 1.0


# ---------------------------------------------------------------------------
# result surface
# ---------------------------------------------------------------------------

def test_numeric_result_counters():
    a, sym, pattern, values = _setup("grid2d", relax=2)
    num = numeric_factorize(a, sym, values=values, pattern=pattern)
    assert isinstance(num, NumericResult)
    assert num.n == a.n
    assert num.n_supernodes == len(sym.supernodes)
    assert num.n_levels >= 1
    assert num.n_updates > 0 and num.gemm_flops > 0
    assert num.elapsed_s > 0
    assert np.array_equal(np.diag(num.l), np.ones(a.n))
