"""Batched execution tier (ISSUE 8 / DESIGN.md §14).

Contract: ``plan.factorize_batch`` / ``factor.solve_batch`` are pure
scheduling changes — every per-system factor block, solution, refinement
history, and accepted-iteration count is **bitwise-identical** to running
the sequential ``plan.factorize(values[i])`` / ``factor.solve(b[i])`` loop,
on every matrix generator, for vector and multi-RHS right-hand sides.
Error behaviour (shape validation, zero pivots, pattern escapes) must name
the offending system, and batched results round-trip through the zero-copy
``system(i)`` views.
"""
import pickle

import numpy as np
import pytest

from repro.api import BatchedLUFactorization, LUOptions, analyze
from repro.sparse import (
    banded_full, banded_random, bordered_block_diagonal, chemical_like,
    circuit_like, economic_like, grid2d_laplacian, grid3d_laplacian,
    indefinite, permute_csr, random_pattern, rcm_order, shuffled_dominant,
)
from repro.sparse.numeric import ZeroPivotError, generic_values_csr

# every generator in sparse/matrices.py, at n <= 1024 (test_api.py sizes)
GENERATORS = {
    "grid2d": lambda: grid2d_laplacian(14),
    "grid3d": lambda: grid3d_laplacian(6),
    "circuit": lambda: circuit_like(300, seed=7),
    "economic": lambda: economic_like(256, block=16, seed=2),
    "chemical": lambda: chemical_like(320, stage=16, seed=3),
    "banded": lambda: banded_random(240, band=6, seed=4),
    "banded_full": lambda: banded_full(200, band=5),
    "random": lambda: random_pattern(160, density=0.02, seed=5),
    "bbd": lambda: bordered_block_diagonal(512, block=16, border=32, seed=6),
    "indefinite": lambda: indefinite(160, band=6, seed=1),
    "shuffled": lambda: shuffled_dominant(160, band=5, seed=2),
}

OPTS = LUOptions(concurrency=64, supernode_relax=2)
BATCH = 4


def _matrix(name):
    a = GENERATORS[name]()
    return permute_csr(a, rcm_order(a))


@pytest.fixture(scope="module")
def plans():
    """One analysis per generator, shared across the property tests."""
    return {name: analyze(_matrix(name), OPTS) for name in GENERATORS}


def _values_batch(a, batch=BATCH):
    return np.stack([generic_values_csr(a, seed=s) for s in range(batch)])


# ---------------------------------------------------------------------------
# property: factorize_batch == loop of plan.factorize, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_factorize_batch_bitwise_matches_loop(name, plans):
    plan = plans[name]
    vb = _values_batch(plan.a)
    bf = plan.factorize_batch(vb)
    assert isinstance(bf, BatchedLUFactorization)
    assert bf.batch == BATCH and bf.n == plan.n
    for i in range(BATCH):
        seq = plan.factorize(vb[i])
        for blk_seq, blk_bat in zip(seq.num.store.blocks,
                                    bf.store.blocks):
            assert np.array_equal(blk_seq, blk_bat[i])


# ---------------------------------------------------------------------------
# property: solve_batch == loop of factor.solve, bitwise — (B, n) & (B, n, k)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_solve_batch_vector_bitwise_matches_loop(name, plans):
    plan = plans[name]
    vb = _values_batch(plan.a)
    bf = plan.factorize_batch(vb)
    rhs = np.random.default_rng(1).standard_normal((BATCH, plan.n))
    solved = bf.solve_batch(rhs)
    for i in range(BATCH):
        seq = plan.factorize(vb[i]).solve(rhs[i])
        assert np.array_equal(seq.x, solved.x[i])
        assert seq.residuals == solved.residuals[i]
    assert solved.residual.shape == (BATCH,)
    assert float(solved.residual.max()) < 1e-10


@pytest.mark.parametrize("name", ["grid2d", "circuit", "bbd"])
def test_solve_batch_multirhs_bitwise_matches_loop(name, plans):
    plan = plans[name]
    vb = _values_batch(plan.a)
    bf = plan.factorize_batch(vb)
    rhs = np.random.default_rng(2).standard_normal((BATCH, plan.n, 3))
    solved = bf.solve_batch(rhs)
    for i in range(BATCH):
        seq = plan.factorize(vb[i]).solve(rhs[i])
        assert np.array_equal(seq.x, solved.x[i])
        assert seq.residuals == solved.residuals[i]


def test_refinement_parity_when_disabled(plans):
    """refine_tol=0.0 keeps iterating on both paths; histories and
    accepted counts must still agree per system."""
    plan = plans["circuit"]
    vb = _values_batch(plan.a)
    bf = plan.factorize_batch(vb)
    rhs = np.random.default_rng(3).standard_normal((BATCH, plan.n))
    solved = bf.solve_batch(rhs, refine_iters=3, refine_tol=0.0)
    for i in range(BATCH):
        seq = plan.factorize(vb[i]).solve(rhs[i], refine_iters=3,
                                          refine_tol=0.0)
        assert np.array_equal(seq.x, solved.x[i])
        assert seq.residuals == solved.residuals[i]
        assert seq.refine_accepted == int(solved.refine_accepted[i])


# ---------------------------------------------------------------------------
# zero-copy system views + pickled plans
# ---------------------------------------------------------------------------

def test_system_views_are_zero_copy_and_solve(plans):
    plan = plans["grid2d"]
    vb = _values_batch(plan.a)
    bf = plan.factorize_batch(vb)
    rhs = np.random.default_rng(4).standard_normal(plan.n)
    for i in range(BATCH):
        sys_i = bf.system(i)
        for blk_view, blk_bat in zip(sys_i.num.store.blocks,
                                     bf.store.blocks):
            assert blk_view.base is not None      # a view, not a copy
            assert np.shares_memory(blk_view, blk_bat)
        seq = plan.factorize(vb[i])
        assert np.array_equal(seq.solve(rhs).x, sys_i.solve(rhs).x)


def test_pickled_plan_factorize_batch_identical(plans):
    plan = plans["circuit"]
    vb = _values_batch(plan.a)
    ref = plan.factorize_batch(vb)
    plan2 = pickle.loads(pickle.dumps(plan))
    got = plan2.factorize_batch(vb)
    for b_ref, b_got in zip(ref.store.blocks, got.store.blocks):
        assert np.array_equal(b_ref, b_got)


# ---------------------------------------------------------------------------
# error behaviour names the offending system
# ---------------------------------------------------------------------------

def test_factorize_batch_rejects_bad_shapes(plans):
    plan = plans["grid2d"]
    with pytest.raises(ValueError, match="values_batch"):
        plan.factorize_batch(generic_values_csr(plan.a))      # (nnz,) not 2D
    with pytest.raises(ValueError):
        plan.factorize_batch(np.zeros((2, plan.a.nnz + 1)))


def test_solve_batch_rejects_bad_shapes(plans):
    plan = plans["grid2d"]
    bf = plan.factorize_batch(_values_batch(plan.a))
    with pytest.raises(ValueError):
        bf.solve_batch(np.zeros(plan.n))                      # missing batch
    with pytest.raises(ValueError):
        bf.solve_batch(np.zeros((BATCH + 1, plan.n)))         # wrong batch


def test_zero_pivot_names_failing_system(plans):
    plan = plans["grid2d"]
    vb = _values_batch(plan.a)
    vb[2] = 0.0                                               # singular sys 2
    with pytest.raises(ZeroPivotError):
        plan.factorize_batch(vb)
