"""Distributed GSoFa on 8 host devices (subprocess: device count is locked at
jax init, so multi-device tests run in their own interpreter)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import json
import numpy as np
import jax
from repro.sparse import circuit_like
from repro.core.theory import elimination_fill
from repro.core.gsofa import prepare_graph
from repro.core.distributed import assign_sources, distributed_symbolic

a = circuit_like(160, seed=6)
e = elimination_fill(a); np.fill_diagonal(e, False)
ids = np.arange(a.n)
l_ref = (e & (ids[None, :] < ids[:, None])).sum(1)
u_ref = (e & (ids[None, :] > ids[:, None])).sum(1)
g = prepare_graph(a)
mesh = jax.make_mesh((8,), ("src",))
out = {}
for pol in ("interleave", "contiguous"):
    r = distributed_symbolic(g, mesh, policy=pol)
    out[pol] = {
        "correct": bool(np.array_equal(r["l_counts"], l_ref)
                        and np.array_equal(r["u_counts"], u_ref)),
        "balance": float(r["balance_ratio"]),
    }
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_result():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_distributed_correct_both_policies(dist_result):
    assert dist_result["interleave"]["correct"]
    assert dist_result["contiguous"]["correct"]


def test_interleave_balances_edge_checks(dist_result):
    """Paper Fig 8: round-robin source assignment flattens the inter-device
    workload ratio (paper: 10.31 -> 1.01; threshold is generous)."""
    assert dist_result["contiguous"]["balance"] > 5.0
    assert dist_result["interleave"]["balance"] < 2.0


def test_assign_sources_shapes():
    from repro.core.distributed import assign_sources
    m = assign_sources(10, 4, policy="interleave")
    assert m.shape == (4, 3)
    assert m[1, 0] == 1 and m[1, 1] == 5  # strided
    c = assign_sources(10, 4, policy="contiguous")
    assert c[0, 0] == 0 and c[0, 2] == 2
    # padding repeats the last valid source
    assert m.max() == 9 and c.max() == 9
