"""Training substrate: optimizer, grad accumulation equivalence, gradient
compression with error feedback, data pipeline determinism, loss descent."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config
from repro.data import make_batch_for
from repro.data.pipeline import SyntheticTextPipeline
from repro.models import transformer as tf
from repro.train import compress as gc
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw
from repro.train.steps import make_train_step


def _mesh():
    from repro.launch.mesh import compat_make_mesh
    return compat_make_mesh((1, 1), ("data", "model"))


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_adamw(params)
    acfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0, decay_steps=200)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, grads, opt, acfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_accum_matches_single_step():
    cfg = get_config("smollm-135m").reduced()
    shape = ShapeConfig("s", 16, 4, "train")
    mesh = _mesh()
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, shape).items()}
    params = tf.init_params(jax.random.key(0), cfg, jnp.float32)
    opt = init_adamw(params)
    s1 = make_train_step(cfg, mesh, shape, dtype=jnp.float32, donate=False,
                         micro_steps=1)
    s4 = make_train_step(cfg, mesh, shape, dtype=jnp.float32, donate=False,
                         micro_steps=4)
    p1, _, m1 = s1.fn(params, opt, batch)
    p4, _, m4 = s4.fn(params, opt, batch)
    # losses averaged over microbatches == full-batch loss
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 1e-4, f"accumulated params diverge by {d}"


def test_loss_descends_on_repeated_batch():
    cfg = get_config("smollm-135m").reduced()
    shape = ShapeConfig("s", 32, 4, "train")
    step = make_train_step(cfg, _mesh(), shape, dtype=jnp.float32, donate=False)
    params = tf.init_params(jax.random.key(0), cfg, jnp.float32)
    opt = init_adamw(params)
    batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, shape).items()}
    losses = []
    for _ in range(6):
        params, opt, m = step.fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.01, losses


def test_compression_error_feedback_preserves_sum():
    """With error feedback, the *cumulative* applied gradient converges to the
    cumulative true gradient (the defining property of EF compression)."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.standard_normal(1000) * (10.0 ** rng.uniform(-3, 1)),
                          jnp.float32) for _ in range(50)]
    err = {"g": jnp.zeros(1000, jnp.float32)}
    applied = jnp.zeros(1000, jnp.float32)
    for g in g_true:
        deq, err_new = gc.compress_decompress({"g": g}, err)
        err = err_new
        applied = applied + deq["g"]
    total_true = sum(np.asarray(g) for g in g_true)
    # residual error is bounded by one step's quantization, not 50 steps'
    resid = np.abs(np.asarray(applied) + np.asarray(err["g"]) - total_true).max()
    assert resid < 1e-3, resid
    drift = np.abs(np.asarray(applied) - total_true).max()
    one_step_q = max(float(np.abs(np.asarray(g)).max()) / 127 for g in g_true)
    assert drift <= 2 * one_step_q + 1e-4


def test_pipeline_deterministic_and_restorable():
    p1 = SyntheticTextPipeline(vocab=100, seq_len=16, global_batch=4, seed=3)
    b1 = [p1.next_batch() for _ in range(3)]
    p2 = SyntheticTextPipeline(vocab=100, seq_len=16, global_batch=4, seed=3)
    p2.restore({"step": 2, "seed": 3})
    b2 = p2.next_batch()
    np.testing.assert_array_equal(b1[2]["tokens"], b2["tokens"])
    # sharded generation: rows 2:4 of the global batch match the full batch
    p3 = SyntheticTextPipeline(vocab=100, seq_len=16, global_batch=4, seed=3)
    p3.restore({"step": 1, "seed": 3})
    local = p3.next_batch(local_slice=slice(2, 4))
    np.testing.assert_array_equal(b1[1]["tokens"][2:4], local["tokens"])


def test_pipeline_is_learnable_not_trivial():
    p = SyntheticTextPipeline(vocab=1000, seq_len=256, global_batch=8)
    b = p.next_batch()
    toks = b["tokens"]
    # periodic structure: same (row, pos mod period) mostly repeats
    same = (toks[:, : 256 - 64] == toks[:, 64: 256]).mean()
    assert same > 0.7, same
    # but not constant
    assert len(np.unique(toks)) > 50
