"""Checkpointing: save/restore round trip, torn-write safety, retention,
async writes, elastic re-shard, end-to-end restart equivalence."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.io import latest_step
from repro.configs.base import ShapeConfig, get_config
from repro.data import make_batch_for
from repro.models import transformer as tf
from repro.train.optimizer import init_adamw
from repro.train.steps import make_train_step


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32),
                  "d": [jnp.zeros(()), jnp.full((2,), 7.0)]}}


def test_round_trip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t, extra={"note": "x"})
    loaded, step, extra = load_checkpoint(str(tmp_path), t)
    assert step == 5 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_torn_checkpoint_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crash mid-write at step 2: directory without 'done'
    torn = tmp_path / "step_000000002"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert latest_step(str(tmp_path)) == 1


def test_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    mgr.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]


def test_elastic_reshard(tmp_path):
    """Save under one mesh, restore under another sharding (elastic)."""
    t = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    save_checkpoint(str(tmp_path), 1, t)
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None))}
    from repro.checkpoint import reshard_checkpoint
    placed, step, _ = reshard_checkpoint(str(tmp_path), t, sh)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(placed["w"]), np.asarray(t["w"]))
    assert placed["w"].sharding == sh["w"]


def test_restart_reproduces_uninterrupted_run(tmp_path):
    """Train 4 steps straight vs 2 steps -> checkpoint -> restore -> 2 steps."""
    cfg = get_config("smollm-135m").reduced()
    shape = ShapeConfig("s", 16, 2, "train")
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    step = make_train_step(cfg, mesh, shape, dtype=jnp.float32, donate=False)

    def batches():
        return [{k: jnp.asarray(v) for k, v in make_batch_for(cfg, shape,
                                                              step=i).items()}
                for i in range(4)]

    p = tf.init_params(jax.random.key(0), cfg, jnp.float32)
    o = init_adamw(p)
    for b in batches():
        p, o, _ = step.fn(p, o, b)
    straight = jax.tree.leaves(p)

    p2 = tf.init_params(jax.random.key(0), cfg, jnp.float32)
    o2 = init_adamw(p2)
    bs = batches()
    for b in bs[:2]:
        p2, o2, _ = step.fn(p2, o2, b)
    save_checkpoint(str(tmp_path), 2, (p2, o2))
    (p3, o3), s, _ = load_checkpoint(str(tmp_path), (p2, o2))
    assert s == 2
    p3 = jax.tree.map(jnp.asarray, p3)
    o3 = jax.tree.map(jnp.asarray, o3)
    for b in bs[2:]:
        p3, o3, _ = step.fn(p3, o3, b)
    for a, b_ in zip(straight, jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=0, atol=1e-6)
