"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
config, one forward/train step on CPU — output shapes + no NaNs — plus
decode-vs-teacher-forced consistency (the cache machinery is exact math, not
an approximation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ALL_ARCHS
from repro.configs.base import ShapeConfig, get_config
from repro.data import make_batch_for
from repro.models import transformer as tf

SMOKE = ShapeConfig("smoke", 24, 2, "train")


def _setup(name):
    cfg = get_config(name).reduced()
    params = tf.init_params(jax.random.key(0), cfg, jnp.float32)
    batch = make_batch_for(cfg, SMOKE)
    kw = {k: jnp.asarray(v) for k, v in batch.items() if k in ("patches", "frames")}
    return cfg, params, batch, kw


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_and_loss(name):
    cfg, params, batch, kw = _setup(name)
    h, caches, aux = tf.forward(params, cfg, jnp.asarray(batch["tokens"]),
                                mode="train", **kw)
    assert h.shape == (SMOKE.global_batch, SMOKE.seq_len, cfg.d_model)
    assert caches is None
    assert not bool(jnp.any(jnp.isnan(h)))
    loss = tf.ce_loss(params, cfg, h, jnp.asarray(batch["labels"]))
    assert np.isfinite(float(loss))
    # random-init loss should be near ln(V) (within a broad band)
    assert float(loss) < np.log(cfg.vocab) + 2.0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_matches_teacher_forcing(name):
    cfg, params, batch, kw = _setup(name)
    toks = jnp.asarray(batch["tokens"][:, :12])
    # cache must hold prefill (incl. prepended patches for VLMs) + decode
    h_pf, caches, _ = tf.forward(params, cfg, toks, mode="prefill",
                                 cache_len=16 + cfg.n_patches, **kw)
    nxt = jnp.argmax(tf.logits_last(params, cfg, h_pf), -1)
    h_dec, caches, _ = tf.forward(params, cfg, nxt[:, None], mode="decode",
                                  caches=caches)
    # teacher-forced: run the extended sequence through the train path
    toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    h_full, _, _ = tf.forward(params, cfg, toks2, mode="train", **kw)
    err = float(jnp.max(jnp.abs(h_full[:, -1] - h_dec[:, 0])))
    assert err < 2e-4, f"{name}: decode diverges from teacher forcing by {err}"


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_two_decode_steps(name):
    cfg, params, batch, kw = _setup(name)
    toks = jnp.asarray(batch["tokens"][:, :8])
    h_pf, caches, _ = tf.forward(params, cfg, toks, mode="prefill",
                                 cache_len=12 + cfg.n_patches, **kw)
    tok = jnp.argmax(tf.logits_last(params, cfg, h_pf), -1)[:, None]
    for _ in range(2):
        h, caches, _ = tf.forward(params, cfg, tok, mode="decode", caches=caches)
        assert not bool(jnp.any(jnp.isnan(h)))
        tok = jnp.argmax(tf.logits_last(params, cfg, h), -1)[:, None]


def test_scan_equals_unrolled():
    cfg, params, batch, kw = _setup("gemma3-4b")
    toks = jnp.asarray(batch["tokens"])
    h_scan, _, _ = tf.forward(params, cfg, toks, mode="train", scan=True)
    h_unroll, _, _ = tf.forward(params, cfg, toks, mode="train", scan=False)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h_unroll),
                               rtol=0, atol=1e-5)


def test_chunked_attention_matches_full():
    cfg, params, batch, kw = _setup("qwen3-1.7b")
    toks = jnp.asarray(batch["tokens"])
    h_full, _, _ = tf.forward(params, cfg, toks, mode="train", q_chunk=None)
    h_chunk, _, _ = tf.forward(params, cfg, toks, mode="train", q_chunk=8)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h_chunk),
                               rtol=0, atol=1e-5)


def test_chunked_ce_matches_full():
    cfg, params, batch, _ = _setup("smollm-135m")
    h, _, _ = tf.forward(params, cfg, jnp.asarray(batch["tokens"]), mode="train")
    labels = jnp.asarray(batch["labels"])
    full = tf.ce_loss(params, cfg, h, labels, chunk=SMOKE.seq_len)
    chunked = tf.ce_loss(params, cfg, h, labels, chunk=8)
    assert abs(float(full) - float(chunked)) < 1e-4


def test_param_count_analytic_close_to_actual():
    # the 6ND roofline uses the analytic count; keep it honest vs real init
    for name in ("smollm-135m", "qwen3-1.7b"):
        cfg = get_config(name)
        reduced = cfg.reduced()
        params = tf.init_params(jax.random.key(0), reduced, jnp.float32)
        actual = tf.n_params(params)
        analytic = reduced.param_count()
        assert abs(actual - analytic) / actual < 0.05, (name, actual, analytic)
