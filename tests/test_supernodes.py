"""Supernode detection subsystem (repro.supernodes) vs the serial oracle.

The serial dense post-pass core/symbolic.detect_supernodes is the ground
truth; the batched fingerprint pipeline must reproduce it exactly at relax=0
on every matrix family, through every multisource variant (arena windows,
bubble-removal truncation, chunking), and through both fingerprint backends
(jnp oracle and the Pallas kernel).
"""
import os

import numpy as np
import pytest

from repro.core.gsofa import dense_pattern, prepare_graph
from repro.core.multisource import run_multisource
from repro.core.symbolic import detect_supernodes, symbolic_factorize
from repro.sparse import (
    banded_random, chemical_like, circuit_like, economic_like,
    grid2d_laplacian, grid3d_laplacian, permute_csr, random_pattern, rcm_order,
)
from repro.supernodes import (
    ColumnFingerprints, detect_from_fingerprints, detect_supernodes_batched,
    fingerprints_from_graph, merge_flags, pack_panels, ranges_from_flags,
    supernode_stats, supernode_weights,
)

MATS = {
    "grid2d": lambda: permute_csr(grid2d_laplacian(12),
                                  rcm_order(grid2d_laplacian(12))),
    "grid3d": lambda: grid3d_laplacian(5),
    "circuit": lambda: circuit_like(150, seed=7),
    "economic": lambda: economic_like(96, block=12, seed=2),
    "chemical": lambda: chemical_like(128, stage=16, seed=3),
    "banded": lambda: banded_random(100, band=6, seed=4),
    "random": lambda: random_pattern(80, density=0.05, seed=5),
    "random_sym": lambda: random_pattern(64, density=0.05, symmetric=True,
                                         seed=6),
}


def _serial(a, max_size=64):
    return detect_supernodes(dense_pattern(prepare_graph(a)),
                             max_size=max_size)


# ---------------------------------------------------------------------------
# parity with the serial dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(MATS))
def test_batched_matches_serial(name):
    a = MATS[name]()
    got = detect_supernodes_batched(a, max_size=64, fp_backend="ref")
    assert np.array_equal(got, _serial(a))


@pytest.mark.parametrize("name", ["grid2d", "circuit", "random"])
def test_pallas_fingerprints_match_serial(name):
    a = MATS[name]()
    got = detect_supernodes_batched(a, max_size=64, fp_backend="kernel")
    assert np.array_equal(got, _serial(a))


@pytest.mark.parametrize("kwargs", [
    dict(),
    dict(bubble=True),
    dict(use_arena=False),
    dict(combined=False),
])
def test_symbolic_factorize_integration(kwargs):
    """detect_supernodes=True rides along every multisource variant."""
    a = MATS["circuit"]()
    ref = _serial(a)
    r = symbolic_factorize(a, concurrency=48, detect_supernodes=True, **kwargs)
    assert np.array_equal(r.supernodes, ref)
    assert r.n_supernodes == len(ref)
    assert r.mean_supernode_size == pytest.approx(a.n / len(ref))


def test_symbolic_factorize_default_has_no_supernodes():
    a = MATS["random"]()
    r = symbolic_factorize(a, concurrency=32)
    assert r.supernodes is None and r.n_supernodes == 0


def test_checkpoint_restart_still_detects(tmp_path):
    """Restart path: restored chunks re-fingerprint without dense gather."""
    a = MATS["economic"]()
    ref = _serial(a)
    path = os.path.join(tmp_path, "ckpt.jsonl")
    symbolic_factorize(a, concurrency=32, checkpoint_path=path)
    r = symbolic_factorize(a, concurrency=32, checkpoint_path=path,
                           detect_supernodes=True)
    assert np.array_equal(r.supernodes, ref)


def test_chunking_invariance():
    """Fingerprints (hence ranges) are independent of #C chunking."""
    a = MATS["chemical"]()
    ref = detect_supernodes_batched(a, concurrency=128, fp_backend="ref")
    for c in (1, 7, 32):
        got = detect_supernodes_batched(a, concurrency=c, fp_backend="ref")
        assert np.array_equal(got, ref)


# ---------------------------------------------------------------------------
# fingerprint accumulator mechanics
# ---------------------------------------------------------------------------

def test_update_is_idempotent_and_merge_matches_full():
    a = MATS["circuit"]()
    g = prepare_graph(a)
    full = fingerprints_from_graph(g, fp_backend="ref")

    # two shards over disjoint interleaved source sets, merged
    lo = ColumnFingerprints(n=a.n, backend="ref")
    hi = ColumnFingerprints(n=a.n, backend="ref")
    run_multisource(g, concurrency=32,
                    sources=np.arange(0, a.n, 2, dtype=np.int32),
                    on_chunk=lo.update)
    run_multisource(g, concurrency=32,
                    sources=np.arange(1, a.n, 2, dtype=np.int32),
                    on_chunk=hi.update)
    # re-delivering a shard's rows is a no-op (chunk padding / replay)
    run_multisource(g, concurrency=32,
                    sources=np.arange(0, a.n, 2, dtype=np.int32),
                    on_chunk=lo.update)
    lo.merge(hi)
    assert lo.complete
    assert np.array_equal(lo.counts, full.counts)
    assert np.array_equal(lo.hsum, full.hsum)
    assert np.array_equal(lo.hxor, full.hxor)
    assert np.array_equal(lo.subdiag, full.subdiag)


def test_merge_rejects_overlapping_shards():
    x = ColumnFingerprints(n=8)
    y = ColumnFingerprints(n=8)
    x.seen[3] = True
    y.seen[3] = True
    with pytest.raises(ValueError):
        x.merge(y)


def test_incomplete_fingerprints_refuse_detection():
    fp = ColumnFingerprints(n=16)
    with pytest.raises(ValueError):
        merge_flags(fp)


def test_counts_match_pattern_columns():
    """Fingerprint counts are the below-diagonal column counts of L."""
    a = MATS["random"]()
    fp = fingerprints_from_graph(prepare_graph(a), fp_backend="ref")
    pat = dense_pattern(prepare_graph(a))
    ids = np.arange(a.n)
    ref_counts = (pat & (ids[:, None] > ids[None, :])).sum(axis=0)
    assert np.array_equal(fp.counts, ref_counts)


# ---------------------------------------------------------------------------
# T3 relaxation & range assembly
# ---------------------------------------------------------------------------

def test_relax_monotonicity():
    """Larger relax => merge set grows => fewer, larger supernodes."""
    a = MATS["grid2d"]()
    fp = fingerprints_from_graph(prepare_graph(a), fp_backend="ref")
    prev = None
    for relax in (0, 1, 2, 4, 8):
        ranges = detect_from_fingerprints(fp, relax=relax, max_size=a.n)
        assert ranges[0, 0] == 0 and ranges[-1, 1] == a.n
        assert (ranges[1:, 0] == ranges[:-1, 1]).all()
        if prev is not None:
            assert len(ranges) <= prev
        prev = len(ranges)
    # relaxation must actually fire on a grid (T2 alone is near-diagonal)
    assert len(detect_from_fingerprints(fp, relax=8, max_size=a.n)) < \
        len(detect_from_fingerprints(fp, relax=0, max_size=a.n))


def test_relax_zero_is_exact_t2():
    a = MATS["banded"]()
    fp = fingerprints_from_graph(prepare_graph(a), fp_backend="ref")
    assert np.array_equal(detect_from_fingerprints(fp, relax=0, max_size=64),
                          _serial(a, max_size=64))


@pytest.mark.parametrize("max_size", [1, 2, 5, 64])
def test_max_size_matches_serial(max_size):
    a = MATS["circuit"]()
    fp = fingerprints_from_graph(prepare_graph(a), fp_backend="ref")
    got = detect_from_fingerprints(fp, max_size=max_size)
    assert np.array_equal(got, _serial(a, max_size=max_size))
    assert (got[:, 1] - got[:, 0]).max() <= max_size


def test_ranges_from_flags_vectorized_splitting():
    flags = np.zeros(10, dtype=bool)
    flags[1:7] = True          # one 7-column run, then singletons
    got = ranges_from_flags(flags, max_size=3)
    assert got.tolist() == [[0, 3], [3, 6], [6, 7], [7, 8], [8, 9], [9, 10]]


def test_supernode_stats():
    s = supernode_stats(np.array([[0, 4], [4, 5], [5, 9]]))
    assert s["n_supernodes"] == 3
    assert s["mean_size"] == 3.0
    assert s["max_size"] == 4


# ---------------------------------------------------------------------------
# balanced panel packing
# ---------------------------------------------------------------------------

def _fp_and_ranges(a, relax=2):
    fp = fingerprints_from_graph(prepare_graph(a), fp_backend="ref")
    return fp, detect_from_fingerprints(fp, relax=relax, max_size=64)


def test_weights_are_panel_nnz():
    a = MATS["grid2d"]()
    fp, ranges = _fp_and_ranges(a)
    w = supernode_weights(ranges, fp.counts)
    pat = dense_pattern(prepare_graph(a))
    ids = np.arange(a.n)
    col_nnz = (pat & (ids[:, None] >= ids[None, :])).sum(axis=0)  # diag incl.
    ref = np.array([col_nnz[s:e].sum() for s, e in ranges])
    assert np.array_equal(w, ref)
    assert w.sum() == col_nnz.sum()


@pytest.mark.parametrize("n_panels", [2, 4, 8])
def test_lpt_packing_quality_bound(n_panels):
    """Greedy LPT guarantee: max load <= total/p + max single weight."""
    a = MATS["grid2d"]()
    fp, ranges = _fp_and_ranges(a)
    part = pack_panels(ranges, fp.counts, n_panels)
    w = supernode_weights(ranges, fp.counts)
    assert part.loads.sum() == w.sum()
    assert part.loads.max() <= w.sum() / n_panels + w.max()
    assert part.balance_ratio >= 1.0
    # every supernode assigned exactly once
    assert sorted(np.concatenate(part.panels()).tolist()) == \
        list(range(len(ranges)))


def test_empty_packing_is_well_formed():
    part = pack_panels(np.zeros((0, 2), np.int64), np.zeros(0, np.int64), 0)
    assert part.n_panels == 0 and part.balance_ratio == 1.0
    part = pack_panels(np.zeros((0, 2), np.int64), np.zeros(0, np.int64), 3)
    assert part.loads.sum() == 0 and part.balance_ratio == 1.0


def test_contiguous_packing_stays_contiguous():
    a = MATS["circuit"]()
    fp, ranges = _fp_and_ranges(a)
    part = pack_panels(ranges, fp.counts, 4, policy="contiguous")
    assert (np.diff(part.assignment) >= 0).all()      # order-preserving
    assert part.loads.sum() == supernode_weights(ranges, fp.counts).sum()
    w = supernode_weights(ranges, fp.counts)
    assert part.loads.max() <= w.sum() / 4 + 2 * w.max()
