"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops


# ---------------------------------------------------------------------------
# minmax relaxation kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,u,v", [
    (1, 8, 16), (4, 50, 70), (8, 128, 256), (3, 200, 130),
    (16, 256, 512), (9, 131, 257), (2, 1, 1),
])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_minmax_relax_shapes(s, u, v, dtype):
    rng = np.random.default_rng(s * 1000 + u + v)
    if dtype == jnp.int32:
        prop = rng.integers(-1, u + 1, size=(s, u)).astype(np.int32)
        inf = np.iinfo(np.int32).max
    else:
        prop = rng.standard_normal((s, u)).astype(np.float32)
        inf = np.inf
    prop[rng.random((s, u)) < 0.3] = inf
    adj = (rng.random((u, v)) < 0.15).astype(np.uint8)
    out = ops.minmax_relax(jnp.asarray(prop), jnp.asarray(adj))
    ref = ops.minmax_relax_ref(jnp.asarray(prop), jnp.asarray(adj))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("blocks", [(8, 8, 128), (8, 16, 128), (16, 128, 256)])
def test_minmax_relax_block_shape_invariance(blocks):
    bs, bu, bv = blocks
    rng = np.random.default_rng(0)
    prop = rng.integers(0, 100, size=(10, 70)).astype(np.int32)
    adj = (rng.random((70, 90)) < 0.2).astype(np.uint8)
    out = ops.minmax_relax(jnp.asarray(prop), jnp.asarray(adj),
                           block_s=bs, block_u=bu, block_v=bv)
    ref = ops.minmax_relax_ref(jnp.asarray(prop), jnp.asarray(adj))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_minmax_relax_empty_adjacency_gives_inf():
    prop = jnp.zeros((4, 32), jnp.int32)
    adj = jnp.zeros((32, 64), jnp.uint8)
    out = ops.minmax_relax(prop, adj)
    assert int(out.min()) == np.iinfo(np.int32).max


@given(st.integers(1, 12), st.integers(1, 64), st.integers(1, 64),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_minmax_relax_property(s, u, v, seed):
    rng = np.random.default_rng(seed)
    prop = rng.integers(-1, 2 * u, size=(s, u)).astype(np.int32)
    adj = (rng.random((u, v)) < rng.uniform(0, 0.5)).astype(np.uint8)
    out = ops.minmax_relax(jnp.asarray(prop), jnp.asarray(adj))
    ref = ops.minmax_relax_ref(jnp.asarray(prop), jnp.asarray(adj))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# supernode fingerprint kernel
# ---------------------------------------------------------------------------

def _fp_inputs(s, v, seed):
    rng = np.random.default_rng(seed)
    rel = rng.integers(-1, v + 2, size=(s, v)).astype(np.int32)
    src = rng.integers(0, v, size=s).astype(np.int32)
    m1 = rng.integers(0, 2**32, size=s, dtype=np.uint64).astype(np.uint32)
    m2 = rng.integers(0, 2**32, size=s, dtype=np.uint64).astype(np.uint32)
    valid = (rng.random(s) < 0.8).astype(np.int32)
    return tuple(jnp.asarray(x) for x in
                 (rel, src, m1.view(np.int32), m2.view(np.int32), valid))


@pytest.mark.parametrize("s,v", [
    (1, 1), (5, 100), (8, 512), (13, 300), (16, 1024), (33, 700),
])
def test_supernode_fp_shapes(s, v):
    args = _fp_inputs(s, v, seed=s * 101 + v)
    out = ops.column_fingerprints(*args)
    ref = ops.column_fingerprints_ref(*args)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("block_v", [128, 256, 512])
def test_supernode_fp_block_shape_invariance(block_v):
    args = _fp_inputs(20, 600, seed=0)
    out = ops.column_fingerprints(*args, block_v=block_v)
    ref = ops.column_fingerprints_ref(*args)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_supernode_fp_invalid_rows_contribute_nothing():
    rel, src, m1, m2, _ = _fp_inputs(9, 200, seed=3)
    none = ops.column_fingerprints(rel, src, m1, m2,
                                   jnp.zeros(9, jnp.int32))
    assert int(jnp.abs(none).max()) == 0


@given(st.integers(1, 24), st.integers(1, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_supernode_fp_property(s, v, seed):
    args = _fp_inputs(s, v, seed)
    out = ops.column_fingerprints(*args)
    ref = ops.column_fingerprints_ref(*args)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# supernodal panel-update kernel
# ---------------------------------------------------------------------------

def _pu_inputs(m, n, k, seed):
    rng = np.random.default_rng(seed)
    acc = rng.standard_normal((m, n)).astype(np.float32)
    lp = rng.standard_normal((m, k)).astype(np.float32)
    up = rng.standard_normal((k, n)).astype(np.float32)
    return tuple(jnp.asarray(x) for x in (acc, lp, up))


@pytest.mark.parametrize("m,n,k", [
    (1, 1, 1), (5, 100, 7), (8, 128, 128), (64, 64, 64), (130, 260, 70),
    (200, 300, 150), (17, 129, 33),
])
def test_panel_update_shapes(m, n, k):
    args = _pu_inputs(m, n, k, seed=m * 7 + n + k)
    out = ops.panel_update(*args)
    ref = ops.panel_update_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("blocks", [(8, 128, 128), (16, 128, 256),
                                    (128, 128, 128)])
def test_panel_update_block_shape_invariance(blocks):
    bm, bn, bk = blocks
    args = _pu_inputs(70, 200, 90, seed=0)
    out = ops.panel_update(*args, block_m=bm, block_n=bn, block_k=bk)
    ref = ops.panel_update_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_panel_update_empty_contraction_is_identity():
    acc = jnp.asarray(np.arange(12, dtype=np.float32).reshape(3, 4))
    lp = jnp.zeros((3, 0), jnp.float32)
    up = jnp.zeros((0, 4), jnp.float32)
    np.testing.assert_array_equal(np.asarray(ops.panel_update(acc, lp, up)),
                                  np.asarray(acc))


def test_panel_update_zero_l_keeps_acc():
    acc, lp, up = _pu_inputs(24, 140, 40, seed=2)
    out = ops.panel_update(acc, jnp.zeros_like(lp), up)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(acc))


@given(st.integers(1, 40), st.integers(1, 80), st.integers(1, 48),
       st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_panel_update_property(m, n, k, seed):
    args = _pu_inputs(m, n, k, seed)
    out = ops.panel_update(*args)
    ref = ops.panel_update_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,t,d", [
    (1, 1, 8, 8, 16), (1, 2, 16, 16, 32), (2, 2, 64, 64, 64),
    (1, 1, 8, 32, 16),      # decode-style: queries are the last 8 of 32
    (1, 1, 1, 40, 64),      # single-token decode
    (1, 2, 24, 24, 48),     # non-power-of-two d
])
def test_flash_attention_shapes(b, h, s, t, d):
    rng = np.random.default_rng(b + h + s + t + d)
    q = rng.standard_normal((b, h, s, d)).astype(np.float32)
    k = rng.standard_normal((b, h, t, d)).astype(np.float32)
    v = rng.standard_normal((b, h, t, d)).astype(np.float32)
    out = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              causal=True, block_q=8, block_k=16)
    ref = ops.flash_attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                  causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 2, 32, 64)), dtype)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 64)), dtype)
    v = jnp.asarray(rng.standard_normal((1, 2, 32, 64)), dtype)
    out = ops.flash_attention(q, k, v, block_q=8, block_k=16)
    ref = ops.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)
    assert out.dtype == dtype


def test_flash_attention_noncausal():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((1, 1, 16, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 48, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 48, 32)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, block_q=8, block_k=16)
    ref = ops.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
