"""Serving front end: plan cache + SolverEngine (ISSUE 8 / DESIGN.md §14).

Contract: ``pattern_fingerprint`` is a pure content hash (same pattern ->
same key across objects, pickle round-trips, and entry order; distinct
patterns of the same shape -> distinct keys); ``PlanCache`` is a strict
LRU (get refreshes recency, put evicts the least-recently-used beyond
capacity, capacity-1 thrashes deterministically); and ``SolverEngine``
answers every request bitwise-identically to the sequential session API
while batching and padding dispatches behind fixed-shape slots.
"""
import pickle

import numpy as np
import pytest

from repro.api import LUOptions, analyze
from repro.serve import PatternKey, PlanCache, SolverEngine, pattern_fingerprint
from repro.sparse import circuit_like, grid2d_laplacian, permute_csr, rcm_order
from repro.sparse.csr import CSRMatrix
from repro.sparse.numeric import generic_values_csr

OPTS = LUOptions(concurrency=64, supernode_relax=2)


def _matrix(seed=7, n=200):
    a = circuit_like(n, seed=seed)
    return permute_csr(a, rcm_order(a))


# ---------------------------------------------------------------------------
# fingerprint: content hash, not object identity
# ---------------------------------------------------------------------------

def test_fingerprint_is_content_hash():
    a = _matrix()
    b = CSRMatrix(n=a.n, indptr=a.indptr.copy(), indices=a.indices.copy())
    assert pattern_fingerprint(a) == pattern_fingerprint(b)
    assert hash(pattern_fingerprint(a)) == hash(pattern_fingerprint(b))


def test_fingerprint_survives_pickle():
    a = _matrix()
    key = pattern_fingerprint(a)
    assert pickle.loads(pickle.dumps(key)) == key
    a2 = pickle.loads(pickle.dumps(a))
    assert pattern_fingerprint(a2) == key


def test_distinct_patterns_same_shape_do_not_collide():
    """Same (n, nnz) but different structure must produce different keys —
    the collision contract the cache relies on."""
    a = _matrix(seed=1)
    perm = np.random.default_rng(0).permutation(a.n)
    b = permute_csr(a, perm)
    assert (b.n, b.nnz) == (a.n, a.nnz)
    assert pattern_fingerprint(a) != pattern_fingerprint(b)


def test_fingerprint_distinguishes_generators():
    keys = {pattern_fingerprint(_matrix(seed=s)) for s in range(8)}
    assert len(keys) == 8
    g = grid2d_laplacian(10)
    assert pattern_fingerprint(g) not in keys


# ---------------------------------------------------------------------------
# PlanCache: strict LRU
# ---------------------------------------------------------------------------

def _keys(count):
    return [PatternKey(n=10, nnz=10, h1=i, h2=i) for i in range(count)]


def test_lru_eviction_order():
    k = _keys(4)
    cache = PlanCache(capacity=3)
    for i in range(3):
        assert cache.put(k[i], f"plan{i}") is None
    assert cache.keys() == (k[0], k[1], k[2])
    assert cache.get(k[0]) == "plan0"          # refresh 0 -> 1 is LRU now
    assert cache.keys() == (k[1], k[2], k[0])
    evicted = cache.put(k[3], "plan3")
    assert evicted == k[1]
    assert k[1] not in cache and len(cache) == 3
    assert cache.get(k[1]) is None


def test_capacity_one_thrash():
    k = _keys(3)
    cache = PlanCache(capacity=1)
    assert cache.put(k[0], "a") is None
    assert cache.put(k[1], "b") == k[0]
    assert cache.put(k[2], "c") == k[1]
    assert cache.get(k[0]) is None and cache.get(k[1]) is None
    assert cache.get(k[2]) == "c" and len(cache) == 1


def test_put_refresh_does_not_evict():
    k = _keys(2)
    cache = PlanCache(capacity=2)
    cache.put(k[0], "a")
    cache.put(k[1], "b")
    assert cache.put(k[0], "a2") is None       # refresh, not insert
    assert cache.get(k[0]) == "a2" and len(cache) == 2


def test_cache_rejects_bad_capacity():
    with pytest.raises(ValueError):
        PlanCache(capacity=0)
    with pytest.raises(ValueError):
        SolverEngine(OPTS, batch_slots=0)


def test_cache_is_thread_safe_under_contention():
    """Concurrent get/put from many threads must never corrupt the LRU
    state (regression: the unlocked OrderedDict could double-evict or die
    in move_to_end when recency updates interleaved with eviction)."""
    import threading

    keys = _keys(32)
    cache = PlanCache(capacity=8)
    errors = []
    start = threading.Barrier(8)

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            start.wait()
            for _ in range(2000):
                k = keys[rng.integers(len(keys))]
                if rng.random() < 0.5:
                    cache.put(k, f"plan-{k.h1}")
                else:
                    got = cache.get(k)
                    if got is not None:
                        assert got == f"plan-{k.h1}"
        except Exception as exc:   # pragma: no cover - only on regression
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # invariants survive the storm: within capacity, keys() consistent
    assert len(cache) <= 8
    ks = cache.keys()
    assert len(ks) == len(set(ks)) == len(cache)
    for k in ks:
        assert cache.get(k) == f"plan-{k.h1}"


# ---------------------------------------------------------------------------
# SolverEngine: end-to-end vs the sequential session API
# ---------------------------------------------------------------------------

def test_engine_matches_sequential_api_bitwise():
    mats = [_matrix(seed=s) for s in range(2)]
    eng = SolverEngine(OPTS, capacity=4, batch_slots=3)
    rng = np.random.default_rng(0)
    reqs = []
    for r in range(8):                         # 4 per pattern -> pad 2 slots
        a = mats[r % 2]
        vals = generic_values_csr(a, seed=r)
        rhs = rng.standard_normal(a.n)
        reqs.append((eng.submit(a, vals, rhs), a, vals, rhs))
    assert eng.pending == 8
    results = eng.flush()
    assert eng.pending == 0
    assert [r.rid for r in results] == [rid for rid, *_ in reqs]
    for res, (rid, a, vals, rhs) in zip(results, reqs):
        seq = analyze(a, OPTS).factorize(vals).solve(rhs)
        assert np.array_equal(seq.x, res.x)
        assert res.residual == seq.residuals[-1]


def test_engine_stats_and_occupancy_accounting():
    a = _matrix()
    eng = SolverEngine(OPTS, capacity=4, batch_slots=4)
    rng = np.random.default_rng(1)
    for r in range(6):                         # 4 + 2 -> 2 dispatches, pad 2
        eng.submit(a, generic_values_csr(a, seed=r), rng.standard_normal(a.n))
    eng.flush()
    s = eng.stats
    assert s["requests"] == 6
    assert s["batches"] == 2
    assert s["padded_slots"] == 2
    assert s["cache_misses"] == 1              # one pattern, analyzed once
    # second flush on the same pattern is a cache hit
    eng.submit(a, generic_values_csr(a, seed=9), rng.standard_normal(a.n))
    eng.flush()
    assert s["cache_misses"] == 1 and s["cache_hits"] == 1


def test_padding_slots_do_not_leak_into_results():
    """A padded dispatch repeats the final request; results must carry one
    entry per real request with correct per-slot answers."""
    a = _matrix()
    eng = SolverEngine(OPTS, capacity=2, batch_slots=8)
    rng = np.random.default_rng(2)
    reqs = [(eng.submit(a, generic_values_csr(a, seed=r),
                        rng.standard_normal(a.n)))
            for r in range(3)]                 # 3 real, 5 padded slots
    results = eng.flush()
    assert len(results) == 3
    assert sorted(r.rid for r in results) == sorted(reqs)
    assert {r.slot for r in results} == {0, 1, 2}
    assert eng.stats["padded_slots"] == 5


def test_engine_eviction_reanalyzes():
    mats = [_matrix(seed=s) for s in range(3)]
    eng = SolverEngine(OPTS, capacity=2, batch_slots=2)
    for a in mats:
        eng.plan_for(a)
    assert eng.stats["cache_evictions"] == 1   # third insert evicts first
    eng.plan_for(mats[0])                      # evicted -> fresh analyze
    assert eng.stats["cache_misses"] == 4
    assert eng.stats["cache_hits"] == 0


def test_engine_one_shot_solve():
    a = _matrix()
    vals = generic_values_csr(a, seed=0)
    rhs = np.random.default_rng(3).standard_normal(a.n)
    res = SolverEngine(OPTS).solve(a, vals, rhs)
    seq = analyze(a, OPTS).factorize(vals).solve(rhs)
    assert np.array_equal(seq.x, res.x)
    assert res.batch_id == 0 and res.slot == 0


def test_engine_rejects_bad_shapes():
    a = _matrix()
    eng = SolverEngine(OPTS)
    with pytest.raises(ValueError):
        eng.submit(a, np.zeros(a.nnz + 1), np.zeros(a.n))
    with pytest.raises(ValueError):
        eng.submit(a, generic_values_csr(a), np.zeros(a.n + 1))
