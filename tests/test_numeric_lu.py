"""End-to-end validation: numeric LU with generic values stays inside (and,
with probability 1, exactly fills) the symbolically predicted pattern."""
import numpy as np
import pytest

from repro.core.gsofa import prepare_graph, dense_pattern
from repro.sparse import circuit_like, economic_like, grid2d_laplacian
from repro.sparse.numeric import lu_nopivot, validate_symbolic, generic_values


@pytest.mark.parametrize("gen", [
    lambda: grid2d_laplacian(8),
    lambda: circuit_like(100, seed=21),
    lambda: economic_like(96, block=12, seed=22),
])
def test_numeric_fill_matches_symbolic(gen):
    a = gen()
    predicted = dense_pattern(prepare_graph(a))
    report = validate_symbolic(a, predicted, seed=0)
    assert report["ok"], f"numeric factorization escaped the symbolic pattern: {report}"
    # generic values -> no accidental cancellation -> exact match
    assert report["n_spurious"] == 0, report


def test_lu_reconstructs_matrix():
    a = grid2d_laplacian(6)
    dense = generic_values(a, seed=1)
    l, u = lu_nopivot(dense)
    np.testing.assert_allclose(l @ u, dense, rtol=1e-9, atol=1e-9)
