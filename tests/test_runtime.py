"""Fault-tolerance runtime: dynamic scheduler, checkpoint/restart, elasticity."""
import os

import numpy as np

from repro.core.gsofa import prepare_graph
from repro.core.symbolic import ChunkCheckpointer, symbolic_factorize
from repro.core.theory import elimination_fill
from repro.runtime.scheduler import DynamicScheduler
from repro.sparse import economic_like


def _refs(a):
    e = elimination_fill(a)
    np.fill_diagonal(e, False)
    ids = np.arange(a.n)
    return ((e & (ids[None, :] < ids[:, None])).sum(1),
            (e & (ids[None, :] > ids[:, None])).sum(1))


def test_scheduler_completes_all_chunks():
    a = economic_like(160, block=16, seed=31)
    l_ref, u_ref = _refs(a)
    out = DynamicScheduler(prepare_graph(a), concurrency=48).run()
    assert np.array_equal(out["l_counts"], l_ref)
    assert np.array_equal(out["u_counts"], u_ref)


def test_scheduler_elastic_shrink():
    a = economic_like(160, block=16, seed=32)
    l_ref, _ = _refs(a)
    out = DynamicScheduler(prepare_graph(a), concurrency=32).run(drop_devices_after=1)
    assert np.array_equal(out["l_counts"], l_ref)


def test_checkpoint_restart_resumes_pending(tmp_path):
    a = economic_like(192, block=16, seed=33)
    l_ref, u_ref = _refs(a)
    path = os.path.join(tmp_path, "ckpt.jsonl")
    # full run writes a checkpoint per chunk
    r1 = symbolic_factorize(a, concurrency=64, checkpoint_path=path)
    assert np.array_equal(r1.l_counts, l_ref)
    # simulate a crash after the first chunk: truncate to one record
    with open(path) as f:
        first = f.readline()
    with open(path, "w") as f:
        f.write(first)
    r2 = symbolic_factorize(a, concurrency=64, checkpoint_path=path)
    assert np.array_equal(r2.l_counts, l_ref)
    assert np.array_equal(r2.u_counts, u_ref)
    # the restart only ran the pending chunks
    assert r2.supersteps < r1.supersteps


def test_scheduler_restart_with_changed_concurrency(tmp_path):
    """Chunk coverage is per source: a checkpoint recorded under one
    concurrency restarts correctly under another (regression: grid-keyed
    matching silently zeroed the uncovered half of mismatched chunks)."""
    a = economic_like(128, block=16, seed=34)
    l_ref, u_ref = _refs(a)
    g = prepare_graph(a)
    path = os.path.join(tmp_path, "ckpt.jsonl")
    DynamicScheduler(g, concurrency=32,
                     checkpointer=ChunkCheckpointer(path, a.n)).run()
    with open(path) as f:
        first = f.readline()
    with open(path, "w") as f:
        f.write(first)
    out = DynamicScheduler(g, concurrency=64,
                           checkpointer=ChunkCheckpointer(path, a.n)).run()
    assert np.array_equal(out["l_counts"], l_ref)
    assert np.array_equal(out["u_counts"], u_ref)


def test_checkpointer_restore(tmp_path):
    path = os.path.join(tmp_path, "c.jsonl")
    ck = ChunkCheckpointer(path, 10)
    srcs = np.arange(0, 5)
    ck.record(0, srcs, np.arange(5), np.arange(5) * 2)
    ck2 = ChunkCheckpointer(path, 10)
    l = np.zeros(10, np.int64)
    u = np.zeros(10, np.int64)
    assert ck2.restore_into(l, u) == 5
    assert l[4] == 4 and u[4] == 8
    # a checkpoint for a different matrix order is ignored
    ck3 = ChunkCheckpointer(path, 11)
    assert not ck3.done
