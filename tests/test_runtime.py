"""Fault-tolerance runtime: dynamic scheduler, checkpoint/restart, elasticity."""
import os

import jax
import numpy as np
import pytest

import repro
from repro.api import LUOptions
from repro.core.gsofa import prepare_graph
from repro.core.symbolic import ChunkCheckpointer, symbolic_factorize
from repro.core.theory import elimination_fill
from repro.runtime.scheduler import DynamicScheduler
from repro.sparse import (
    banded_full, banded_random, bordered_block_diagonal, chemical_like,
    circuit_like, economic_like, grid2d_laplacian, grid3d_laplacian,
    random_pattern,
)

# same family as tests/test_distributed_plan.py: every structure generator,
# sized for fast turnaround
GENERATORS = {
    "grid2d": lambda: grid2d_laplacian(10),
    "grid3d": lambda: grid3d_laplacian(5),
    "circuit": lambda: circuit_like(200, seed=7),
    "economic": lambda: economic_like(192, block=16, seed=2),
    "chemical": lambda: chemical_like(240, stage=16, seed=3),
    "banded": lambda: banded_random(160, band=6, seed=4),
    "banded_full": lambda: banded_full(150, band=5),
    "random": lambda: random_pattern(120, density=0.02, seed=5),
    "bbd": lambda: bordered_block_diagonal(320, block=16, border=32, seed=6),
}


def _refs(a):
    e = elimination_fill(a)
    np.fill_diagonal(e, False)
    ids = np.arange(a.n)
    return ((e & (ids[None, :] < ids[:, None])).sum(1),
            (e & (ids[None, :] > ids[:, None])).sum(1))


def test_scheduler_completes_all_chunks():
    a = economic_like(160, block=16, seed=31)
    l_ref, u_ref = _refs(a)
    out = DynamicScheduler(prepare_graph(a), concurrency=48).run()
    assert np.array_equal(out["l_counts"], l_ref)
    assert np.array_equal(out["u_counts"], u_ref)


def test_scheduler_elastic_shrink():
    a = economic_like(160, block=16, seed=32)
    l_ref, _ = _refs(a)
    out = DynamicScheduler(prepare_graph(a), concurrency=32).run(drop_devices_after=1)
    assert np.array_equal(out["l_counts"], l_ref)


def test_checkpoint_restart_resumes_pending(tmp_path):
    a = economic_like(192, block=16, seed=33)
    l_ref, u_ref = _refs(a)
    path = os.path.join(tmp_path, "ckpt.jsonl")
    # full run writes a checkpoint per chunk
    r1 = symbolic_factorize(a, concurrency=64, checkpoint_path=path)
    assert np.array_equal(r1.l_counts, l_ref)
    # simulate a crash after the first chunk: truncate to one record
    with open(path) as f:
        first = f.readline()
    with open(path, "w") as f:
        f.write(first)
    r2 = symbolic_factorize(a, concurrency=64, checkpoint_path=path)
    assert np.array_equal(r2.l_counts, l_ref)
    assert np.array_equal(r2.u_counts, u_ref)
    # the restart only ran the pending chunks
    assert r2.supersteps < r1.supersteps


def test_scheduler_restart_with_changed_concurrency(tmp_path):
    """Chunk coverage is per source: a checkpoint recorded under one
    concurrency restarts correctly under another (regression: grid-keyed
    matching silently zeroed the uncovered half of mismatched chunks)."""
    a = economic_like(128, block=16, seed=34)
    l_ref, u_ref = _refs(a)
    g = prepare_graph(a)
    path = os.path.join(tmp_path, "ckpt.jsonl")
    DynamicScheduler(g, concurrency=32,
                     checkpointer=ChunkCheckpointer(path, a.n)).run()
    with open(path) as f:
        first = f.readline()
    with open(path, "w") as f:
        f.write(first)
    out = DynamicScheduler(g, concurrency=64,
                           checkpointer=ChunkCheckpointer(path, a.n)).run()
    assert np.array_equal(out["l_counts"], l_ref)
    assert np.array_equal(out["u_counts"], u_ref)


def test_checkpointer_restore(tmp_path):
    path = os.path.join(tmp_path, "c.jsonl")
    ck = ChunkCheckpointer(path, 10)
    srcs = np.arange(0, 5)
    ck.record(0, srcs, np.arange(5), np.arange(5) * 2)
    ck2 = ChunkCheckpointer(path, 10)
    l = np.zeros(10, np.int64)
    u = np.zeros(10, np.int64)
    assert ck2.restore_into(l, u) == 5
    assert l[4] == 4 and u[4] == 8
    # a checkpoint for a different matrix order is ignored
    ck3 = ChunkCheckpointer(path, 11)
    assert not ck3.done


# ---- plan-integrated dynamic runtime (DESIGN.md §13) ---------------------


@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_dynamic_runtime_matches_static_analyze(name):
    """``LUOptions(runtime="dynamic")`` drives ``repro.analyze`` through the
    work-stealing scheduler; counts, pattern, and supernode partition must
    be bitwise-identical to the static chunk loop on every structure."""
    a = GENERATORS[name]()
    static = repro.analyze(a, LUOptions(concurrency=48, supernode_relax=2))
    dyn = repro.analyze(a, LUOptions(concurrency=48, supernode_relax=2,
                                     runtime="dynamic"))
    assert np.array_equal(dyn.sym.l_counts, static.sym.l_counts)
    assert np.array_equal(dyn.sym.u_counts, static.sym.u_counts)
    assert np.array_equal(dyn.sym.supernodes, static.sym.supernodes)
    assert np.array_equal(dyn.pattern.indptr, static.pattern.indptr)
    assert np.array_equal(dyn.pattern.rowind, static.pattern.rowind)
    assert dyn.sym.runtime is not None
    assert dyn.sym.runtime["completed"] == dyn.sym.runtime["chunks"]
    # the dynamic plan carries a placement for the visible devices
    assert dyn.placement is not None


def test_dynamic_runtime_factors_and_solve_match():
    a = circuit_like(200, seed=7)
    rng = np.random.default_rng(0)
    b = rng.standard_normal((a.n, 3))
    f_s = repro.analyze(a, LUOptions(concurrency=32)).factorize(None)
    f_d = repro.analyze(
        a, LUOptions(concurrency=32, runtime="dynamic")).factorize(None)
    assert np.array_equal(f_d.l, f_s.l)
    assert np.array_equal(f_d.u, f_s.u)
    assert np.array_equal(f_d.solve(b).x, f_s.solve(b).x)


def test_dynamic_runtime_rejects_mesh_and_distribute():
    with pytest.raises(ValueError, match="dynamic"):
        LUOptions(runtime="dynamic", distribute=True)
    with pytest.raises(ValueError, match="runtime"):
        LUOptions(runtime="bogus")


def test_dynamic_runtime_checkpoint_restart(tmp_path):
    """A dynamic-runtime analyze restarted from a truncated checkpoint
    recomputes only the pending chunks and still delivers the complete
    pattern + supernode partition (the covered sources' collector re-run)."""
    a = economic_like(192, block=16, seed=33)
    static = symbolic_factorize(a, concurrency=64, detect_supernodes=True)
    path = os.path.join(tmp_path, "ckpt.jsonl")
    r1 = symbolic_factorize(a, concurrency=64, checkpoint_path=path,
                            runtime="dynamic", detect_supernodes=True)
    assert np.array_equal(r1.l_counts, static.l_counts)
    with open(path) as f:
        first = f.readline()
    with open(path, "w") as f:
        f.write(first)
    r2 = symbolic_factorize(a, concurrency=64, checkpoint_path=path,
                            runtime="dynamic", detect_supernodes=True)
    assert np.array_equal(r2.l_counts, static.l_counts)
    assert np.array_equal(r2.u_counts, static.u_counts)
    assert np.array_equal(r2.supernodes, static.supernodes)
    assert r2.supersteps < r1.supersteps


def test_scheduler_elastic_join():
    """Start on one executor slot, activate the rest mid-run: the queue
    drains correctly and the late joiners' pulls count as steals."""
    a = economic_like(160, block=16, seed=36)
    l_ref, u_ref = _refs(a)
    sched = DynamicScheduler(prepare_graph(a), devices=jax.devices() * 4,
                             concurrency=16)
    out = sched.run(join_devices_after=2)
    assert np.array_equal(out["l_counts"], l_ref)
    assert np.array_equal(out["u_counts"], u_ref)
    assert out["completed"] == out["chunks"]


def test_scheduler_straggler_reissue_and_retire():
    """A flight that never reports ready is speculatively re-issued to an
    idle slot; when the copy wins, the straggler flight is retired — and
    the results stay bitwise-correct (exactly-once delivery)."""
    a = economic_like(160, block=16, seed=35)
    l_ref, u_ref = _refs(a)
    sched = DynamicScheduler(prepare_graph(a), devices=jax.devices() * 3,
                             concurrency=32, timeout_factor=0.0)
    orig_ready = DynamicScheduler._ready
    stuck = {}

    def ready(fl):
        # the FIRST flight of chunk 1 is a permanent straggler; re-issued
        # copies (fresh _InFlight objects) complete normally
        if fl.chunk_id == 1 and stuck.setdefault(1, fl) is fl:
            return False
        return orig_ready(fl)

    sched._ready = ready
    out = sched.run()
    assert sched.reissues >= 1
    assert sched.retired >= 1
    assert out["completed"] == out["chunks"]
    assert np.array_equal(out["l_counts"], l_ref)
    assert np.array_equal(out["u_counts"], u_ref)


def test_dynamic_runtime_obs_counters():
    """Tracing on: the dynamic analyze emits the ``runtime`` span and the
    steal/re-issue/chunk counters through the obs registry."""
    from repro.obs import metrics as om
    from repro.obs import trace as ot

    a = economic_like(160, block=16, seed=37)
    ot.disable()
    om.registry().reset()
    try:
        ot.enable()
        plan = repro.analyze(a, LUOptions(concurrency=32, runtime="dynamic"))
        snap = om.registry().snapshot()
        assert snap["counters"]["runtime.chunks"] == plan.sym.runtime["chunks"]
        assert "runtime.steals" in snap["counters"]
        assert "runtime.reissues" in snap["counters"]
        assert plan.stats is not None and plan.stats.find("runtime") is not None
    finally:
        ot.disable()
        om.registry().reset()


def test_segment_batch_toggle_bitwise_identical():
    """The batched same-shape segment GEMMs (LUOptions.segment_batch, on by
    default) are bitwise-identical to per-panel dispatch on both numeric
    backends, and report batched-dispatch counters when tracing."""
    from repro.obs import metrics as om
    from repro.obs import trace as ot

    a = bordered_block_diagonal(320, block=16, border=32, seed=6)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.n)
    for backend in ("numpy", "kernel"):
        base = LUOptions(concurrency=48, supernode_relax=2,
                         numeric_backend=backend)
        f_on = repro.analyze(a, base).factorize(None)
        f_off = repro.analyze(
            a, base.replace(segment_batch=False)).factorize(None)
        assert np.array_equal(f_on.l, f_off.l), backend
        assert np.array_equal(f_on.u, f_off.u), backend
        assert np.array_equal(f_on.solve(b).x, f_off.solve(b).x), backend
    # batched dispatch actually engaged (bbd has many same-shape panels)
    ot.disable()
    om.registry().reset()
    try:
        ot.enable()
        repro.analyze(a, LUOptions(concurrency=48,
                                   supernode_relax=2)).factorize(None)
        snap = om.registry().snapshot()
        assert snap["counters"].get("gemm.batched.calls", 0) >= 1
        assert (snap["counters"]["gemm.batched.panels"]
                > snap["counters"]["gemm.batched.calls"])
    finally:
        ot.disable()
        om.registry().reset()
