"""Numerical robustness tier (ISSUE 9 / DESIGN.md §15).

Contract: the analyze-time static-pivoting pre-pass (max-product
transversal + Ruiz equilibration) rescues every generator matrix the
pivot-free seed path dies on — factorizing and solving to relative
residual <= 1e-8 after refinement — while ``pivot="none"`` stays
bitwise-identical to the historical path; tiny-pivot perturbation is
counted and surfaces in the quality report; zero-pivot errors carry
column/panel/level/system attribution; robust plans pickle; and the
Hager condition estimate tracks ``numpy.linalg.cond(., 1)``.
"""
import pickle

import numpy as np
import pytest

from repro.api import LUOptions, analyze
from repro.numeric import numeric_factorize, solve_factored
from repro.numeric.solve import solve_factored_transposed
from repro.robust import (
    QualityReport, RobustPlan, StructurallySingularError,
    build_robust_prepass, equilibrate, max_product_transversal,
)
from repro.core.symbolic import symbolic_factorize
from repro.sparse import (
    banded_random, indefinite, indefinite_values_csr, shuffled_dominant,
    shuffled_dominant_values_csr,
)
from repro.sparse.csr import csr_from_dense
from repro.sparse.numeric import (
    PERTURB_EPS, ZeroPivotError, csr_matvec, generic_values_csr,
)

ROBUST = LUOptions(supernode_relax=2, pivot="static", perturb=True)
PLAIN = LUOptions(supernode_relax=2)

#: the rescue tier: (pattern, CSR-aligned values) pairs the pivot-free
#: seed path raises ZeroPivotError on
HOSTILE = {
    "indefinite": lambda: (
        lambda a: (a, indefinite_values_csr(a, seed=1)))(
            indefinite(240, band=6, seed=1)),
    "shuffled": lambda: (
        lambda a: (a, shuffled_dominant_values_csr(a, band=6, seed=2)))(
            shuffled_dominant(240, band=6, seed=2)),
}


def _dense_of(a, vals):
    d = np.zeros((a.n, a.n))
    rows = np.repeat(np.arange(a.n), np.diff(a.indptr))
    d[rows, a.indices] = vals
    return d


# ---------------------------------------------------------------------------
# transversal + equilibration units
# ---------------------------------------------------------------------------

def test_transversal_recovers_row_rotation():
    # dominant band rotated by 2: matching must undo the rotation exactly
    rng = np.random.default_rng(0)
    n = 8
    base = rng.uniform(0.5, 1.5, (n, n)) * (np.abs(
        np.subtract.outer(np.arange(n), np.arange(n))) <= 2)
    np.fill_diagonal(base, 10.0)
    rotated = np.roll(base, -2, axis=0)
    a = csr_from_dense(rotated)
    perm = max_product_transversal(a, rotated)
    assert np.array_equal(perm, (np.arange(n) - 2) % n)


def test_transversal_skips_zero_valued_diagonal():
    # diagonal structurally present but numerically zero: the matching must
    # route around it, not "match" a zero weight
    dense = np.array([[0.0, 3.0], [2.0, 1e-12]])
    dense[1, 1] = 1e-12
    a = csr_from_dense(np.ones((2, 2)))
    perm = max_product_transversal(a, dense)
    # |A[1,0]|*|A[0,1]| = 6 beats |A[0,0]|*|A[1,1]| ~ 0
    assert np.array_equal(perm, [1, 0])


def test_structurally_singular_raises():
    # column 1 empty in every row: Hall violation, no transversal exists
    dense = np.array([[1.0, 0.0, 1.0],
                      [1.0, 0.0, 1.0],
                      [1.0, 0.0, 1.0]])
    a = csr_from_dense(dense)
    with pytest.raises(StructurallySingularError):
        max_product_transversal(a, dense)


def test_equilibrate_drives_extremes_to_unit():
    rng = np.random.default_rng(3)
    n = 40
    a = banded_random(n, band=4, seed=3)
    vals = generic_values_csr(a) * 1e6   # badly scaled
    rows = np.repeat(np.arange(n), np.diff(a.indptr))
    r, c = equilibrate(n, rows, a.indices.astype(np.int64), np.abs(vals))
    s = np.abs(vals) * r[rows] * c[a.indices]
    rmax = np.zeros(n)
    np.maximum.at(rmax, rows, s)
    cmax = np.zeros(n)
    np.maximum.at(cmax, a.indices.astype(np.int64), s)
    # Ruiz converges to the unit fixed point; 8 iterations land within ~1e-3
    assert np.allclose(rmax, 1.0, atol=1e-2)
    assert np.allclose(cmax, 1.0, atol=1e-2)
    del rng


def test_prepass_transform_parity_dense_vs_csr():
    a, vals = HOSTILE["shuffled"]()
    a_f, rp = build_robust_prepass(a, vals)
    via_csr = rp.transform_values(vals)
    dense_f = rp.transform_dense(_dense_of(a, vals))
    rows_f = np.repeat(np.arange(a.n), np.diff(a_f.indptr))
    # value_scale premultiplies r·c, the dense path scales in two steps —
    # same transform, one-rounding difference
    assert np.allclose(via_csr, dense_f[rows_f, a_f.indices], rtol=1e-13)


# ---------------------------------------------------------------------------
# rescue: hostile generators factor + solve under the robust tier
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(HOSTILE))
def test_seed_path_raises_with_attribution(name):
    a, vals = HOSTILE[name]()
    with pytest.raises(ZeroPivotError) as ei:
        analyze(a, PLAIN).factorize(vals)
    e = ei.value
    assert e.panel is not None and e.level is not None
    assert f"panel {e.panel}" in str(e) and "pivot='static'" in str(e)


@pytest.mark.parametrize("name", sorted(HOSTILE))
def test_robust_tier_rescues(name):
    a, vals = HOSTILE[name]()
    plan = analyze(a, ROBUST, values=vals)
    factor = plan.factorize(vals)
    rng = np.random.default_rng(7)
    b = rng.standard_normal(a.n)
    res = factor.solve(b)
    rel = (np.linalg.norm(csr_matvec(a, vals, res.x) - b)
           / np.linalg.norm(b))
    assert rel <= 1e-8
    q = factor.quality()
    assert q.verdict in ("ok", "suspect")
    # verdict + estimates surface through the report, not just the solve
    assert np.isfinite(q.cond_1_est) and np.isfinite(q.growth)


@pytest.mark.parametrize("name", sorted(HOSTILE))
def test_robust_tier_rescues_batched(name):
    a, vals = HOSTILE[name]()
    batch = np.stack([vals, vals * 1.25, vals * 0.8])
    plan = analyze(a, ROBUST, values=vals)
    factor = plan.factorize_batch(batch)
    rng = np.random.default_rng(11)
    b = rng.standard_normal((3, a.n))
    res = factor.solve_batch(b)
    for i in range(3):
        rel = (np.linalg.norm(csr_matvec(a, batch[i], res.x[i]) - b[i])
               / np.linalg.norm(b[i]))
        assert rel <= 1e-8
    # per-system views replay the same transform
    f0 = plan.factorize(batch[0])
    s0 = factor.system(0)
    for blk_a, blk_b in zip(f0.num.store.blocks, s0.num.store.blocks):
        assert np.array_equal(blk_a, blk_b)


# ---------------------------------------------------------------------------
# bitwise parity: robustness off == historical path
# ---------------------------------------------------------------------------

def test_pivot_none_is_bitwise_historical():
    a = banded_random(240, band=6, seed=4)
    vals = generic_values_csr(a)
    explicit = analyze(a, LUOptions(supernode_relax=2, pivot="none"))
    factor = explicit.factorize(vals)
    sym = symbolic_factorize(a, concurrency=64, detect_supernodes=True,
                             supernode_relax=2)
    num = numeric_factorize(a, sym, values=vals)
    ls, us = factor.num.store.dense_lu()
    ld, ud = num.store.dense_lu()
    assert np.array_equal(ls, ld) and np.array_equal(us, ud)
    assert factor.perturbed_pivots == 0


def test_options_validation():
    with pytest.raises(ValueError):
        LUOptions(pivot="partial")
    with pytest.raises(ValueError):
        LUOptions(perturb_eps=-1.0)


# ---------------------------------------------------------------------------
# tiny-pivot perturbation
# ---------------------------------------------------------------------------

def _tiny_diag_system(n=60, band=4):
    a = banded_random(n, band=band, seed=9)
    vals = generic_values_csr(a, seed=9)
    # zero out the very first pivot: no elimination update reaches column 0,
    # so the sweep sees exactly 0.0 there
    rows = np.repeat(np.arange(n), np.diff(a.indptr))
    slot = np.flatnonzero((rows == 0) & (a.indices == 0))[0]
    vals = vals.copy()
    vals[slot] = 0.0
    return a, vals


def test_perturbation_counts_and_flags_suspect():
    a, vals = _tiny_diag_system()
    plan = analyze(a, LUOptions(supernode_relax=2, perturb=True))
    factor = plan.factorize(vals)
    assert factor.perturbed_pivots >= 1
    # the bumped pivot is the signed threshold eps*max|A|
    thr = PERTURB_EPS * np.abs(vals).max()
    assert abs(factor.num.store.blocks[0][0, 0]) == pytest.approx(thr)
    q = factor.quality()
    assert q.perturbed_pivots == factor.perturbed_pivots
    assert q.verdict == "suspect"      # perturbed => never silently "ok"


def test_perturbation_counts_batched_per_system():
    a, bad = _tiny_diag_system()
    good = generic_values_csr(a, seed=9)
    plan = analyze(a, LUOptions(supernode_relax=2, perturb=True))
    factor = plan.factorize_batch(np.stack([good, bad, good]))
    assert factor.perturbed_pivots.tolist() == [0, 1, 0]
    assert factor.system(1).quality().verdict == "suspect"
    assert factor.system(0).quality().verdict == "ok"


def test_batched_zero_pivot_names_system():
    a, bad = _tiny_diag_system()
    good = generic_values_csr(a, seed=9)
    plan = analyze(a, PLAIN)
    with pytest.raises(ZeroPivotError) as ei:
        plan.factorize_batch(np.stack([good, good, bad]))
    e = ei.value
    assert e.system == 2 and e.k == 0
    assert "system 2" in str(e)


# ---------------------------------------------------------------------------
# plan persistence
# ---------------------------------------------------------------------------

def test_robust_plan_pickles_and_replays():
    a, vals = HOSTILE["shuffled"]()
    plan = analyze(a, ROBUST, values=vals)
    clone = pickle.loads(pickle.dumps(plan))
    assert isinstance(clone.robust, RobustPlan)
    for field in ("perm", "row_scale", "col_scale", "value_map",
                  "value_scale"):
        assert np.array_equal(getattr(clone.robust, field),
                              getattr(plan.robust, field))
    f1, f2 = plan.factorize(vals), clone.factorize(vals)
    for blk_a, blk_b in zip(f1.num.store.blocks, f2.num.store.blocks):
        assert np.array_equal(blk_a, blk_b)


# ---------------------------------------------------------------------------
# condition / growth estimates
# ---------------------------------------------------------------------------

def test_transposed_solve_matches_dense():
    a = banded_random(80, band=5, seed=5)
    vals = generic_values_csr(a, seed=5)
    factor = analyze(a, PLAIN).factorize(vals)
    rng = np.random.default_rng(5)
    b = rng.standard_normal(a.n)
    x = solve_factored_transposed(factor.num, b)
    dense = _dense_of(a, vals)
    assert np.allclose(dense.T @ x, b, atol=1e-9)
    # and the forward path still matches, same factors
    y = solve_factored(factor.num, b, batched=False)
    assert np.allclose(dense @ y, b, atol=1e-9)


def test_condition_estimate_tracks_numpy():
    a = banded_random(120, band=5, seed=6)
    vals = generic_values_csr(a, seed=6)
    factor = analyze(a, PLAIN).factorize(vals)
    q = factor.quality()
    true_cond = np.linalg.cond(_dense_of(a, vals), 1)
    # Hager is a lower bound, in practice within a small factor
    assert q.cond_1_est <= true_cond * (1 + 1e-8)
    assert q.cond_1_est >= true_cond / 20.0
    assert q.verdict == "ok" and q.ok


def test_quality_rejects_garbage_factors():
    # exercise the verdict logic directly: non-finite growth => reject
    from repro.robust.condition import _verdict
    assert _verdict(np.inf, 1.0, 0) == "reject"
    assert _verdict(1.0, 1e15, 0) == "reject"
    assert _verdict(1.0, 1e12, 0) == "suspect"
    assert _verdict(1e7, 1.0, 0) == "suspect"
    assert _verdict(1.0, 1.0, 3) == "suspect"
    assert _verdict(1.0, 1.0, 0) == "ok"
    assert QualityReport(growth=1.0, cond_1_est=1.0, norm1_a=1.0,
                         perturbed_pivots=0, verdict="ok").ok


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------

def test_engine_attaches_quality_reports():
    from repro.serve.engine import SolverEngine

    a, vals = HOSTILE["shuffled"]()
    eng = SolverEngine(ROBUST, batch_slots=4, quality=True)
    rng = np.random.default_rng(2)
    rids = [eng.submit(a, vals, rng.standard_normal(a.n)) for _ in range(5)]
    results = eng.flush()
    assert [r.rid for r in results] == rids
    for r in results:
        assert r.residual <= 1e-8
        assert r.quality is not None and r.quality.verdict in ("ok",
                                                               "suspect")
    # default engines skip the certificate entirely
    eng2 = SolverEngine(ROBUST, batch_slots=4)
    assert eng2.solve(a, vals, rng.standard_normal(a.n)).quality is None
