"""Plan/factor session API (ISSUE 4 / DESIGN.md §10).

Contract: ``repro.analyze`` precomputes everything value-independent and
``plan.factorize(values)`` is bitwise-identical to one-shot
``numeric_factorize`` on every matrix generator; plans pickle and the
unpickled plan produces identical factors; the streamed CSC pattern equals
the dense gather; multi-RHS solves match column-by-column solves; the
deprecated shims warn exactly once per call while matching new-API outputs;
and analyze never materializes a dense (n, n) pattern.
"""
import dataclasses
import pickle
import tracemalloc
import warnings

import numpy as np
import pytest

import repro
from repro.api import LUFactorization, LUOptions, LUPlan, analyze
from repro.core.gsofa import dense_pattern, prepare_graph
from repro.core.symbolic import PatternCollector, symbolic_factorize
from repro.numeric import numeric_factorize, solve
from repro.sparse import (
    banded_full, banded_random, bordered_block_diagonal, chemical_like,
    circuit_like, economic_like, grid2d_laplacian, grid3d_laplacian,
    indefinite, permute_csr, random_pattern, rcm_order, shuffled_dominant,
)
from repro.sparse.numeric import (
    ZeroPivotError, generic_values, generic_values_csr,
)

# every generator in sparse/matrices.py, at n <= 1024
GENERATORS = {
    "grid2d": lambda: grid2d_laplacian(14),
    "grid3d": lambda: grid3d_laplacian(6),
    "circuit": lambda: circuit_like(300, seed=7),
    "economic": lambda: economic_like(256, block=16, seed=2),
    "chemical": lambda: chemical_like(320, stage=16, seed=3),
    "banded": lambda: banded_random(240, band=6, seed=4),
    "banded_full": lambda: banded_full(200, band=5),
    "random": lambda: random_pattern(160, density=0.02, seed=5),
    "bbd": lambda: bordered_block_diagonal(512, block=16, border=32, seed=6),
    "indefinite": lambda: indefinite(160, band=6, seed=1),
    "shuffled": lambda: shuffled_dominant(160, band=5, seed=2),
}

OPTS = LUOptions(concurrency=64, supernode_relax=2)


def _matrix(name):
    a = GENERATORS[name]()
    return permute_csr(a, rcm_order(a))


@pytest.fixture(scope="module")
def plans():
    """One analysis per generator, shared across the property tests."""
    return {name: analyze(_matrix(name), OPTS) for name in GENERATORS}


# ---------------------------------------------------------------------------
# property: plan.factorize == one-shot numeric_factorize, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_factorize_bitwise_matches_oneshot(name, plans):
    plan = plans[name]
    a = plan.a
    values = generic_values_csr(a)
    factor = plan.factorize(values)
    sym = symbolic_factorize(a, concurrency=64, detect_supernodes=True,
                             supernode_relax=2)
    num = numeric_factorize(a, sym, values=values)
    ls, us = factor.num.store.dense_lu()
    ld, ud = num.store.dense_lu()
    assert np.array_equal(ls, ld) and np.array_equal(us, ud)


@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_streamed_pattern_matches_dense_gather(name, plans):
    plan = plans[name]
    ref = dense_pattern(prepare_graph(plan.a))
    assert np.array_equal(plan.pattern.to_dense(), ref)


@pytest.mark.parametrize("name", ["grid2d", "circuit", "bbd"])
def test_pickled_plan_produces_identical_factors(name, plans):
    plan = plans[name]
    values = generic_values_csr(plan.a)
    ref = plan.factorize(values)
    plan2 = pickle.loads(pickle.dumps(plan))
    got = plan2.factorize(values)
    for b_ref, b_got in zip(ref.num.store.blocks, got.num.store.blocks):
        assert np.array_equal(b_ref, b_got)
    b = np.random.default_rng(0).standard_normal(plan.n)
    assert np.array_equal(ref.solve(b).x, got.solve(b).x)


def test_refactorize_reuses_buffers_in_place(plans):
    plan = plans["circuit"]
    values = generic_values_csr(plan.a)
    factor = plan.factorize(values)
    blocks_before = [id(b) for b in factor.num.store.blocks]
    factor2 = factor.refactorize(values * 3.0)
    assert [id(b) for b in factor2.num.store.blocks] == blocks_before
    ref = plan.factorize(values * 3.0)
    for b_ref, b_got in zip(ref.num.store.blocks, factor2.num.store.blocks):
        assert np.array_equal(b_ref, b_got)


def test_factorize_accepts_dense_values(plans):
    plan = plans["grid2d"]
    a = plan.a
    vals = generic_values_csr(a)
    dense = np.zeros((a.n, a.n))
    for i in range(a.n):
        dense[i, a.row(i)] = vals[a.indptr[i]:a.indptr[i + 1]]
    f_dense = plan.factorize(dense)
    f_csr = plan.factorize(vals)
    ls, us = f_dense.num.store.dense_lu()
    lc, uc = f_csr.num.store.dense_lu()
    assert np.array_equal(ls, lc) and np.array_equal(us, uc)


def test_zero_pivot_propagates_through_plan(plans):
    plan = plans["grid2d"]
    vals = generic_values_csr(plan.a)
    diag = plan.a.indices == np.repeat(
        np.arange(plan.n), np.diff(plan.a.indptr))
    bad = vals.copy()
    bad[np.flatnonzero(diag)[0]] = 0.0
    bad[~diag] = 0.0                       # diagonal matrix with a zero pivot
    with pytest.raises(ZeroPivotError):
        plan.factorize(bad)


# ---------------------------------------------------------------------------
# multi-RHS solve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["grid2d", "economic", "bbd"])
def test_multi_rhs_matches_dense_oracle(name, plans):
    plan = plans[name]
    values = generic_values(plan.a)
    factor = plan.factorize(values)
    rhs = np.random.default_rng(1).standard_normal((plan.n, 5))
    res = factor.solve(rhs)
    x0 = np.linalg.solve(values, rhs)
    assert res.x.shape == (plan.n, 5)
    assert np.abs(res.x - x0).max() / np.abs(x0).max() <= 1e-10
    assert res.residual <= 1e-10


def test_multi_rhs_columns_match_single_solves(plans):
    plan = plans["grid3d"]
    values = generic_values(plan.a)
    factor = plan.factorize(values)
    rhs = np.random.default_rng(2).standard_normal((plan.n, 3))
    # refinement off: per-column acceptance makes refined multi-RHS answers
    # only near-identical; the pure substitution pipeline is bitwise
    multi = factor.solve(rhs, refine_iters=0)
    for c in range(rhs.shape[1]):
        single = factor.solve(rhs[:, c], refine_iters=0)
        # BLAS triangular solves round differently for matrix vs vector
        # RHS, so columns agree to roundoff, not bitwise
        np.testing.assert_allclose(multi.x[:, c], single.x, rtol=1e-12,
                                   atol=1e-12 * np.abs(single.x).max())


def test_multi_rhs_refinement_history_non_increasing(plans):
    plan = plans["circuit"]
    values = generic_values(plan.a)
    factor = plan.factorize(values)
    rhs = np.random.default_rng(3).standard_normal((plan.n, 4))
    res = factor.solve(rhs, refine_iters=5, refine_tol=0.0)
    hist = np.array(res.residuals)
    assert (np.diff(hist) <= 0).all()


def test_solve_timing_split(plans):
    plan = plans["grid2d"]
    values = generic_values_csr(plan.a)
    factor = plan.factorize(values)
    b = np.random.default_rng(4).standard_normal(plan.n)
    res = factor.solve(b)
    # the factorization happened on the factor object, not in solve()
    assert factor.factor_s > 0
    assert res.factor_s == 0.0
    assert res.solve_s > 0
    assert res.elapsed_s == res.factor_s + res.solve_s
    # the engine-level solve that builds its own factorization reports both
    res2 = solve(plan.a, b, values=values, pattern=plan.pattern,
                 supernodes=plan.sym.supernodes)
    assert res2.factor_s > 0 and res2.solve_s > 0


def test_plan_solve_convenience(plans):
    plan = plans["banded"]
    values = generic_values_csr(plan.a)
    b = np.random.default_rng(5).standard_normal(plan.n)
    res = plan.solve(b, values)
    assert res.residual <= 1e-10
    assert res.factor_s > 0          # the convenience path reports the split


# ---------------------------------------------------------------------------
# LUOptions
# ---------------------------------------------------------------------------

def test_options_frozen_and_validated():
    opts = LUOptions()
    with pytest.raises(dataclasses.FrozenInstanceError):
        opts.concurrency = 4        # type: ignore[misc]


def test_options_reject_unknown_backends():
    with pytest.raises(ValueError, match="symbolic backend"):
        LUOptions(backend="nope")
    with pytest.raises(ValueError, match="numeric backend"):
        LUOptions(numeric_backend="nope")
    with pytest.raises(ValueError, match="packing policy"):
        LUOptions(policy="nope")


def test_options_reject_nonpositive_sizes():
    """Nonsensical knob values fail fast with actionable messages instead
    of surfacing as opaque shape/index errors deep in the pipeline."""
    with pytest.raises(ValueError, match="concurrency must be >= 1"):
        LUOptions(concurrency=0)
    with pytest.raises(ValueError, match="concurrency must be >= 1"):
        LUOptions(concurrency=-8)
    with pytest.raises(ValueError, match="supernode_max_size must be >= 1"):
        LUOptions(supernode_max_size=0)
    with pytest.raises(ValueError, match="supernode_relax must be >= 0"):
        LUOptions(supernode_relax=-1)
    with pytest.raises(ValueError, match="n_bins must be >= 1"):
        LUOptions(n_bins=0)
    with pytest.raises(ValueError, match="refine_iters must be >= 0"):
        LUOptions(refine_iters=-1)
    with pytest.raises(ValueError, match="budget_bytes must be >= 1"):
        LUOptions(budget_bytes=0)
    with pytest.raises(ValueError, match="perturb_eps must be positive"):
        LUOptions(perturb_eps=0.0)


def test_options_reject_bad_blocking_knobs():
    with pytest.raises(ValueError, match="block_max_width must be >= 1"):
        LUOptions(block_max_width=0)
    with pytest.raises(ValueError, match="block_merge_threshold must be > 0"):
        LUOptions(block_merge_threshold=0.0)
    with pytest.raises(ValueError, match="block_merge_threshold must be > 0"):
        LUOptions(block_merge_threshold=-1.5)
    # valid combinations construct fine
    assert LUOptions(blocking=True, block_max_width=1).block_max_width == 1
    assert LUOptions(autotune=True,
                     block_merge_threshold=1.25).block_merge_threshold == 1.25


def test_options_replace():
    opts = LUOptions()
    opts2 = opts.replace(supernode_relax=3)
    assert opts2.supernode_relax == 3 and opts.supernode_relax == 0
    assert opts2.concurrency == opts.concurrency


def test_options_thread_through_plan(plans):
    plan = analyze(_matrix("grid2d"),
                   OPTS.replace(policy="contiguous", n_bins=4))
    assert plan.options.policy == "contiguous"
    # same partition, different packing policy: factors are bitwise
    # invariant to the packing (PR-2 contract)
    ref = plans["grid2d"].factorize(generic_values_csr(plan.a))
    got = plan.factorize(generic_values_csr(plan.a))
    ls, us = ref.num.store.dense_lu()
    lg, ug = got.num.store.dense_lu()
    assert np.array_equal(ls, lg) and np.array_equal(us, ug)


# ---------------------------------------------------------------------------
# deprecated one-shot surface: removed in 1.4.0 (announced for one release)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["symbolic_factorize", "numeric_factorize",
                                  "solve"])
def test_deprecated_names_are_gone_from_top_level(name):
    """The 1.3.x DeprecationWarning shims were removed on schedule: the
    names are absent from the lazy export table and raise AttributeError —
    the engine-level homes (repro.core.symbolic / repro.numeric) remain."""
    assert name not in repro._LAZY_EXPORTS
    assert name not in repro.__all__
    with pytest.raises(AttributeError, match=name):
        getattr(repro, name)


def test_engine_level_names_still_importable():
    from repro.core.symbolic import symbolic_factorize as sf
    from repro.numeric import numeric_factorize as nf, solve as sv

    assert callable(sf) and callable(nf) and callable(sv)


def test_internal_modules_never_call_deprecated_surface(plans):
    """The repo-wide ``error::DeprecationWarning:repro`` filter stays: any
    future deprecation cycle gets the same cannot-call-internally
    guarantee; assert the filter is actually installed."""
    filters = [f for f in warnings.filters
               if f[2] is DeprecationWarning]
    assert any(f[3] and f[3].pattern == "repro" and f[0] == "error"
               for f in filters
               if f[3] is not None), warnings.filters


# ---------------------------------------------------------------------------
# memory shape: analyze never goes dense
# ---------------------------------------------------------------------------

def test_analyze_allocates_no_dense_pattern():
    """BBD circuit analogue at n = 4096: tracemalloc ceiling far below the
    16.8 MB a dense bool (n, n) pattern would cost on top of the O(nnz)
    state (the bench_refactorize large case re-checks this at n = 20_000
    with a 256 MB ceiling vs a 400 MB dense pattern)."""
    n = 4096
    a = bordered_block_diagonal(n, block=16, border=32, seed=3)
    analyze(a, LUOptions(concurrency=256))       # warm the jit caches first
    tracemalloc.start()
    plan = analyze(a, LUOptions(concurrency=256))
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < 12 * 1024 * 1024, f"peak {peak/1e6:.1f} MB"
    assert plan.pattern.nnz < 16 * a.nnz         # fill stayed O(nnz)
    # and the plan still factors + solves correctly
    factor = plan.factorize(generic_values_csr(a))
    b = np.random.default_rng(7).standard_normal(n)
    assert factor.solve(b).residual <= 1e-10


def test_pattern_collector_rejects_incomplete():
    pc = PatternCollector(n=4)
    pc.update(np.eye(4, dtype=bool)[:2], np.array([0, 1]))
    with pytest.raises(ValueError, match="pattern incomplete"):
        pc.to_csc()


def test_pattern_collector_idempotent_redelivery():
    rng = np.random.default_rng(8)
    mask = rng.random((4, 6)) < 0.4
    pc = PatternCollector(n=6)
    pc.update(mask, np.array([0, 1, 2, 3]))
    n_new = pc.update(mask, np.array([0, 1, 2, 3]))     # replayed chunk
    assert n_new == 0
    pc.update(np.zeros((2, 6), dtype=bool), np.array([4, 5]))
    dense = pc.to_csc().to_dense()
    ref = np.zeros((6, 6), dtype=bool)
    ref[:4] = mask
    np.fill_diagonal(ref, True)
    assert np.array_equal(dense, ref)


def test_version_and_exports():
    assert repro.__version__ == "1.7.0"
    for name in ("analyze", "replan", "LUOptions", "LUPlan",
                 "LUFactorization", "BatchedLUFactorization", "SolverEngine",
                 "PanelPlacement", "RobustPlan", "QualityReport",
                 "RooflineCostModel", "TuneReport", "BlockingStats"):
        assert name in repro.__all__
        assert getattr(repro, name) is not None
    assert repro.analyze is analyze
    assert repro.LUPlan is LUPlan
    assert repro.LUFactorization is LUFactorization
