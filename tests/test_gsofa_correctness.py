"""Correctness of the symbolic-factorization core against independent oracles.

Chain of evidence:
  elimination_fill (definition of fill)  ==  minimax_fill (Theorem 1 semiring)
  ==  fill2 (paper Fig 4a)  ==  GSoFa fixpoint (paper Fig 4b, all backends)
  ==  multi-source / arena / bubble variants.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.fill2 import fill2_dense
from repro.core.gsofa import prepare_graph, dense_pattern, gsofa_batch
from repro.core.multisource import run_multisource
from repro.core.symbolic import symbolic_factorize
from repro.core.theory import elimination_fill, minimax_fill, fill_ratio
from repro.sparse import (
    banded_random, chemical_like, circuit_like, economic_like, grid2d_laplacian,
    grid3d_laplacian, random_pattern, rcm_order, permute_csr,
)
from repro.sparse.csr import csr_from_dense

MATS = {
    "grid2d": lambda: grid2d_laplacian(7),
    "grid3d": lambda: grid3d_laplacian(4),
    "circuit": lambda: circuit_like(120, seed=1),
    "economic": lambda: economic_like(96, block=12, seed=2),
    "chemical": lambda: chemical_like(128, stage=16, seed=3),
    "banded": lambda: banded_random(100, band=6, seed=4),
    "random": lambda: random_pattern(80, density=0.05, seed=5),
    "random_sym": lambda: random_pattern(64, density=0.05, symmetric=True, seed=6),
}


def _ref_counts(a):
    e = elimination_fill(a)
    np.fill_diagonal(e, False)
    ids = np.arange(a.n)
    return ((e & (ids[None, :] < ids[:, None])).sum(1),
            (e & (ids[None, :] > ids[:, None])).sum(1))


@pytest.mark.parametrize("name", sorted(MATS))
def test_oracles_agree(name):
    a = MATS[name]()
    assert np.array_equal(elimination_fill(a), minimax_fill(a)), \
        "Theorem-1 minimax closure must equal elimination fill"


@pytest.mark.parametrize("name", sorted(MATS))
def test_fill2_matches_oracle(name):
    a = MATS[name]()
    assert np.array_equal(fill2_dense(a), elimination_fill(a))


@pytest.mark.parametrize("name", sorted(MATS))
@pytest.mark.parametrize("backend", ["ell", "dense", "kernel"])
def test_gsofa_matches_oracle(name, backend):
    a = MATS[name]()
    dense_block = 128 if backend in ("dense", "kernel") else None
    g = prepare_graph(a, dense_block=dense_block)
    got = dense_pattern(g, backend=backend, batch=48)
    assert np.array_equal(got, elimination_fill(a))


@pytest.mark.parametrize("kwargs", [
    dict(combined=True, use_arena=True),
    dict(combined=True, use_arena=False),
    dict(combined=False, use_arena=False),
    dict(combined=True, bubble=True),
])
def test_multisource_variants(kwargs):
    a = circuit_like(150, seed=7)
    l_ref, u_ref = _ref_counts(a)
    r = run_multisource(prepare_graph(a), concurrency=48, **kwargs)
    assert np.array_equal(r.l_counts, l_ref)
    assert np.array_equal(r.u_counts, u_ref)


def test_arena_reuses_windows_without_reinit():
    a = grid2d_laplacian(12)  # 144 vertices -> 3 chunks at #C=64
    r = run_multisource(prepare_graph(a), concurrency=64, use_arena=True)
    assert r.windows >= 3
    assert r.reinits == 1, "window trick must avoid per-chunk re-initialization"


def test_combined_traversal_reduces_supersteps():
    a = circuit_like(200, seed=8)
    g = prepare_graph(a)
    combined = run_multisource(g, concurrency=64, combined=True)
    separate = run_multisource(g, concurrency=64, combined=False)
    assert np.array_equal(combined.l_counts, separate.l_counts)
    assert combined.supersteps < separate.supersteps / 4


def test_public_api_counts_and_fill_ratio():
    a = economic_like(128, block=16, seed=9)
    l_ref, u_ref = _ref_counts(a)
    r = symbolic_factorize(a, concurrency=64)
    assert np.array_equal(r.l_counts, l_ref)
    assert np.array_equal(r.u_counts, u_ref)
    assert r.fill_ratio == pytest.approx(
        fill_ratio(a, elimination_fill(a)) * a.nnz / a.nnz, rel=1e-6)


def test_memory_budget_reduces_concurrency():
    a = circuit_like(400, seed=10)
    g = prepare_graph(a)
    small = symbolic_factorize(a, graph=g, concurrency=256, budget_bytes=1_500_000)
    big = symbolic_factorize(a, graph=g, concurrency=256)
    assert small.concurrency < big.concurrency
    assert np.array_equal(small.l_counts, big.l_counts)


def test_workload_grows_with_source_id():
    """Paper Fig 3: average frontier workload rises with the source id."""
    a = grid2d_laplacian(14)
    r = run_multisource(prepare_graph(a), concurrency=64)
    n = a.n
    lo = r.edge_checks[: n // 4].mean()
    hi = r.edge_checks[3 * n // 4:].mean()
    assert hi > 2 * lo


def test_rcm_reordering_reduces_fill():
    a = random_pattern(120, density=0.03, symmetric=True, seed=11)
    base = elimination_fill(a).sum()
    perm = rcm_order(a)
    ra = permute_csr(a, perm)
    reordered = elimination_fill(ra).sum()
    assert reordered < base  # RCM should not hurt on a random symmetric pattern
    # and GSoFa agrees on the reordered matrix too
    assert np.array_equal(dense_pattern(prepare_graph(ra)), elimination_fill(ra))


# ---------------------------------------------------------------------------
# property-based: random digraphs, invariants of the label fixpoint
# ---------------------------------------------------------------------------

@st.composite
def digraphs(draw):
    n = draw(st.integers(min_value=2, max_value=28))
    density = draw(st.floats(min_value=0.02, max_value=0.35))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    dense = rng.random((n, n)) < density
    np.fill_diagonal(dense, True)
    return csr_from_dense(dense)


@given(digraphs())
@settings(max_examples=60, deadline=None)
def test_property_gsofa_equals_elimination(a):
    g = prepare_graph(a)
    assert np.array_equal(dense_pattern(g, batch=32), elimination_fill(a))


@given(digraphs())
@settings(max_examples=40, deadline=None)
def test_property_fill_superset_of_A_and_monotone(a):
    """Invariants: L+U contains A; labels are lower bounds that only decrease."""
    g = prepare_graph(a)
    pat = dense_pattern(g, batch=32)
    assert np.all(pat | ~a.to_dense() == pat | ~a.to_dense())  # well-formed
    assert np.all((a.to_dense() & ~np.eye(a.n, dtype=bool)) <= pat)
    # monotonicity: running extra supersteps never changes the converged labels
    srcs = np.arange(a.n, dtype=np.int32)
    r1 = gsofa_batch(g, srcs)
    r2 = gsofa_batch(g, srcs, max_iters=4 * (a.n + 2))
    assert np.array_equal(np.asarray(r1.labels), np.asarray(r2.labels))


@given(digraphs(), st.integers(min_value=1, max_value=5))
@settings(max_examples=30, deadline=None)
def test_property_chunking_invariant(a, chunks):
    """Counts are independent of how sources are chunked (#C)."""
    l_ref, u_ref = _ref_counts(a)
    c = max(1, a.n // chunks)
    r = run_multisource(prepare_graph(a), concurrency=c)
    assert np.array_equal(r.l_counts, l_ref)
    assert np.array_equal(r.u_counts, u_ref)


def test_supernode_detection():
    """Paper §V: supernode detection as a post-pass (grid matrices have
    nontrivial supernodes after fill)."""
    from repro.core.gsofa import dense_pattern, prepare_graph
    from repro.core.symbolic import detect_supernodes
    from repro.sparse import grid2d_laplacian, permute_csr, rcm_order

    a = grid2d_laplacian(12)
    a = permute_csr(a, rcm_order(a))
    pattern = dense_pattern(prepare_graph(a))
    sn = detect_supernodes(pattern)
    # ranges are a partition of the columns
    assert sn[0, 0] == 0 and sn[-1, 1] == a.n
    assert (sn[1:, 0] == sn[:-1, 1]).all()
    sizes = sn[:, 1] - sn[:, 0]
    assert (sizes >= 1).all()
    # dense trailing blocks of a filled grid produce multi-column supernodes
    assert sizes.max() >= 2
    # inside a supernode every column has identical below-block structure
    s, e = sn[sizes.argmax()]
    for j in range(s + 1, e):
        assert (pattern[e:, j] == pattern[e:, s]).all()
