"""Structure-aware irregular blocking + roofline autotune (DESIGN.md §16).

Contract: the merge pass emits a valid contiguous partition whose merged
panels keep padded entries exactly zero; blocked and autotuned factors hold
dense-oracle parity on every generator (merging regroups float ops, so the
gate is the oracle, not bitwise); ``repro.replan`` with the plan's own
knobs reproduces its factors bitwise and never re-runs the fixpoint; the
cost model and ``choose_concurrency`` are deterministic pure functions; and
the ``blocking.*`` / ``tune.*`` metrics land in the registry when tracing.
"""
import pickle

import numpy as np
import pytest

import repro
from repro.api import LUOptions, analyze, replan
from repro.kernels.ops import padded_gemm_shape
from repro.sparse import (
    banded_full, banded_random, bordered_block_diagonal, chemical_like,
    circuit_like, economic_like, grid2d_laplacian, grid3d_laplacian,
    indefinite, permute_csr, random_pattern, rcm_order, shuffled_dominant,
)
from repro.sparse.numeric import generic_values_csr, lu_nopivot
from repro.supernodes.blocking import (
    BlockingStats, merge_supernodes, partition_stats,
)
from repro.tune import (
    RooflineCostModel, autotune_partition, choose_concurrency,
    cost_model_for,
)

GENERATORS = {
    "grid2d": lambda: grid2d_laplacian(14),
    "grid3d": lambda: grid3d_laplacian(6),
    "circuit": lambda: circuit_like(300, seed=7),
    "economic": lambda: economic_like(256, block=16, seed=2),
    "chemical": lambda: chemical_like(320, stage=16, seed=3),
    "banded": lambda: banded_random(240, band=6, seed=4),
    "banded_full": lambda: banded_full(200, band=5),
    "random": lambda: random_pattern(160, density=0.02, seed=5),
    "bbd": lambda: bordered_block_diagonal(512, block=16, border=32, seed=6),
    "indefinite": lambda: indefinite(160, band=6, seed=1),
    "shuffled": lambda: shuffled_dominant(160, band=5, seed=2),
}

OPTS = LUOptions(concurrency=64, supernode_relax=2)


def _matrix(name):
    a = GENERATORS[name]()
    return permute_csr(a, rcm_order(a))


def _dense(a, values):
    out = np.zeros((a.n, a.n))
    for i in range(a.n):
        out[i, a.indices[a.indptr[i]:a.indptr[i + 1]]] = \
            values[a.indptr[i]:a.indptr[i + 1]]
    return out


def _rel_err(got, ref):
    scale = max(1.0, np.abs(ref).max())
    return np.abs(got - ref).max() / scale


@pytest.fixture(scope="module")
def plans():
    """One default analysis per generator; blocked/tuned variants replan
    from it (no fixpoint re-run), mirroring the bench harness."""
    return {name: analyze(_matrix(name), OPTS) for name in GENERATORS}


# ---------------------------------------------------------------------------
# property: blocked + autotuned factors hold dense-oracle parity everywhere
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_blocked_factors_match_dense_oracle(name, plans):
    plan = plans[name]
    values = generic_values_csr(plan.a)
    blocked = replan(plan, OPTS.replace(blocking=True))
    assert blocked.n_supernodes <= plan.n_supernodes
    factor = blocked.factorize(values)
    l0, u0 = lu_nopivot(_dense(plan.a, values))
    assert _rel_err(factor.l, l0) <= 1e-10
    assert _rel_err(factor.u, u0) <= 1e-10


@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_autotuned_factors_match_dense_oracle(name, plans):
    plan = plans[name]
    values = generic_values_csr(plan.a)
    tuned = replan(plan, OPTS.replace(autotune=True))
    factor = tuned.factorize(values)
    l0, u0 = lu_nopivot(_dense(plan.a, values))
    assert _rel_err(factor.l, l0) <= 1e-10
    assert _rel_err(factor.u, u0) <= 1e-10
    # the sweep's chosen knobs are frozen onto the plan's options
    assert tuned.tuned is not None
    assert tuned.options.blocking is True
    assert tuned.options.supernode_relax == \
        tuned.tuned.chosen["supernode_relax"]
    # the model never prefers a partition it scores above the untuned one
    assert tuned.tuned.modeled_s <= tuned.tuned.baseline_s + 1e-12


@pytest.mark.parametrize("name", ["grid2d", "circuit", "bbd"])
def test_blocked_solve_matches_default_solution(name, plans):
    plan = plans[name]
    values = generic_values_csr(plan.a)
    b = np.random.default_rng(0).standard_normal(plan.n)
    x0 = plan.factorize(values).solve(b).x
    xb = replan(plan, OPTS.replace(blocking=True)).factorize(values).solve(b).x
    scale = max(1.0, np.abs(x0).max())
    assert np.abs(xb - x0).max() / scale <= 1e-9


# ---------------------------------------------------------------------------
# replan: same knobs -> bitwise; fingerprint retention contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["grid2d", "circuit", "bbd"])
def test_replan_same_knobs_is_bitwise(name, plans):
    plan = plans[name]
    values = generic_values_csr(plan.a)
    ref = plan.factorize(values)
    got = replan(plan).factorize(values)
    for b_ref, b_got in zip(ref.num.store.blocks, got.num.store.blocks):
        assert np.array_equal(b_ref, b_got)
    b = np.random.default_rng(1).standard_normal(plan.n)
    assert np.array_equal(ref.solve(b).x, got.solve(b).x)


def test_replan_without_fingerprints_raises(plans):
    plan = plans["grid2d"]
    import dataclasses as _dc

    stripped = _dc.replace(plan.sym, fingerprints=None)
    crippled = _dc.replace(plan, sym=stripped)
    with pytest.raises(ValueError, match="fingerprints"):
        replan(crippled)


def test_plan_retains_picklable_fingerprints(plans):
    plan = plans["circuit"]
    assert plan.sym.fingerprints is not None
    fp2 = pickle.loads(pickle.dumps(plan.sym.fingerprints))
    assert np.array_equal(fp2.counts, plan.sym.fingerprints.counts)
    assert np.array_equal(fp2.hxor, plan.sym.fingerprints.hxor)


def test_blocked_plan_pickle_roundtrip_is_bitwise(plans):
    plan = plans["bbd"]
    values = generic_values_csr(plan.a)
    blocked = replan(plan, OPTS.replace(blocking=True))
    ref = blocked.factorize(values)
    got = pickle.loads(pickle.dumps(blocked)).factorize(values)
    for b_ref, b_got in zip(ref.num.store.blocks, got.num.store.blocks):
        assert np.array_equal(b_ref, b_got)


# ---------------------------------------------------------------------------
# merge pass: partition validity, padding stays exactly zero, stats
# ---------------------------------------------------------------------------

def test_merge_emits_valid_contiguous_partition(plans):
    plan = plans["bbd"]
    model = RooflineCostModel()
    merged, stats = merge_supernodes(plan.pattern, plan.sym.supernodes,
                                     model, max_width=64)
    assert isinstance(stats, BlockingStats)
    assert merged[0][0] == 0 and merged[-1][1] == plan.n
    assert (merged[1:, 0] == merged[:-1, 1]).all()      # contiguous cover
    assert (merged[:, 1] - merged[:, 0] <= 64).all()    # max_width respected
    assert stats.n_before - stats.merges == stats.n_after
    assert stats.modeled_after_s <= stats.modeled_before_s + 1e-12
    assert stats.pad_entries_after >= stats.pad_entries_before


def test_merge_threshold_below_one_merges_less(plans):
    plan = plans["bbd"]
    model = RooflineCostModel()
    loose, _ = merge_supernodes(plan.pattern, plan.sym.supernodes, model,
                                threshold=1.0)
    strict, _ = merge_supernodes(plan.pattern, plan.sym.supernodes, model,
                                 threshold=1e-9)
    assert len(strict) >= len(loose)
    # a vanishing threshold accepts (essentially) no merges
    assert len(strict) == len(plan.sym.supernodes)


def test_blocked_padding_is_exactly_zero(plans):
    plan = plans["circuit"]
    values = generic_values_csr(plan.a)
    blocked = replan(plan, OPTS.replace(blocking=True))
    factor = blocked.factorize(values)
    store = factor.num.store
    assert store.pad_entries > 0          # merging did introduce padding
    for blk, mask in zip(store.blocks, store.in_pattern):
        assert not blk[~mask].any()       # padded slots exactly zero


def test_partition_stats_match_store(plans):
    plan = plans["grid2d"]
    stats = partition_stats(plan.pattern, plan.schedule.supernodes)
    store = plan.store_template
    for i, (s, e) in enumerate(plan.schedule.supernodes):
        assert stats["w"][i] == e - s
        assert stats["m"][i] + stats["k"][i] == len(store.rows[i])
    assert stats["pad_entries"].sum() == store.pad_entries


# ---------------------------------------------------------------------------
# cost model + concurrency chooser: deterministic pure functions
# ---------------------------------------------------------------------------

def test_cost_model_roofline_behavior():
    model = RooflineCostModel(mem_bw_gbs=10.0, flops_gflops=50.0,
                              dispatch_overhead_s=0.0)
    # tiny GEMM: bandwidth-bound -> time == bytes / bw
    t = model.gemm_time(8, 8, 8)
    assert t == pytest.approx(8 * (64 + 64 + 128) / 10e9)
    # huge cubic GEMM: compute-bound -> time == flops / peak
    t = model.gemm_time(2048, 2048, 2048)
    assert t == pytest.approx(2 * 2048 ** 3 / 50e9)
    # vectorized call matches scalar calls elementwise
    m = np.array([8, 2048]); k = np.array([8, 2048]); w = np.array([8, 2048])
    vec = model.gemm_time(m, k, w)
    assert vec[0] == pytest.approx(model.gemm_time(8, 8, 8))
    assert vec[1] == pytest.approx(model.gemm_time(2048, 2048, 2048))


def test_cost_model_from_peaks_and_kernel_padding():
    peaks = {"mem_bw_gbs": 100.0, "flops_gflops": 1000.0}
    model = cost_model_for(LUOptions(numeric_backend="kernel"), peaks)
    assert model.mem_bw_gbs == 100.0 and model.backend == "kernel"
    # kernel backend charges the padded MXU shape, so it costs at least
    # as much as the logical shape the numpy model charges
    logical = RooflineCostModel(mem_bw_gbs=100.0, flops_gflops=1000.0)
    assert model.gemm_time(5, 3, 7) >= logical.gemm_time(5, 3, 7)


def test_padded_gemm_shape_multiples():
    assert padded_gemm_shape(5, 3, 7) == (8, 128, 128)
    assert padded_gemm_shape(130, 128, 128) == (256, 128, 128)
    assert padded_gemm_shape(0, 3, 7) == (0, 0, 0)
    m, k, n = padded_gemm_shape(np.array([5, 130]), np.array([3, 128]),
                                np.array([7, 128]))
    assert list(m) == [8, 256] and list(k) == [128, 128]


def test_choose_concurrency_deterministic_and_clamped():
    assert choose_concurrency(20000) == 512
    assert choose_concurrency(300) == 300       # never exceeds n
    assert choose_concurrency(10_000_000) == 64  # floor
    assert choose_concurrency(1) == 1
    with pytest.raises(ValueError):
        choose_concurrency(0)


def test_autotune_requires_fingerprints(plans):
    with pytest.raises(ValueError, match="fingerprints"):
        autotune_partition(plans["grid2d"].pattern, None, OPTS)


# ---------------------------------------------------------------------------
# observability: blocking.* / tune.* metrics land when tracing
# ---------------------------------------------------------------------------

def test_blocking_and_tune_metrics_recorded(plans):
    plan = plans["circuit"]
    reg = repro.obs.registry()
    reg.reset()
    with repro.obs.tracing():
        replan(plan, OPTS.replace(autotune=True))
    snap = reg.snapshot()
    assert snap["counters"]["tune.candidates"] > 0
    assert snap["counters"]["blocking.merges"] >= 0
    assert "blocking.panels_after" in snap["gauges"]
    assert "tune.modeled_s" in snap["gauges"]
