"""Multi-device behavior on 8 forced host devices (subprocess — the device
count must be set before jax initializes, so these run out-of-process).

Covers: ring all-reduce (exact + compressed), distributed GSoFa with
interleaved sources (balance + counts equality vs single-device), and a
data+tensor-parallel train step whose loss matches the 1-device run.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, "src")
import json
import jax, jax.numpy as jnp
import numpy as np
out = {}

# --- ring all-reduce ---
from repro.runtime.collectives import make_ring_allreduce
from repro.launch.mesh import compat_make_mesh
mesh1 = compat_make_mesh((8,), ("x",))
x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 500)), jnp.float32)
want = np.asarray(x).sum(0)
got = np.asarray(make_ring_allreduce(mesh1, "x")(x))
out["ring_exact_err"] = float(np.abs(got - want[None]).max())
gotc = np.asarray(make_ring_allreduce(mesh1, "x", compress=True)(x))
out["ring_int8_rel_err"] = float(np.abs(gotc - want[None]).max()
                                 / np.abs(want).max())

# --- distributed GSoFa: interleaved sources over 8 devices ---
from repro.core.distributed import distributed_symbolic
from repro.core.gsofa import prepare_graph
from repro.core.multisource import run_multisource
from repro.sparse import paper_dataset_analogue, permute_csr, rcm_order
a = permute_csr(paper_dataset_analogue("TT"), rcm_order(paper_dataset_analogue("TT")))
graph = prepare_graph(a)
res_i = distributed_symbolic(graph, mesh1, policy="interleave")
res_c = distributed_symbolic(graph, mesh1, policy="contiguous")
single = run_multisource(graph, concurrency=64)
out["gsofa_counts_match"] = bool(
    (res_i["l_counts"] == single.l_counts).all()
    and (res_i["u_counts"] == single.u_counts).all())
out["balance_interleave"] = float(res_i["balance_ratio"])
out["balance_contiguous"] = float(res_c["balance_ratio"])

# --- DP x TP train step equals single-device ---
from repro.configs.base import ShapeConfig, get_config
from repro.data import make_batch_for
from repro.models import transformer as tf
from repro.train.optimizer import init_adamw
from repro.train.steps import make_train_step
cfg = get_config("qwen3-1.7b").reduced()
shape = ShapeConfig("s", 16, 4, "train")
batch = {k: jnp.asarray(v) for k, v in make_batch_for(cfg, shape).items()}
params = tf.init_params(jax.random.key(0), cfg, jnp.float32)
losses = {}
for name, axes in (("dp_tp", (4, 2)), ("single", (1, 1))):
    n_dev = axes[0] * axes[1]
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:n_dev]).reshape(axes), ("data", "model"))
    step = make_train_step(cfg, mesh, shape, dtype=jnp.float32, donate=False)
    p, o, m = step.fn(params, init_adamw(params), batch)
    losses[name] = float(m["loss"])
out["loss_dp_tp"] = losses["dp_tp"]
out["loss_single"] = losses["single"]
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results(tmp_path_factory):
    path = tmp_path_factory.mktemp("md") / "script.py"
    path.write_text(_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, str(path)], capture_output=True,
                       text=True, timeout=1200, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_ring_allreduce_exact(results):
    assert results["ring_exact_err"] < 1e-4


def test_ring_allreduce_int8(results):
    assert results["ring_int8_rel_err"] < 0.05


def test_distributed_gsofa_counts_match_single_device(results):
    assert results["gsofa_counts_match"]


def test_interleave_beats_contiguous(results):
    assert results["balance_interleave"] < 2.0
    assert results["balance_contiguous"] > 3.0


def test_dp_tp_loss_matches_single_device(results):
    assert abs(results["loss_dp_tp"] - results["loss_single"]) < 1e-3
