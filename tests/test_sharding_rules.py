"""Sharding-rule properties (hypothesis): specs always valid for the mesh —
axes never repeated, sharded dims always divisible — plus concrete checks of
the TP/FSDP/ZeRO layouts on the production mesh."""
import math

import jax
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.train import sharding as shd
from repro.train.steps import param_specs


@pytest.fixture(scope="module")
def mesh():
    # host has 1 device: an abstract mesh stands in for the 16x16 pod
    from repro.launch.mesh import compat_abstract_mesh
    return compat_abstract_mesh((16, 16), ("data", "model"))


def _canon(spec):
    """PartitionSpec may store ('data',) as 'data'; compare canonically."""
    out = []
    for e in spec:
        if e is None or isinstance(e, str):
            out.append(e)
        elif isinstance(e, tuple) and len(e) == 1:
            out.append(e[0])
        else:
            out.append(tuple(e))
    return tuple(out)


def _spec_axes(spec):
    axes = []
    for e in spec:
        if e is None:
            continue
        axes.extend(e if isinstance(e, tuple) else (e,))
    return axes


def _check_valid(spec, shape, mesh):
    axes = _spec_axes(spec)
    assert len(axes) == len(set(axes)), f"repeated axis in {spec}"
    for dim, e in zip(shape, tuple(spec) + (None,) * len(shape)):
        if e is None:
            continue
        es = e if isinstance(e, tuple) else (e,)
        total = math.prod(mesh.shape[a] for a in es)
        assert dim % total == 0, f"{spec} does not divide {shape}"


NAMES = ["table", "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "w_in",
         "w_out", "wq_a", "wq_b", "wkv_a", "wkv_b", "router", "scale",
         "conv_w", "a_log", "d_skip", "w_xproj", "w_dt", "u", "mix"]


@settings(max_examples=200, deadline=None)
@given(
    name=st.sampled_from(NAMES),
    grouped=st.booleans(),
    dims=st.lists(st.sampled_from([1, 3, 8, 16, 48, 64, 96, 576, 2048, 4096,
                                   16384, 49152, 92553]), min_size=1, max_size=3),
)
def test_param_pspec_always_valid(mesh, name, grouped, dims):
    cfg = get_config("qwen3-14b")
    shape = tuple(([4] if grouped else []) + dims)
    path = ("groups/l0/mixer/" if grouped else "") + name
    spec = shd.param_pspec(path, shape, mesh, cfg)
    assert len(tuple(spec)) <= len(shape)
    _check_valid(spec, shape, mesh)
    if grouped:
        assert tuple(spec)[0] is None          # stacked axis never sharded


@settings(max_examples=100, deadline=None)
@given(dims=st.lists(st.integers(1, 4096), min_size=1, max_size=4))
def test_zero1_always_valid(mesh, dims):
    spec = shd.zero1_pspec(P(), tuple(dims), mesh)
    _check_valid(spec, tuple(dims), mesh)


@settings(max_examples=100, deadline=None)
@given(
    b=st.sampled_from([1, 2, 16, 32, 128, 256]),
    hkv=st.sampled_from([1, 3, 4, 8, 16, 128]),
    t=st.sampled_from([128, 4096, 32768, 524288]),
)
def test_cache_pspec_always_valid(mesh, b, hkv, t):
    cfg = get_config("qwen3-14b")
    shape = (4, b, hkv, t, 128)
    spec = shd.cache_pspec("groups/l0/self/k", shape, mesh, cfg)
    _check_valid(spec, shape, mesh)


def test_tp_layout_on_production_mesh(mesh):
    cfg = get_config("qwen3-14b")
    specs = param_specs(cfg, jax.numpy.bfloat16)
    gp = specs["groups"]["l0"]
    wq = shd.param_pspec("groups/l0/mixer/wq", gp["mixer"]["wq"].shape, mesh, cfg)
    assert _canon(wq) == (None, "data", "model")      # column TP + FSDP on d
    wo = shd.param_pspec("groups/l0/mixer/wo", gp["mixer"]["wo"].shape, mesh, cfg)
    assert _canon(wo) == (None, "model", "data")      # row TP + FSDP on d
    # vocab 151936 divides 16 -> embedding vocab-sharded
    emb = shd.param_pspec("embed/table", specs["embed"]["table"].shape, mesh, cfg)
    assert tuple(emb)[0] == "model"


def test_fsdp_applies_for_giant_archs(mesh):
    cfg = get_config("deepseek-v3-671b")
    spec = shd.param_pspec("groups/l0/ffn/w_gate", (61, 256, 7168, 2048),
                           mesh, cfg)
    # experts over model (EP) + d_model over data (FSDP)
    assert _canon(spec) == (None, "model", "data", None)


def test_internvl_vocab_not_divisible_replicates(mesh):
    cfg = get_config("internvl2-26b")
    spec = shd.param_pspec("embed/table", (92553, 6144), mesh, cfg)
    assert tuple(spec)[0] is None                 # 92553 % 16 != 0


def test_long_context_cache_seq_sharded(mesh):
    cfg = get_config("jamba-1.5-large-398b")
    # batch=1 -> B unshardable; kv=8 < 16 -> heads unshardable; seq picks up
    # (data x model) = 256-way sharding
    spec = shd.cache_pspec("groups/l0/self/k", (9, 1, 8, 524288, 128), mesh, cfg)
    assert tuple(spec)[3] == ("data", "model")
